"""Quickstart: the full MobileRAG pipeline in one script, on CPU.

Builds an EcoVector index over a synthetic document set (real k-means +
centroid HNSW + per-cluster HNSW graphs spilled to disk), runs a query,
applies SCR, and generates an answer with a reduced on-device sLM.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.scr import SCRConfig
from repro.data.synthetic import make_qa_corpus
from repro.data.tokenizer import HashTokenizer
from repro.models import model
from repro.serving.embedder import HashEmbedder
from repro.serving.engine import Engine
from repro.serving.rag import MobileRAG, NaiveRAG


def main():
    print("== MobileRAG quickstart ==")
    corpus = make_qa_corpus("squad", n_docs=150, n_questions=10, seed=0)
    emb = HashEmbedder(dim=128)

    print("[1/4] building EcoVector index (k-means + centroid HNSW + "
          "per-cluster graphs on disk)...")
    mobile = MobileRAG(corpus.docs, emb, top_k=3, scr=SCRConfig(3, 2, 1))
    naive = NaiveRAG(corpus.docs, emb, top_k=3)
    ev = mobile.index
    print(f"      {len(corpus.docs)} docs, {ev.n_clusters} clusters, "
          f"RAM={ev.ram_bytes()/1e3:.0f} KB, disk={ev.disk_bytes()/1e3:.0f} KB"
          f" at {ev.storage_dir}")

    ex = corpus.examples[0]
    print(f"[2/4] query: {ex.question}")
    a_naive = naive.answer(ex.question)
    a_mobile = mobile.answer(ex.question)
    print(f"      Naive-RAG prompt: {a_naive.prompt_tokens} tokens "
          f"(model TTFT {a_naive.ttft_model_s:.2f}s)")
    print(f"      MobileRAG prompt: {a_mobile.prompt_tokens} tokens "
          f"(model TTFT {a_mobile.ttft_model_s:.2f}s) "
          f"[SCR kept spans {a_mobile.scr.spans}]")
    hit = ex.answer.lower() in a_mobile.prompt.lower()
    print(f"      planted answer survived SCR: {hit}")

    print("[3/4] loading reduced on-device sLM and generating...")
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=160)
    tok = HashTokenizer(cfg.vocab_size)
    prompt_ids = np.asarray(tok.encode(a_mobile.prompt)[-96:], np.int32)
    res = eng.generate([prompt_ids], max_new=12)[0]
    print(f"      generated {len(res.tokens)} tokens "
          f"(prefill {res.prefill_s:.2f}s): {tok.decode(res.tokens)!r}")

    print("[4/4] index update: inserting a fresh document...")
    newdoc = "The aurora777 was first described in 1859. It glows green."
    mobile.docs.append(newdoc)
    mobile.index.insert(len(mobile.docs) - 1, emb([newdoc])[0])
    a = mobile.answer("What is known about the aurora777?")
    print(f"      retrieved docs {a.doc_ids}; answer in context: "
          f"{'1859' in a.prompt}")
    print("done.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
