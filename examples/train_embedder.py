"""Train a ~100M-parameter embedding-model-family LM for a few hundred
steps on synthetic text (the gte-small architecture at ~its real size),
with checkpoint/restart and the step watchdog.

  PYTHONPATH=src python examples/train_embedder.py --steps 200
  (add --tiny for a fast smoke run)
"""
import argparse
import dataclasses
import sys

from repro.configs import get_config, get_reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (seconds instead of minutes)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_embedder_ckpt")
    args = ap.parse_args()

    from repro.launch import train as T

    if args.tiny:
        losses = T.run("gte_small", reduced=True, steps=args.steps,
                       batch=8, seq=64, ckpt_dir=args.ckpt_dir,
                       ckpt_interval=50)
    else:
        # full gte-small (~33M) is the paper's embedder; scale d_ff/layers
        # up to ~100M to satisfy the "~100M model" driver requirement
        import repro.launch.train as LT
        from repro.configs import gte_small
        cfg = dataclasses.replace(gte_small.CONFIG, name="gte-100m",
                                  num_layers=18, d_model=512, num_heads=8,
                                  d_ff=2048)
        print(f"[example] params ~= {cfg.param_count()/1e6:.0f}M")

        # route through the same driver with a custom config
        import repro.configs as configs_mod

        class _Shim:
            CONFIG = cfg
        sys.modules["repro.configs.gte_100m"] = _Shim
        losses = T.run("gte_100m", reduced=False, steps=args.steps,
                       batch=8, seq=128, ckpt_dir=args.ckpt_dir,
                       ckpt_interval=100)
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
