"""EcoVector dynamic updates (paper §3.3, Algorithms 1 & 2): build, insert
a batch, delete a batch, verify recall and graph invariants throughout.

  PYTHONPATH=src python examples/index_update.py
"""
import sys
import time

import numpy as np

from repro.core.ecovector import EcoVector


def recall(ev, X, Q, k=10, **kw):
    rec = []
    for q in Q:
        gt = set(np.argsort(np.sum((X - q) ** 2, 1))[:k].tolist())
        ids, _ = ev.search(q, k=k, **kw)
        rec.append(len(set(map(int, ids)) & gt) / k)
    return float(np.mean(rec))


def main():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(10, 64)) * 4
    X = np.concatenate([c + rng.normal(size=(200, 64))
                        for c in centers]).astype(np.float32)
    Q = X[:25] + 0.01 * rng.normal(size=(25, 64)).astype(np.float32)

    ev = EcoVector(64, n_clusters=20, M=8, ef_construction=40).build(X)
    print(f"built: {len(X)} vectors, {ev.n_clusters} clusters, "
          f"recall@10={recall(ev, X, Q, n_probe=5):.3f}")

    # --- insertions (Algorithm 1 inside the owning cluster's graph)
    new = centers[0] + rng.normal(size=(50, 64)).astype(np.float32)
    t0 = time.perf_counter()
    for i, v in enumerate(new):
        ev.insert(10_000 + i, v)
    print(f"inserted 50 in {(time.perf_counter()-t0)*1e3:.0f} ms "
          f"({ev.stats.disk_loads} cluster loads so far)")
    found = sum(1 for i, v in enumerate(new)
                if (10_000 + i) in set(map(int, ev.search(v, 3, 3)[0])))
    print(f"{found}/50 insertions retrievable")

    # --- deletions (Algorithm 2: unlink + recNeighbors reconnection)
    t0 = time.perf_counter()
    for i in range(50):
        ev.delete(10_000 + i)
    print(f"deleted 50 in {(time.perf_counter()-t0)*1e3:.0f} ms")
    leaked = sum(1 for v in new
                 if any(int(i) >= 10_000 for i in ev.search(v, 5, 3)[0]))
    print(f"deleted ids leaked into results: {leaked} (want 0)")
    print(f"post-update recall@10={recall(ev, X, Q, n_probe=5):.3f}")
    return 0 if leaked == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
