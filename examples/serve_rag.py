"""End-to-end serving driver: batched RAG requests through the scheduler
(dynamic length-bucketed batching, hedged re-dispatch on replica failure),
MobileRAG retrieval + SCR + real decode loop on reduced models.

  PYTHONPATH=src python examples/serve_rag.py --questions 8 --replicas 2 \
      [--inject-failure]
"""
import argparse
import sys
import time

import numpy as np

from repro.data.synthetic import make_qa_corpus
from repro.data.tokenizer import HashTokenizer
from repro.launch.serve import make_generator
from repro.serving.embedder import HashEmbedder
from repro.serving.rag import MobileRAG, accuracy
from repro.serving.scheduler import Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--questions", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--inject-failure", action="store_true",
                    help="first replica always fails: exercises hedging")
    args = ap.parse_args()

    corpus = make_qa_corpus("squad", n_docs=150,
                            n_questions=args.questions, seed=0)
    emb = HashEmbedder(dim=128)
    pipe = MobileRAG(corpus.docs, emb, top_k=3)
    gen, tok, eng = make_generator()

    def healthy(prompts, mx):
        return gen(prompts, mx)

    def broken(prompts, mx):
        raise RuntimeError("injected replica failure")

    replicas = [broken if (args.inject_failure and i == 0) else healthy
                for i in range(args.replicas)]
    sched = Scheduler(replicas, max_wave=4, max_strikes=1)

    t0 = time.perf_counter()
    answers = []
    for ex in corpus.examples[: args.questions]:
        a = pipe.answer(ex.question)
        answers.append(a)
        sched.submit(np.asarray(tok.encode(a.prompt)[-96:], np.int32),
                     args.max_new)
    completions = sched.run()
    wall = time.perf_counter() - t0

    acc = accuracy(pipe, corpus.examples, max_q=args.questions)
    print(f"{len(completions)} completions in {wall:.1f}s | "
          f"acc={acc:.2f} | "
          f"mean prompt tokens={np.mean([a.prompt_tokens for a in answers]):.0f} | "
          f"hedged={sum(c.hedged for c in completions)} | "
          f"replica health={[s.healthy for s in sched.state]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
