"""End-to-end serving example on the request-centric API: MobileRAG
retrieval + SCR condensation streamed through a RagSession (continuous
batching on the slot-paged engine), plus multi-replica slot admission
with failover through the SlotScheduler.

  PYTHONPATH=src python examples/serve_rag.py --questions 8 --replicas 2 \
      [--inject-failure]
"""
import argparse
import sys
import time

import numpy as np

from repro.data.synthetic import make_qa_corpus
from repro.serving.embedder import HashEmbedder
from repro.serving.rag import MobileRAG, accuracy
from repro.serving.scheduler import SlotScheduler


class BrokenEngine:
    """A replica whose step() always raises — exercises drain/failover."""

    def submit(self, prompt, max_new):
        return 0

    def available_slots(self):
        return 2

    def step(self):
        raise RuntimeError("injected replica failure")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--questions", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--inject-failure", action="store_true",
                    help="first replica always fails: exercises failover")
    args = ap.parse_args()

    corpus = make_qa_corpus("squad", n_docs=150,
                            n_questions=args.questions, seed=0)
    emb = HashEmbedder(dim=128)
    pipe = MobileRAG(corpus.docs, emb, top_k=3)
    questions = [e.question for e in corpus.examples[: args.questions]]

    # 1) the streaming session surface: submit/step/stream events
    t0 = time.perf_counter()
    n_tokens = 0
    answers = {}
    for ev in pipe.stream(questions, max_new=args.max_new):
        if ev.kind == "token":
            n_tokens += 1
        elif ev.kind == "done":
            answers[ev.req_id] = ev.payload
    wall = time.perf_counter() - t0
    acc = accuracy(pipe, corpus.examples, max_q=args.questions)
    print(f"[session] {len(answers)} answers, {n_tokens} streamed tokens "
          f"in {wall:.1f}s | acc={acc:.2f} | mean prompt tokens="
          f"{np.mean([a.prompt_tokens for a in answers.values()]):.0f}")

    # 2) multi-replica slot admission + failover
    slm = pipe._ensure_slm()
    engines = [slm.continuous(slots=2)]
    for _ in range(1, args.replicas):
        engines.append(engines[0].clone())
    if args.inject_failure:
        engines[0] = BrokenEngine()
    sched = SlotScheduler(engines, max_strikes=1)
    for a in answers.values():
        sched.submit(slm.encode_prompt(a.prompt, bucket=False),
                     args.max_new)
    completions = sched.run()
    print(f"[scheduler] {len(completions)} completions | "
          f"hedged={sum(c.hedged for c in completions)} | "
          f"replica health={[s.healthy for s in sched.state]} | "
          f"served={[s.served for s in sched.state]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
