#!/usr/bin/env python3
"""Crash-safety soak for the durable store (CI's storage chaos step).

Three sweeps, all deterministic (faults keyed on the fs-op index, the
same philosophy as `serving/faults.py` — see DESIGN.md §11/§12):

  1. **Crash sweep** — an in-process `CrashPlan` kills the EcoVector
     save / WAL-append / compaction workload at EVERY fs op in turn;
     after each crash the journal must reload to a complete index (or
     report no committed generation) with every acknowledged mutation
     present.
  2. **Kill -9 sweep** — the same workload in a subprocess with
     `REPRO_STORE_CRASH_AT` arming a hard `os._exit` at a sample of op
     indices: no atexit, no flush, exactly a power cut.
  3. **Fuzz sweep** — byte-flips and truncations at seeded offsets in
     committed generation files and live spill files; every mutation
     must be detected by the scrubber and tolerated by search
     (quarantine + degrade, never garbage results or a crash).

Exit 0 = all invariants held. Any violation prints the failing sweep
point and exits 1.

Usage: PYTHONPATH=src python tools/soak_store.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import warnings

import numpy as np


def _fail(msg: str) -> None:
    print(f"SOAK FAIL: {msg}")
    sys.exit(1)


def crash_sweep(step: int) -> int:
    from repro.core import store_faults
    from repro.core.ecovector import EcoVector

    rng = np.random.default_rng(0)
    X = rng.normal(size=(160, 12)).astype(np.float32)
    vecs = rng.normal(size=(8, 12)).astype(np.float32)

    def workload(root: str, acked: list) -> None:
        # `acked` grows as each op RETURNS — after a crash it holds
        # exactly the acknowledged prefix, the recovery ground truth
        ev = EcoVector(12, n_clusters=6, M=8, ef_construction=32).build(X)
        ev.save(root)
        for i, v in enumerate(vecs):
            if i % 4 == 3:
                ev.delete(10 ** 6 + i - 1)
                acked.append(("delete", 10 ** 6 + i - 1))
            else:
                ev.insert(10 ** 6 + i, v)
                acked.append(("insert", 10 ** 6 + i))
        ev.save()
        acked.append(("compacted", -1))

    ops = [("delete", 10 ** 6 + i - 1) if i % 4 == 3 else
           ("insert", 10 ** 6 + i) for i in range(len(vecs))]
    with tempfile.TemporaryDirectory() as tmp:
        total = store_faults.count_fs_ops(
            lambda: workload(os.path.join(tmp, "probe"), []))
    checked = 0
    for at in range(1, total + 1, step):
        with tempfile.TemporaryDirectory() as tmp:
            root = os.path.join(tmp, "j")
            acked: list = []
            with store_faults.CrashPlan(at):
                try:
                    workload(root, acked)
                except store_faults.InjectedCrash:
                    pass
            # exempt the one in-flight op (durable-but-unacked allowed)
            n_mut = len([a for a in acked if a[0] != "compacted"])
            inflight = ops[n_mut][1] if n_mut < len(ops) else None
            _verify(root, [a for a in acked if a[1] != inflight], at)
            checked += 1
    return checked


def _verify(root: str, acked: list, at: int, dim: int = 12) -> None:
    """Post-crash invariants: loadable (or nothing committed + nothing
    acked), zero acknowledged writes lost, search still answers."""
    from repro.core.ecovector import EcoVector

    try:
        ev = EcoVector.load(root)
    except FileNotFoundError:
        if acked:
            _fail(f"at={at}: journal empty but ops were acknowledged: "
                  f"{acked}")
        return
    expect = {}
    for op, vid in acked:
        if op != "compacted":
            expect[vid] = (op == "insert")
    for vid, present in expect.items():
        if (vid in ev.assign) != present:
            _fail(f"at={at}: acknowledged {'insert' if present else 'delete'}"
                  f" of {vid} lost after reload")
    rng = np.random.default_rng(1)
    for q in rng.normal(size=(4, dim)).astype(np.float32):
        ids, _ = ev.search(q, 5, n_probe=6)
        if len(ids) != 5:
            _fail(f"at={at}: degraded search returned {len(ids)}/5")


def kill9_sweep(points) -> int:
    checked = 0
    for at in points:
        with tempfile.TemporaryDirectory() as tmp:
            env = dict(os.environ, PYTHONPATH="src",
                       REPRO_STORE_CRASH_AT=str(at))
            p = subprocess.run(
                [sys.executable, "-m", "repro.core.store_faults",
                 "--root", tmp, "--stage", "compact"],
                env=env, capture_output=True, text=True, timeout=300)
            if p.returncode not in (0, 42):
                _fail(f"kill9 at={at}: driver rc={p.returncode}\n"
                      f"{p.stdout}{p.stderr}")
            acked = []
            ack_path = os.path.join(tmp, "acked.txt")
            if os.path.exists(ack_path):
                with open(ack_path) as f:
                    for line in f.read().splitlines():
                        parts = line.split()
                        acked.append((parts[0], int(parts[1])
                                      if len(parts) > 1 else -1))
            # exempt the single in-flight (never-acked) op
            ops = [("delete", 10 ** 6 + i - 1) if i % 3 == 2 else
                   ("insert", 10 ** 6 + i) for i in range(12)]
            n_mut = len([a for a in acked if a[0] != "compacted"])
            inflight = ops[n_mut][1] if n_mut < len(ops) else None
            acked = [a for a in acked if a[1] != inflight]
            _verify(os.path.join(tmp, "journal"),
                    [(op, vid) for op, vid in acked], at, dim=16)
            checked += 1
    return checked


def fuzz_sweep(n_mutations: int) -> int:
    from repro.core import store, store_faults
    from repro.core.ecovector import EcoVector

    rng = np.random.default_rng(2)
    X = rng.normal(size=(160, 12)).astype(np.float32)
    Q = X[rng.choice(len(X), 8)]
    checked = 0
    for trial in range(n_mutations):
        with tempfile.TemporaryDirectory() as tmp:
            root = os.path.join(tmp, "j")
            ev = EcoVector(12, n_clusters=6, M=8,
                           ef_construction=32).build(X)
            ev.device_pack()
            ev.save(root)
            # rot one live spill file at a seeded offset
            victim = int(rng.integers(ev.n_clusters))
            path = ev._path(victim)
            if rng.integers(2):
                store_faults.flip_byte(path, int(rng.integers(1 << 20)))
            else:
                store_faults.truncate_file(
                    path, int(rng.integers(os.path.getsize(path))))
            if all(r["ok"] for r in store.scrub_path(
                    os.path.dirname(path))):
                _fail(f"fuzz trial {trial}: scrub missed the mutation "
                      f"in {path}")
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                for q in Q:
                    ids, _ = ev.search(q, 5, n_probe=6)
                    if len(ids) != 5:
                        _fail(f"fuzz trial {trial}: search returned "
                              f"{len(ids)}/5 after corruption")
            if ev.stats.corrupt_reads:
                ev.rebuild_cluster(victim)
                if ev.stats.quarantined:
                    _fail(f"fuzz trial {trial}: rebuild left quarantine")
            # committed generation unaffected by live-file rot
            ev2 = EcoVector.load(root)
            for q in Q:
                if len(ev2.search(q, 5, n_probe=6)[0]) != 5:
                    _fail(f"fuzz trial {trial}: committed snapshot "
                          f"damaged by live-file mutation")
            checked += 1
    return checked


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description="storage crash-safety soak")
    p.add_argument("--quick", action="store_true",
                   help="CI-sized sweeps (sampled crash points)")
    args = p.parse_args(argv)
    step = 3 if args.quick else 1
    kill_points = ((10, 30, 52, 95) if args.quick
                   else tuple(range(5, 101, 5)))
    fuzz_trials = 6 if args.quick else 24

    n = crash_sweep(step)
    print(f"crash sweep: {n} injection points ok")
    n = kill9_sweep(kill_points)
    print(f"kill -9 sweep: {n} subprocess crashes recovered")
    n = fuzz_sweep(fuzz_trials)
    print(f"fuzz sweep: {n} corruption trials detected + tolerated")
    print("storage soak: all invariants held")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
