#!/usr/bin/env python3
"""Trace-invariant checker: the serving trace as a correctness oracle.

Validates a TraceSink record stream (in-process list, or a JSONL export
from `TraceSink.export_jsonl`) against the lifecycle contract documented
in docs/OBSERVABILITY.md:

  ordering    seq strictly increasing, ts monotone non-decreasing;
  lifecycle   per (comp, src, rid) the event DAG is respected —
              engine:  queued -> admitted -> prefill_chunk* ->
                       first_token -> token* -> done | shed | cancelled
              session: queued -> retrieved -> condensed ->
                       done | shed | failed
              sched:   queued -> placed/requeue/hedge* -> done | shed
              with nothing after a terminal and at most one terminal;
  spans       every B has a matching E on the same (comp, src, rid)
              key, never nested, none left open at end of a complete
              trace (prefill_chunk, decode_step, retrieve);
  terminals   in a complete trace every request that entered a
              component reaches exactly one terminal state there —
              chaos may delay requests, never strand them;
  pager       page_stats snapshots are self-consistent (free <= total,
              retained <= mapped_refs) and a drained engine's mapped
              references are exactly its prefix-cache retentions;
  chaos       an injected replica crash that had requests in flight is
              followed by engine "cancelled" records on that replica —
              faults surface as span chains, not silent drops;
  replica     a sched "recover" requires an earlier "drain"/"probe" of
              the same replica.

Ring-buffer truncation is handled: when the export's first seq is > 0
the oldest records were evicted, and rids whose beginning fell off the
buffer are exempt from "must start with queued" (their remaining chain
is still order-checked).

Deliberately stdlib-only and repo-import-free so it runs over any JSONL
export with a bare python3 (CI artifact checks, post-mortems).

Usage: python tools/trace_check.py trace.jsonl [--live]
  --live   the trace is a running snapshot: skip completeness checks
           (unterminated requests and open spans are not violations)

Exit 0 and a per-component summary when clean; exit 1 listing every
violation otherwise.
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Tuple

TERMINALS = {"engine": {"done", "shed", "cancelled"},
             "session": {"done", "shed", "failed"},
             "sched": {"done", "shed"}}
SPAN_NAMES = {("engine", "prefill_chunk"), ("engine", "decode_step"),
              ("session", "retrieve")}
# per-comp event -> prerequisites (any one suffices); "" = may be first
PREREQS = {
    "engine": {"queued": set(), "admitted": {"queued"},
               "prefill_chunk": {"admitted"},
               "first_token": {"admitted"}, "token": {"first_token"},
               "done": {"first_token"}, "shed": {"queued"},
               "cancelled": {"queued"}},
    "session": {"queued": set(), "degraded": {"queued"},
                "retrieved": {"queued"}, "condensed": {"retrieved"},
                "done": {"condensed"}, "failed": {"queued"},
                "shed": {"queued"}},
    "sched": {"queued": set(), "degraded": {"queued"},
              "placed": {"queued"}, "requeue": {"placed"},
              "hedge": {"placed"}, "done": {"placed"},
              "shed": {"queued"}},
}


def _norm(rec) -> dict:
    """Accept TraceRecord objects or plain dicts."""
    if isinstance(rec, dict):
        return rec
    return rec.to_dict()


def load_jsonl(path) -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class TraceChecker:
    """One pass over a record stream, accumulating violations."""

    def __init__(self, records: Iterable, *, complete: bool = True):
        self.records = [_norm(r) for r in records]
        self.complete = complete
        self.violations: List[str] = []
        # first record's seq > 0 => ring buffer evicted the stream head
        self.truncated = bool(self.records) and self.records[0]["seq"] > 0

    def _bad(self, rec: Optional[dict], msg: str) -> None:
        where = f"seq={rec['seq']} " if rec else ""
        self.violations.append(where + msg)

    # ---------------------------------------------------------- ordering

    def _check_ordering(self) -> None:
        last_seq, last_ts = -1, float("-inf")
        for r in self.records:
            if r["seq"] <= last_seq:
                self._bad(r, f"seq not increasing (prev {last_seq})")
            if r["ts"] < last_ts:
                self._bad(r, f"ts went backwards (prev {last_ts:.9f})")
            last_seq, last_ts = r["seq"], r["ts"]

    # --------------------------------------------------------- lifecycle

    def _check_lifecycle(self) -> None:
        # (comp, src, rid) -> set of event names seen; and terminal name
        seen: Dict[Tuple, set] = defaultdict(set)
        term: Dict[Tuple, str] = {}
        grandfathered: set = set()
        for r in self.records:
            comp, rid = r["comp"], r["rid"]
            if comp not in PREREQS or rid < 0:
                continue
            key = (comp, r["src"], rid)
            name = r["name"]
            if r.get("ph") == "E":
                continue                  # E ordering is the span check's
            if key in term:
                if name == "queued":
                    # rid recycled (engine `generate` pins rids to batch
                    # index): a fresh queued starts a new incarnation
                    del term[key]
                    seen[key] = set()
                else:
                    self._bad(r, f"{key}: '{name}' after terminal "
                                 f"'{term[key]}'")
                    continue
            if key not in seen and name != "queued":
                if self.truncated:
                    grandfathered.add(key)
                else:
                    self._bad(r, f"{key}: first event '{name}', "
                                 f"expected 'queued'")
            prereq = PREREQS[comp].get(name)
            if prereq is None:
                self._bad(r, f"{key}: unknown event '{name}'")
            elif prereq and not (prereq & seen[key]) \
                    and key not in grandfathered:
                self._bad(r, f"{key}: '{name}' before any of "
                             f"{sorted(prereq)}")
            if name == "queued" and "queued" in seen[key]:
                self._bad(r, f"{key}: duplicate 'queued'")
            seen[key].add(name)
            if name in TERMINALS[comp]:
                term[key] = name
        if self.complete:
            for key, names in seen.items():
                if key not in term:
                    self._bad(None, f"{key}: no terminal state "
                                    f"(saw {sorted(names)})")

    # -------------------------------------------------------- span pairs

    def _check_spans(self) -> None:
        open_b: Dict[Tuple, int] = {}
        for r in self.records:
            if (r["comp"], r["name"]) not in SPAN_NAMES:
                continue
            key = (r["comp"], r["src"], r["rid"], r["name"])
            if r.get("ph") == "B":
                if key in open_b:
                    self._bad(r, f"{key}: span re-opened (B at seq "
                                 f"{open_b[key]} still open)")
                open_b[key] = r["seq"]
            elif r.get("ph") == "E":
                if key not in open_b:
                    if not self.truncated:
                        self._bad(r, f"{key}: E without open B")
                else:
                    del open_b[key]
        if self.complete:
            for key, seq in open_b.items():
                self._bad(None, f"{key}: span opened at seq {seq} "
                                f"never closed")

    # ------------------------------------------------------------- pager

    def _check_pager(self) -> None:
        engine_seen: Dict[Tuple, set] = defaultdict(set)
        engine_term: set = set()
        last_stats: Dict[str, dict] = {}
        for r in self.records:
            if r["comp"] == "engine" and r["rid"] >= 0 \
                    and r.get("ph") != "E":
                key = (r["src"], r["rid"])
                if r["name"] == "queued":       # new incarnation
                    engine_term.discard(key)
                    engine_seen[key] = set()
                engine_seen[key].add(r["name"])
                if r["name"] in TERMINALS["engine"]:
                    engine_term.add(key)
            if r["comp"] != "pager":
                continue
            if r["name"] in ("prefix_hit", "cow_fork"):
                key = (r["src"], r["rid"])
                if "queued" not in engine_seen[key] \
                        and not self.truncated:
                    self._bad(r, f"pager '{r['name']}' for unknown "
                                 f"engine request {key}")
                if key in engine_term:
                    self._bad(r, f"pager '{r['name']}' after terminal "
                                 f"for {key}")
            elif r["name"] == "page_stats":
                a = r["attrs"]
                if a["free"] > a["total"]:
                    self._bad(r, f"page_stats: free {a['free']} > "
                                 f"total {a['total']}")
                if a["retained"] > a["mapped_refs"]:
                    self._bad(r, f"page_stats: retained {a['retained']}"
                                 f" > mapped_refs {a['mapped_refs']}")
                last_stats[r["src"]] = a
        for src, a in last_stats.items():
            if a.get("inflight", 0) == 0 \
                    and a["mapped_refs"] != a["retained"]:
                self._bad(None, f"src={src}: drained engine holds "
                                f"{a['mapped_refs']} refs but only "
                                f"{a['retained']} retentions — leak")

    # ------------------------------------------------------------- chaos

    def _check_chaos(self) -> None:
        for i, r in enumerate(self.records):
            if r["comp"] != "chaos" or r["name"] != "injected":
                continue
            a = r["attrs"]
            if "kind" not in a:
                self._bad(r, "chaos record without fault kind")
                continue
            if a["kind"] == "replica_crash" and a.get("inflight", 0) > 0:
                # a crash loses in-flight state: the wrapped engine must
                # surface it as cancelled chains, never a silent drop
                ok = any(x["comp"] == "engine"
                         and x["name"] == "cancelled"
                         and x["src"] == r["src"]
                         for x in self.records[i + 1:])
                if not ok:
                    self._bad(r, f"crash on src={r['src']} with "
                                 f"{a['inflight']} in flight but no "
                                 f"'cancelled' records follow")

    def _check_replica(self) -> None:
        drained: set = set()
        for r in self.records:
            if r["comp"] != "sched" or r["rid"] >= 0:
                continue
            rep = r["attrs"].get("replica")
            if r["name"] in ("drain", "probe"):
                drained.add((r["src"], rep))
            elif r["name"] == "recover" \
                    and (r["src"], rep) not in drained \
                    and not self.truncated:
                self._bad(r, f"replica {rep} recovered without an "
                             f"earlier drain/probe")

    # --------------------------------------------------------------- run

    def run(self) -> List[str]:
        self._check_ordering()
        self._check_lifecycle()
        self._check_spans()
        self._check_pager()
        self._check_chaos()
        self._check_replica()
        return self.violations

    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for r in self.records:
            out[r["comp"]] += 1
        out["records"] = len(self.records)
        out["violations"] = len(self.violations)
        return dict(out)


def check_records(records: Iterable, *, complete: bool = True) -> List[str]:
    """Violations in a record stream (TraceRecords or dicts); [] = clean."""
    return TraceChecker(records, complete=complete).run()


def check_jsonl(path, *, complete: bool = True) -> List[str]:
    """Violations in a `TraceSink.export_jsonl` file; [] = clean."""
    return check_records(load_jsonl(path), complete=complete)


def last_page_stats(records: Iterable, src: Optional[str] = None) -> dict:
    """The final page_stats snapshot (for reconciling an export against
    a live engine's `page_stats()`)."""
    out: dict = {}
    for r in (_norm(x) for x in records):
        if r["comp"] == "pager" and r["name"] == "page_stats" \
                and (src is None or r["src"] == src):
            out = r["attrs"]
    return out


def main(argv: List[str]) -> int:
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0
    live = "--live" in argv
    path = [a for a in argv if not a.startswith("--")][0]
    checker = TraceChecker(load_jsonl(path), complete=not live)
    violations = checker.run()
    s = checker.summary()
    if violations:
        for v in violations:
            print(f"VIOLATION: {v}")
        print(f"{len(violations)} violation(s) in {s['records']} records")
        return 1
    comps = ", ".join(f"{k}={v}" for k, v in sorted(s.items())
                      if k not in ("records", "violations"))
    print(f"trace OK: {s['records']} records ({comps})"
          + (" [truncated head]" if checker.truncated else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
