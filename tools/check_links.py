#!/usr/bin/env python3
"""Dead-link checker for the repo's markdown docs (CI `docs` job).

Scans every tracked *.md file for markdown links `[text](target)` and
bare `file:line` anchors in backticks, and fails (exit 1) when a
relative target does not exist on disk. Rules:

  - http(s)/mailto targets are skipped (no network in CI);
  - pure fragment targets (`#section`) are skipped;
  - `path#fragment` is checked for the file part only;
  - `path:123` / `path:12-34` file:line anchors resolve to the file;
  - targets resolve relative to the md file's directory first, then the
    repo root, then `src/repro/` (the docs' conventional shorthand for
    module paths, e.g. `core/scr.py` or `serving/engine.py:87`).

Usage: python tools/check_links.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ANCHOR_RE = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|yml|yaml|txt)"
                       r"(?::\d+(?:-\d+)?)?)`")
SKIP_DIRS = {".git", ".github", "__pycache__", ".venv", "node_modules",
             ".claude"}


def _md_files(root: Path):
    for p in sorted(root.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


def _strip(target: str) -> str | None:
    """Normalize a link target to a filesystem path, or None to skip."""
    if target.startswith(("http://", "https://", "mailto:")):
        return None
    target = target.split("#", 1)[0]
    if not target:
        return None
    # file:line / file:line-line anchors
    m = re.match(r"^(.*?):\d+(?:-\d+)?$", target)
    if m:
        target = m.group(1)
    return target or None


def _exists(root: Path, base: Path, rel: str) -> bool:
    rel = rel.strip()
    if rel.startswith("/"):          # repo-absolute
        return (root / rel.lstrip("/")).exists()
    return ((base / rel).exists() or (root / rel).exists()
            or (root / "src" / "repro" / rel).exists())


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(
        __file__).resolve().parent.parent
    dead: list[str] = []
    n_links = 0
    for md in _md_files(root):
        text = md.read_text(encoding="utf-8", errors="replace")
        targets = [t for t in LINK_RE.findall(text)]
        targets += [t for t in ANCHOR_RE.findall(text) if "/" in t]
        for raw in targets:
            rel = _strip(raw)
            if rel is None:
                continue
            n_links += 1
            if not _exists(root, md.parent, rel):
                dead.append(f"{md.relative_to(root)}: ({raw})")
    if dead:
        print(f"[check_links] {len(dead)} dead link(s) "
              f"(of {n_links} checked):")
        for d in dead:
            print(f"  {d}")
        return 1
    print(f"[check_links] ok: {n_links} intra-repo links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
