#!/usr/bin/env python3
"""Offline index scrubber: walk a durable-store directory and verify
every checksum before the data is needed in anger.

Handles both layouts `core/store.py` produces:

  * a Journal root (``gen_XXXXXXXX/`` snapshots + ``wal_*.log``) — every
    committed generation's files are checked against the manifest CRCs,
    segment files are additionally deep-validated record by record, and
    the active WAL is replayed for torn/corrupt frames;
  * a plain spill directory of segment files (an index's live
    ``storage_dir``).

Tiered indexes (DESIGN.md §14) get two extra passes: cold-pack payload
spans + per-cluster CRCs (``cold_manifest.seg`` / ``cold_payload.raw``,
both in journal generations and live spill dirs), and tier-assignment
consistency for the latest committed generation (hot ∩ cold = ∅,
hot ∪ cold ∪ quarantined covers every cluster).

Exit status: 0 when everything checks out, 1 when corruption was found
(CI treats nonzero as failure). ``--quarantine`` moves corrupt plain
files aside (``<name>.quarantined``) so the owning index rebuilds them
on next load instead of tripping at query time; committed generation
files are never moved (the manifest records them — the right fix is a
fresh save()).

Usage:
  PYTHONPATH=src python tools/scrub_index.py PATH [PATH ...]
      [--shallow] [--quarantine] [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="verify checksums of durable retrieval state")
    p.add_argument("paths", nargs="+",
                   help="journal roots or spill directories to scrub")
    p.add_argument("--shallow", action="store_true",
                   help="manifest CRCs only; skip per-record segment "
                        "validation")
    p.add_argument("--quarantine", action="store_true",
                   help="move corrupt plain spill files aside")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable report on stdout")
    args = p.parse_args(argv)

    from repro.core import store, tiered

    reports = []
    for path in args.paths:
        for rep in store.scrub_path(path, deep=not args.shallow):
            rep = dict(rep, root=path)
            plain = (os.path.dirname(os.path.abspath(rep["item"]))
                     == os.path.abspath(path))
            if (not rep["ok"] and args.quarantine and plain
                    and not rep["item"].endswith(".log")):
                rep["quarantined_to"] = store.quarantine_file(rep["item"])
            reports.append(rep)
        if not args.shallow and os.path.isdir(path):
            names = os.listdir(path)
            if any(n.startswith(("gen_", "wal_")) for n in names):
                extra = tiered.scrub_tier_state(path)
            else:
                extra = tiered.scrub_cold_pack(path)
            reports.extend(dict(r, root=path) for r in extra)

    bad = [r for r in reports if not r["ok"]]
    if args.as_json:
        json.dump({"checked": len(reports), "corrupt": len(bad),
                   "reports": reports}, sys.stdout, indent=1)
        sys.stdout.write("\n")
    else:
        for r in reports:
            mark = "ok  " if r["ok"] else "BAD "
            extra = f"  ({r['error']})" if not r["ok"] else ""
            print(f"{mark}{r['item']}{extra}")
        print(f"scrub: {len(reports)} items, {len(bad)} corrupt")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
