#!/usr/bin/env python3
"""Roofline-regression diff over dryrun summary.json artifacts.

Compares two `launch/dryrun.py` campaign summaries (the nightly CI keeps
the previous run's summary.json as an artifact) cell by cell and flags:

  - a cell that compiled before and errors now (hard regression);
  - a dominant-term flip (e.g. compute-bound -> collective-bound);
  - a roofline time term (t_compute/t_memory/t_collective) that grew by
    more than `--tol` (relative, default 10%);
  - peak device memory that grew past the HBM fit line.

New cells and improvements are reported informationally. With no
baseline (first nightly) the diff degrades to a summary print and exit
0, so the workflow bootstraps itself.

Deliberately stdlib-only (no repo imports — `launch.dryrun` forces a
512-device XLA host platform on import, which must never leak into the
checker process).

Usage:
  python tools/roofline_diff.py NEW_SUMMARY [BASELINE_SUMMARY]
      [--tol 0.10] [--out DIFF.md]

Exit 1 when any hard regression is found, else 0.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

TERMS = ("t_compute_s", "t_memory_s", "t_collective_s")


def _load(path) -> dict:
    return json.loads(Path(path).read_text())["cells"]


def diff_cells(new: dict, base: dict, tol: float):
    """(regressions, notes) between two summary cell maps."""
    regressions, notes = [], []
    for tag in sorted(set(new) | set(base)):
        n, b = new.get(tag), base.get(tag)
        if b is None:
            notes.append(f"NEW {tag}: {n['status']}")
            continue
        if n is None:
            regressions.append(f"GONE {tag}: present in baseline, "
                               f"missing from this run")
            continue
        if b["status"] == "ok" and n["status"] != "ok":
            regressions.append(f"BROKE {tag}: ok -> {n['status']}")
            continue
        if n["status"] != "ok":
            notes.append(f"STILL-FAILING {tag}")
            continue
        if b["status"] != "ok":
            notes.append(f"FIXED {tag}")
            continue
        if n.get("dominant") != b.get("dominant"):
            regressions.append(
                f"DOMINANT-FLIP {tag}: {b.get('dominant')} -> "
                f"{n.get('dominant')}")
        for term in TERMS:
            nv, bv = n.get(term), b.get(term)
            if nv is None or bv is None or bv <= 0:
                continue
            rel = (nv - bv) / bv
            if rel > tol:
                regressions.append(
                    f"SLOWER {tag}: {term} {bv:.4g}s -> {nv:.4g}s "
                    f"(+{rel:.0%} > {tol:.0%})")
            elif rel < -tol:
                notes.append(f"faster {tag}: {term} {bv:.4g}s -> "
                             f"{nv:.4g}s ({rel:.0%})")
        if b.get("fits_hbm_16g") and n.get("fits_hbm_16g") is False:
            regressions.append(
                f"OOM {tag}: peak "
                f"{n.get('peak_bytes_per_device', 0) / 1e9:.2f} GB no "
                f"longer fits 16 GB HBM")
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="this run's summary.json")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="previous run's summary.json (omit to bootstrap)")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="relative slowdown tolerance per roofline term")
    ap.add_argument("--out", default=None,
                    help="also write the diff as markdown")
    args = ap.parse_args(argv)

    new = _load(args.new)
    lines = [f"# Roofline diff ({len(new)} cells)"]
    rc = 0
    if args.baseline is None or not Path(args.baseline).exists():
        lines.append("no baseline summary: bootstrap run, nothing to "
                     "diff against")
        ok = sum(1 for c in new.values() if c["status"] == "ok")
        lines.append(f"this run: {ok}/{len(new)} cells ok")
    else:
        regressions, notes = diff_cells(new, _load(args.baseline),
                                        args.tol)
        if regressions:
            lines.append(f"## {len(regressions)} regression(s)")
            lines += [f"- {r}" for r in regressions]
            rc = 1
        else:
            lines.append("no regressions")
        if notes:
            lines.append(f"## {len(notes)} note(s)")
            lines += [f"- {n}" for n in notes]
    text = "\n".join(lines)
    print(text)
    if args.out:
        Path(args.out).write_text(text + "\n")
    return rc


if __name__ == "__main__":
    sys.exit(main())
