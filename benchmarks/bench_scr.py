"""Table 4 + Figure 12: SCR token reduction & accuracy across window /
overlap settings, vs the compressor baseline and Naive small-chunks —
plus real per-query SCR post-retrieval latency, before/after the
corpus-resident window index (per-query re-embed vs `scr_select` over
precomputed window blocks, DESIGN.md §6–§7)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.scr import SCRConfig, split_sentences
from repro.data.synthetic import make_qa_corpus
from repro.serving.embedder import HashEmbedder
from repro.serving.rag import MobileRAG, NaiveRAG, accuracy

STYLES = {"SQuAD-like": "squad", "HotpotQA-like": "hotpot",
          "TriviaQA-like": "trivia"}


def _compressor(docs, ratio=0.4):
    """BERTSUM stand-in: lead-k extractive summary (keeps first k
    sentences) — the 'discards too much context' baseline."""
    out = []
    for d in docs:
        s = split_sentences(d)
        out.append(" ".join(s[: max(1, int(len(s) * ratio))]))
    return out


def _answers(pipe, questions):
    """Warm the jit/dispatch caches, then answer every question once."""
    pipe.answer(questions[0])
    return [pipe.answer(q) for q in questions]


def _latency(label, corpus, mobile, questions):
    """Per-query SCR post-retrieval latency, before/after the window
    index: `legacy` re-splits/re-windows/re-embeds every window of every
    retrieved doc per query; `mobile` consumes the corpus-resident index
    (single-query and fully batched `answer_batch` serving paths). Both
    must select identical spans in identical order."""
    legacy = MobileRAG(corpus.docs, mobile.embed, top_k=3,
                       scr=mobile.scr_cfg, use_window_index=False)
    ans_l = _answers(legacy, questions)
    ans_w = _answers(mobile, questions)
    mismatch = sum(1 for a, b in zip(ans_l, ans_w)
                   if a.scr.spans != b.scr.spans
                   or a.scr.order != b.scr.order)
    t_leg = float(np.mean([a.post_s for a in ans_l]))
    t_one = float(np.mean([a.post_s for a in ans_w]))
    mobile.answer_batch(questions)                 # warm at batch shape
    t_bat = float(np.mean([a.post_s
                           for a in mobile.answer_batch(questions)]))
    emit(f"scr.latency.{label}", t_bat * 1e6,
         f"legacy_reembed_ms={t_leg * 1e3:.3f};"
         f"window_index_ms={t_one * 1e3:.3f};"
         f"window_index_batched_ms={t_bat * 1e3:.3f};"
         f"speedup={t_leg / max(t_one, 1e-12):.1f}x;"
         f"speedup_batched={t_leg / max(t_bat, 1e-12):.1f}x;"
         f"parity={'ok' if mismatch == 0 else f'{mismatch}mism'};"
         f"index_build_ms={mobile.scr_build_s * 1e3:.1f}")


def run(mode="quick"):
    nq = 25 if mode == "quick" else 100
    for label, style in STYLES.items():
        corpus = make_qa_corpus(style, n_docs=150, n_questions=nq, seed=0)
        emb = HashEmbedder(dim=128).fit(corpus.docs)
        questions = [e.question for e in corpus.examples[:nq]]

        naive = NaiveRAG(corpus.docs, emb, top_k=3)
        acc_n = accuracy(naive, corpus.examples, max_q=nq)
        tok_n = np.mean([a.prompt_tokens for a in _answers(naive, questions)])

        # Table 4: paper's parameters (window 3, overlap 2, extension 1)
        mobile = MobileRAG(corpus.docs, emb, top_k=3,
                           scr=SCRConfig(3, 2, 1))
        acc_m = accuracy(mobile, corpus.examples, max_q=nq)
        ans_m = _answers(mobile, questions)
        tok_m = np.mean([a.prompt_tokens for a in ans_m])
        emit(f"scr.table4.{label}",
             float(np.mean([a.post_s for a in ans_m])) * 1e6,
             f"before={tok_n:.0f};after={tok_m:.0f};"
             f"reduction={100*(1-tok_m/tok_n):.0f}%;"
             f"acc_naive={acc_n:.2f};acc_scr={acc_m:.2f}")

        # before/after: per-query re-embed vs corpus-resident window index
        _latency(label, corpus, mobile, questions)

        # Fig 12 sweep: window/overlap settings
        for w, o in ((1, 0), (3, 1), (3, 2), (5, 2)):
            m = MobileRAG(corpus.docs, emb, top_k=3, scr=SCRConfig(w, o, 1))
            acc = accuracy(m, corpus.examples, max_q=nq)
            ans = _answers(m, questions)
            emit(f"scr.sweep.{label}.w{w}o{o}",
                 float(np.mean([a.post_s for a in ans])) * 1e6,
                 f"acc={acc:.2f};"
                 f"tokens={np.mean([a.prompt_tokens for a in ans]):.0f}")

        # compressor baseline: same retrieval, lead-k compression
        comp_docs = _compressor(corpus.docs)
        comp = NaiveRAG(comp_docs, emb, top_k=3)
        acc_c = accuracy(comp, corpus.examples, max_q=nq)
        tok_c = np.mean([a.prompt_tokens for a in _answers(comp, questions)])
        emit(f"scr.compressor.{label}", 0.0,
             f"acc={acc_c:.2f};tokens={tok_c:.0f}")

        # Naive-RAG with small chunks from the outset (chunk ratio 0.6)
        small_docs = []
        for d in corpus.docs:
            s = split_sentences(d)
            small_docs.append(" ".join(s[: max(1, int(len(s) * 0.6))]))
        small = NaiveRAG(small_docs, emb, top_k=3)
        acc_s = accuracy(small, corpus.examples, max_q=nq)
        emit(f"scr.small_chunks.{label}", 0.0, f"acc={acc_s:.2f}")


if __name__ == "__main__":
    run()
