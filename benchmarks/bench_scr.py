"""Table 4 + Figure 12: SCR token reduction & accuracy across window /
overlap settings, vs the compressor baseline and Naive small-chunks."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.scr import SCRConfig, apply_scr, split_sentences
from repro.data.synthetic import make_qa_corpus
from repro.serving.embedder import HashEmbedder
from repro.serving.rag import MobileRAG, NaiveRAG, accuracy

STYLES = {"SQuAD-like": "squad", "HotpotQA-like": "hotpot",
          "TriviaQA-like": "trivia"}


def _compressor(docs, ratio=0.4):
    """BERTSUM stand-in: lead-k extractive summary (keeps first k
    sentences) — the 'discards too much context' baseline."""
    out = []
    for d in docs:
        s = split_sentences(d)
        out.append(" ".join(s[: max(1, int(len(s) * ratio))]))
    return out


def run(mode="quick"):
    nq = 25 if mode == "quick" else 100
    for label, style in STYLES.items():
        corpus = make_qa_corpus(style, n_docs=150, n_questions=nq, seed=0)
        emb = HashEmbedder(dim=128).fit(corpus.docs)

        naive = NaiveRAG(corpus.docs, emb, top_k=3)
        acc_n = accuracy(naive, corpus.examples, max_q=nq)
        tok_n = np.mean([naive.answer(e.question).prompt_tokens
                         for e in corpus.examples[:nq]])

        # Table 4: paper's parameters (window 3, overlap 2, extension 1)
        mobile = MobileRAG(corpus.docs, emb, top_k=3,
                           scr=SCRConfig(3, 2, 1))
        acc_m = accuracy(mobile, corpus.examples, max_q=nq)
        tok_m = np.mean([mobile.answer(e.question).prompt_tokens
                         for e in corpus.examples[:nq]])
        emit(f"scr.table4.{label}", 0.0,
             f"before={tok_n:.0f};after={tok_m:.0f};"
             f"reduction={100*(1-tok_m/tok_n):.0f}%;"
             f"acc_naive={acc_n:.2f};acc_scr={acc_m:.2f}")

        # Fig 12 sweep: window/overlap settings
        for w, o in ((1, 0), (3, 1), (3, 2), (5, 2)):
            m = MobileRAG(corpus.docs, emb, top_k=3, scr=SCRConfig(w, o, 1))
            acc = accuracy(m, corpus.examples, max_q=nq)
            tok = np.mean([m.answer(e.question).prompt_tokens
                           for e in corpus.examples[:nq]])
            emit(f"scr.sweep.{label}.w{w}o{o}", 0.0,
                 f"acc={acc:.2f};tokens={tok:.0f}")

        # compressor baseline: same retrieval, lead-k compression
        comp_docs = _compressor(corpus.docs)
        comp = NaiveRAG(comp_docs, emb, top_k=3)
        acc_c = accuracy(comp, corpus.examples, max_q=nq)
        tok_c = np.mean([comp.answer(e.question).prompt_tokens
                         for e in corpus.examples[:nq]])
        emit(f"scr.compressor.{label}", 0.0,
             f"acc={acc_c:.2f};tokens={tok_c:.0f}")

        # Naive-RAG with small chunks from the outset (chunk ratio 0.6)
        small_docs = []
        for d in corpus.docs:
            s = split_sentences(d)
            small_docs.append(" ".join(s[: max(1, int(len(s) * 0.6))]))
        small = NaiveRAG(small_docs, emb, top_k=3)
        acc_s = accuracy(small, corpus.examples, max_q=nq)
        emit(f"scr.small_chunks.{label}", 0.0, f"acc={acc_s:.2f}")


if __name__ == "__main__":
    run()
