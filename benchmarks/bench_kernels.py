"""Kernel micro-benchmarks (interpret-mode on CPU: correctness-grade
timing only; real perf numbers come from the dry-run roofline terms)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(mode="quick"):
    k0 = jax.random.PRNGKey(0)
    B, d, NC, CAP, P = 8, 128, 64, 256, 8
    q = jax.random.normal(k0, (B, d))
    data = jax.random.normal(k0, (NC, CAP, d))
    lens = jnp.full((NC,), CAP, jnp.int32)
    probes = jnp.tile(jnp.arange(P, dtype=jnp.int32)[None], (B, 1))
    t_ref = _time(ops.ecoscan, q, data, lens, probes, use_pallas=False)
    t_pal = _time(ops.ecoscan, q, data, lens, probes, use_pallas=True)
    emit("kernel.ecoscan.ref", t_ref * 1e6, f"B={B};P={P};CAP={CAP}")
    emit("kernel.ecoscan.pallas_interpret", t_pal * 1e6, "correctness-mode")

    # before/after: the seed kernel shape (one probe per grid step, O(k*M)
    # fori_loop argmin merge) vs the tiled sort-based merge. Interpret-mode
    # numbers are correctness-grade; on TPU the argmin loop serializes k
    # full-vector reductions per probe while the sort is one lane-parallel
    # sort network per tile of probes.
    from repro.kernels.ecoscan import ecoscan as _eco
    t_argmin = _time(_eco, q, data, lens, probes, merge="argmin",
                     probe_tile=1)
    t_sort = _time(_eco, q, data, lens, probes, merge="sort")
    emit("kernel.ecoscan.merge_argmin", t_argmin * 1e6,
         "before: per-probe fori_loop argmin merge")
    emit("kernel.ecoscan.merge_sort", t_sort * 1e6,
         f"after: tiled sort_key_val merge;"
         f"speedup={t_argmin / t_sort:.2f}x")

    # fused on-device route->scan vs host-routed two-step
    cent = jax.random.normal(jax.random.PRNGKey(7), (NC, d))

    def two_step(q, cent, data, lens, n_probe=P, k=10):
        qn = jax.device_get(q)
        cn = jax.device_get(cent)
        d2 = ((qn ** 2).sum(1)[:, None] - 2 * qn @ cn.T
              + (cn ** 2).sum(1)[None, :])
        import numpy as _np
        pr = jnp.asarray(_np.argsort(d2, 1)[:, :n_probe].astype(_np.int32))
        return ops.ecoscan(q, data, lens, pr, k=k)

    t_two = _time(two_step, q, cent, data, lens)
    t_fused = _time(ops.route_and_scan, q, cent, data, lens, n_probe=P)
    emit("kernel.route_scan.two_step", t_two * 1e6,
         "before: host argsort routing + scan")
    emit("kernel.route_scan.fused", t_fused * 1e6,
         f"after: one jitted route+scan;speedup={t_two / t_fused:.2f}x")

    x = jax.random.normal(k0, (4096, 128))
    c = jax.random.normal(k0, (64, 128))
    emit("kernel.kmeans_assign.ref",
         _time(ops.kmeans_assign, x, c, use_pallas=False) * 1e6, "N=4096")
    emit("kernel.kmeans_assign.pallas_interpret",
         _time(ops.kmeans_assign, x, c, use_pallas=True) * 1e6, "N=4096")

    w = jax.random.normal(k0, (4, 512, 384))
    qq = jax.random.normal(k0, (4, 384))
    emit("kernel.scr_score.ref",
         _time(ops.scr_score, w, qq, use_pallas=False) * 1e6, "NW=512")
    emit("kernel.scr_score.pallas_interpret",
         _time(ops.scr_score, w, qq, use_pallas=True) * 1e6, "NW=512")

    # fused SCR select: score + per-doc segment-argmax in one call over
    # the corpus-resident window pack (DESIGN.md §7)
    ND, CAPW, K = 256, 16, 8
    wdata = jax.random.normal(k0, (ND, CAPW, 384))
    wlens = jnp.full((ND,), CAPW, jnp.int32)
    dids = jax.random.randint(jax.random.PRNGKey(9), (4, K), 0, ND,
                              jnp.int32)
    emit("kernel.scr_select.ref",
         _time(ops.scr_select, qq, wdata, wlens, dids,
               use_pallas=False) * 1e6, f"ND={ND};CAPW={CAPW};K={K}")
    emit("kernel.scr_select.pallas_interpret",
         _time(ops.scr_select, qq, wdata, wlens, dids,
               use_pallas=True) * 1e6, f"ND={ND};CAPW={CAPW};K={K}")


if __name__ == "__main__":
    run()
