"""Kernel micro-benchmarks (interpret-mode on CPU: correctness-grade
timing only; real perf numbers come from the dry-run roofline terms)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(mode="quick"):
    k0 = jax.random.PRNGKey(0)
    B, d, NC, CAP, P = 8, 128, 64, 256, 8
    q = jax.random.normal(k0, (B, d))
    data = jax.random.normal(k0, (NC, CAP, d))
    lens = jnp.full((NC,), CAP, jnp.int32)
    probes = jnp.tile(jnp.arange(P, dtype=jnp.int32)[None], (B, 1))
    t_ref = _time(ops.ecoscan, q, data, lens, probes, use_pallas=False)
    t_pal = _time(ops.ecoscan, q, data, lens, probes, use_pallas=True)
    emit("kernel.ecoscan.ref", t_ref * 1e6, f"B={B};P={P};CAP={CAP}")
    emit("kernel.ecoscan.pallas_interpret", t_pal * 1e6, "correctness-mode")

    x = jax.random.normal(k0, (4096, 128))
    c = jax.random.normal(k0, (64, 128))
    emit("kernel.kmeans_assign.ref",
         _time(ops.kmeans_assign, x, c, use_pallas=False) * 1e6, "N=4096")
    emit("kernel.kmeans_assign.pallas_interpret",
         _time(ops.kmeans_assign, x, c, use_pallas=True) * 1e6, "N=4096")

    w = jax.random.normal(k0, (4, 512, 384))
    qq = jax.random.normal(k0, (4, 384))
    emit("kernel.scr_score.ref",
         _time(ops.scr_score, w, qq, use_pallas=False) * 1e6, "NW=512")
    emit("kernel.scr_score.pallas_interpret",
         _time(ops.scr_score, w, qq, use_pallas=True) * 1e6, "NW=512")


if __name__ == "__main__":
    run()
