"""Figure 11: EcoVector memory / latency / power across centroid counts."""
from __future__ import annotations

from benchmarks.common import datasets, emit, ground_truth, recall_and_qps
from repro.core.analytical import HW, energy_mj, memory_bytes
from repro.core.ecovector import EcoVector


def run(mode="quick"):
    for dset, (X, Q) in datasets(mode).items():
        gt = ground_truth(X, Q)
        for nc in (16, 32, 64, 128):
            if nc * 4 > len(X):
                continue
            idx = EcoVector(X.shape[1], n_clusters=nc).build(X)
            idx.stats.distance_ops = 0
            idx.stats.disk_bytes = 0
            idx.stats.disk_loads = 0
            rec, qps, per = recall_and_qps(idx, Q, gt, n_probe=8,
                                           ef_search=32)
            nq = len(Q)
            t_s = per * 1e3  # measured ms as CPU proxy
            t_d = idx.stats.disk_time_s / nq * 1e3
            e = energy_mj(t_s - t_d, t_d)
            model = memory_bytes("EcoVector", N=len(X), d=X.shape[1], Nc=nc)
            emit(f"centroids.{dset}.Nc={nc}", per * 1e6,
                 f"recall={rec:.3f};ram_MB={idx.ram_bytes()/1e6:.3f};"
                 f"model_MB={model/1e6:.3f};energy_mJ={e:.4f}")


if __name__ == "__main__":
    run()
