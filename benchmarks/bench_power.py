"""Figure 9 / §3.4.3: per-search energy via the paper's power model,
driven by *measured* distance-op and disk-byte counters from real searches
(CPU current for t_s, disk current for t_d)."""
from __future__ import annotations

from benchmarks.common import build, datasets, emit
from repro.core.analytical import HW, energy_mj
from repro.core.baselines import ALL_BASELINES


def run(mode="quick"):
    for dset, (X, Q) in datasets(mode).items():
        d = X.shape[1]
        for name in ALL_BASELINES:
            idx, _ = build(name, X)
            idx.stats.reset() if hasattr(idx.stats, "reset") else None
            idx.stats.distance_ops = 0
            idx.stats.disk_loads = 0
            idx.stats.disk_bytes = 0
            for q in Q:
                idx.search(q, k=10, n_probe=8)
            nq = len(Q)
            t_s = (idx.stats.distance_ops / nq) * HW.t_op_ms(d)
            dbytes = idx.stats.disk_bytes / nq
            nseek = idx.stats.disk_loads / nq
            t_d = nseek * (HW.t_seek_ms + HW.t_cmd_ms
                           + dbytes / max(nseek, 1e-9)
                           * HW.t_transfer_ms_per_byte) if nseek else 0.0
            e = energy_mj(t_s, t_d)
            emit(f"power.{dset}.{name}", (t_s + t_d) * 1e3,
                 f"energy_mJ={e:.4f};t_s_ms={t_s:.3f};t_d_ms={t_d:.3f}")


if __name__ == "__main__":
    run()
