"""Serving latency under a ragged request stream: wave vs continuous.

Workload: requests with ragged prompt lengths and ragged generation
budgets arriving as a Poisson process (rate auto-calibrated to ~80% of
the engine's measured decode capacity, so the queue is loaded but not
saturated on any host speed).

Baseline ("wave"): the legacy Engine surface — up to `slots` queued
requests form a fixed-shape wave (prompts left-padded to one bucket
length, exactly what the bucketed sLM path did) and the wave blocks until
its slowest member finishes; arrivals during a wave wait for the next one.

Continuous: the slot-paged ContinuousEngine — a queued prompt is admitted
into any slot the step after its occupant hits EOS, its prefill chunked
into the running decode loop, every request stops at its own budget.

Emits p50/p95 request latency (submit -> last token) for both, plus slot
utilisation for the continuous engine.

A second section exercises the post-PR-5 coverage of the paged path:
continuous-only rows for a sliding-window (ring-page) config, an int8-KV
config, an MoE config and a sampled (non-greedy, per-slot PRNG streams)
run — quick mode keeps one swa + one sampled row for the CI smoke.

A third section sweeps SHARED-PREFIX RATIO (0/50/90% of the prompt in
common across requests; quick mode keeps the 0/90 endpoints) and reports
p50 TTFT per share: the block-table pager maps cached prefix pages
instead of recomputing them, so TTFT must drop as the share rises —
`--prefix` runs just this sweep (the CI prefix smoke).

A fourth section measures GOODPUT UNDER CHAOS: 3 SlotScheduler replicas
wrapped in a seeded FaultPlan (replica crashes, slot stalls, slow steps —
serving/faults.py), per-request deadlines, and a
completed-within-deadline / submitted column beside the latency
percentiles. `python -m benchmarks.bench_serving --chaos` runs just that
section (the CI chaos smoke).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit

SLOTS = 4
PAD_LEN = 80            # wave bucket length (prompts padded up to this)
MAX_LEN = 128


def _workload(mode: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = 12 if mode == "quick" else 32
    plens = rng.integers(12, 72, size=n)
    gens = rng.integers(4, 20, size=n)
    prompts = [rng.integers(4, 500, p).astype(np.int32) for p in plens]
    return prompts, gens


def _pad(prompt: np.ndarray) -> np.ndarray:
    return np.concatenate(
        [np.zeros(PAD_LEN - len(prompt), np.int32), prompt])


def _arrivals(n: int, rate: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 1)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _run_wave(eng, prompts, gens, arrivals):
    """FIFO waves of up to SLOTS requests; per-request latency = wave end
    (the wave blocks on its slowest member — the thing being measured)."""
    n = len(prompts)
    t0 = time.perf_counter()
    queue = []
    nxt = 0
    lat = {}
    while len(lat) < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            queue.append(nxt)
            nxt += 1
        if not queue:
            time.sleep(max(arrivals[nxt] - now, 0.0) + 1e-4)
            continue
        wave, queue = queue[:SLOTS], queue[SLOTS:]
        eng.generate([_pad(prompts[i]) for i in wave],
                     max_new=int(max(gens[i] for i in wave)),
                     continuous=False)
        t_done = time.perf_counter() - t0
        for i in wave:
            lat[i] = t_done - arrivals[i]
    return np.array([lat[i] for i in range(n)])


def _run_continuous(ce, prompts, gens, arrivals, greedy=True):
    """Drive the open-loop workload and derive per-request latency and
    TTFT from the trace spans (queued -> first_token -> done) instead of
    ad-hoc timers: the bench reports exactly what the sink records, so a
    production JSONL export reproduces these numbers. Returns
    (latency, ttft) arrays in arrival order."""
    from repro.serving.trace import TraceSink
    n = len(prompts)
    prev = ce.trace
    sink = ce.trace = prev if prev is not None else TraceSink()
    ce.steps = ce.active_slot_steps = 0
    t0 = time.perf_counter()
    nxt = 0
    done = set()
    rid2i = {}
    while len(done) < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            rid2i[ce.submit(prompts[nxt], int(gens[nxt]),
                            greedy=greedy)] = nxt
            nxt += 1
        if not ce.pending:
            time.sleep(max(arrivals[nxt] - now, 0.0) + 1e-4)
            continue
        for ev in ce.step():
            if ev.kind == "done":
                done.add(ev.rid)
    lat, ttft = np.zeros(n), np.zeros(n)
    for rid, i in rid2i.items():
        q = sink.query(comp="engine", rid=rid, name="queued")[-1].ts
        lat[i] = sink.query(comp="engine", rid=rid,
                            name="done")[-1].ts - q
        ttft[i] = sink.query(comp="engine", rid=rid,
                             name="first_token")[-1].ts - q
    ce.trace = prev
    return lat, ttft


def _variant_cfgs(mode: str):
    """(row name, reduced config, greedy) for the paged-coverage rows."""
    import dataclasses
    from repro.configs import get_reduced
    out = [
        ("swa", get_reduced("h2o_danube_1_8b"), True),
        ("sampled", get_reduced("qwen25_0_5b"), False),
    ]
    if mode != "quick":
        out += [
            ("int8", dataclasses.replace(get_reduced("qwen25_0_5b"),
                                         kv_quant=True), True),
            ("moe", get_reduced("granite_moe_1b_a400m"), True),
        ]
    return out


def _run_variants(mode: str, prompts, gens):
    """Continuous-only latency rows for swa / int8 / moe / sampled
    configs: the model zoo the slot-paged engine covers since PR 5."""
    import jax
    from repro.models import model
    from repro.serving.engine import ContinuousEngine

    n = 8 if mode == "quick" else len(prompts)
    prompts, gens = prompts[:n], gens[:n]
    for name, cfg, greedy in _variant_cfgs(mode):
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        ce = ContinuousEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN)
        ce.generate(prompts[:2], max_new=2, greedy=greedy)       # warm
        t0 = time.perf_counter()
        # everything arrives at t=0: a pure drain through the shared loop
        lat, ttft = _run_continuous(ce, prompts, gens, np.zeros(n),
                                    greedy=greedy)
        wall = time.perf_counter() - t0
        p50, p95 = np.percentile(lat, [50, 95])
        emit(f"serving.continuous_{name}", p50 * 1e6,
             f"p95_ms={p95 * 1e3:.0f};"
             f"ttft_p50_ms={np.percentile(ttft, 50) * 1e3:.1f};"
             f"wall_s={wall:.2f};"
             f"slot_util={ce.utilisation():.2f};n={len(prompts)}")


def run_prefix(mode="quick", seed=0):
    """TTFT vs shared-prefix ratio (the PR-8 block-table pager).

    For each share in the sweep, every measured prompt starts with
    `share * L` tokens of a common prefix followed by a random suffix. A
    fresh engine per share is seeded with one unmeasured prompt (warming
    the prefix trie and the COW-copy executable), then each measured
    prompt's TTFT (GenResult.prefill_s: chunked prefill + any COW copy)
    is recorded. Shared full pages are mapped instead of recomputed and
    the resumed chunk grid skips the reused span, so p50 TTFT must DROP
    as the share rises — asserted for the 90% vs 0% pair."""
    import jax
    from repro.configs import get_reduced
    from repro.models import model
    from repro.serving.engine import ContinuousEngine

    shares = (0.0, 0.9) if mode == "quick" else (0.0, 0.5, 0.9)
    n = 8 if mode == "quick" else 16
    plen = 96
    rng = np.random.default_rng(seed)
    common = rng.integers(4, 500, plen).astype(np.int32)
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))

    def prompt_at(share):
        k = int(share * plen)
        tail = rng.integers(4, 500, plen - k).astype(np.int32)
        return np.concatenate([common[:k], tail]) if k else tail

    p50s = {}
    for share in shares:
        ce = ContinuousEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN)
        ce.warmup()
        prompts = [prompt_at(share) for _ in range(n)]
        # seed pass: registers the common prefix and compiles the COW
        # copy off the measured path (two probes so the second COW-forks)
        ce.generate([prompt_at(share)], max_new=2)
        ce.generate([prompt_at(share)], max_new=2)
        hits0, reused0 = ce.prefix_hits, ce.prefix_tokens_reused
        ttfts = []
        for p in prompts:
            ttfts.append(ce.generate([p], max_new=2)[0].prefill_s)
        p50s[share] = float(np.percentile(ttfts, 50))
        emit(f"serving.prefix_ttft_share{int(share * 100):02d}",
             p50s[share] * 1e6,
             f"hits={ce.prefix_hits - hits0};"
             f"tokens_reused={ce.prefix_tokens_reused - reused0};"
             f"n={n};plen={plen}")
    assert p50s[0.9] < p50s[0.0], (
        f"prefix sharing did not cut TTFT: "
        f"p50@90%={p50s[0.9]:.4f}s >= p50@0%={p50s[0.0]:.4f}s")


def run_trace_overhead(mode="quick", seed=0):
    """Gate: span tracing must cost < 5% on p50 request latency.

    Alternates traced and untraced drains of the same ragged workload on
    one engine (interleaved so clock/thermal drift cancels), measures
    each drain with wall timers — identical instrumentation in both arms
    — and compares the median of per-arm p50s."""
    import jax
    from repro.configs import get_reduced
    from repro.models import model
    from repro.serving.engine import ContinuousEngine
    from repro.serving.trace import TraceSink

    prompts, gens = _workload(mode, seed=seed)
    n = 8 if mode == "quick" else len(prompts)
    prompts, gens = prompts[:n], gens[:n]
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    ce = ContinuousEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN)
    ce.warmup()

    def drain():
        t0 = time.perf_counter()
        sub = {ce.submit(p, int(g)): time.perf_counter() - t0
               for p, g in zip(prompts, gens)}
        lat = {}
        while ce.pending:
            for ev in ce.step():
                if ev.kind == "done":
                    lat[ev.rid] = (time.perf_counter() - t0
                                   - sub[ev.rid])
        return float(np.percentile(list(lat.values()), 50))

    drain()                               # shape warm-up, untimed
    reps = 3 if mode == "quick" else 5
    p50s = {True: [], False: []}
    for _ in range(reps):
        for traced in (True, False):
            ce.trace = TraceSink() if traced else None
            p50s[traced].append(drain())
    ce.trace = None
    on = float(np.median(p50s[True]))
    off = float(np.median(p50s[False]))
    overhead = (on - off) / off
    emit("serving.trace_overhead", overhead * 1e6,
         f"p50_on_ms={on * 1e3:.1f};p50_off_ms={off * 1e3:.1f};"
         f"reps={reps};n={n}")
    assert overhead < 0.05, (
        f"tracing overhead {overhead:.1%} >= 5% p50 "
        f"(on={on * 1e3:.1f}ms off={off * 1e3:.1f}ms)")


def run_chaos(mode="quick", seed=0, trace_export=None):
    """Goodput under a seeded FaultPlan: every request either completes
    within its deadline or is explicitly shed — the emitted row asserts
    the partition (lost == 0) on top of the latency percentiles. With
    `trace_export=PATH` the whole run records into a shared TraceSink
    whose JSONL export feeds tools/trace_check.py (the nightly CI
    artifact)."""
    import jax
    from repro.configs import get_reduced
    from repro.models import model
    from repro.serving.engine import ContinuousEngine
    from repro.serving.faults import FaultPlan, wrap_replicas
    from repro.serving.scheduler import SlotScheduler
    from repro.serving.trace import TraceSink

    n = 16 if mode == "quick" else 48
    prompts, gens = _workload(mode, seed=seed)
    while len(prompts) < n:
        more, mg = _workload(mode, seed=seed + len(prompts))
        prompts, gens = prompts + more, np.concatenate([gens, mg])
    prompts, gens = prompts[:n], gens[:n]

    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    base = ContinuousEngine(cfg, params, slots=2, max_len=MAX_LEN)
    base.warmup()
    engines = [base] + [base.clone() for _ in range(2)]
    for e in engines[1:]:
        e.warmup()
    sink = TraceSink() if trace_export else None
    if sink is not None:
        for e in engines:
            e.trace = sink

    plan = FaultPlan.quick(seed)
    sched = SlotScheduler(wrap_replicas(engines, plan), stall_s=1.0,
                          probe_cooldown_s=0.1, deadline_s=60.0,
                          trace=sink)
    t0 = time.perf_counter()
    deadlines = {}
    for i, p in enumerate(prompts):
        # every 5th request gets a tight deadline (exercises shedding)
        d = 0.02 if i % 5 == 4 else 60.0
        deadlines[sched.submit(p, int(gens[i]), deadline_s=d)] = d
    done = sched.run()
    wall = time.perf_counter() - t0

    lat = np.array([c.latency_s for c in done]) if done else np.zeros(1)
    p50, p95 = np.percentile(lat, [50, 95])
    good = sum(1 for c in done if c.latency_s <= deadlines[c.rid])
    cnt = sched.counters
    lost = n - len(done) - len(sched.shed)
    emit("serving.chaos", p50 * 1e6,
         f"p95_ms={p95 * 1e3:.0f};goodput={good}/{n};"
         f"shed={len(sched.shed)};lost={lost};hedges={cnt.hedges};"
         f"drains={cnt.drains};recoveries={cnt.recoveries};"
         f"wall_s={wall:.2f}")
    assert lost == 0, f"{lost} requests silently lost under chaos"
    if sink is not None:
        m = sink.export_jsonl(trace_export)
        emit("serving.chaos_trace", float(m),
             f"path={trace_export};evicted={sink.evicted}")


def run(mode="quick"):
    import jax
    from repro.configs import get_reduced
    from repro.models import model
    from repro.serving.engine import Engine

    prompts, gens = _workload(mode)
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=MAX_LEN, slots=SLOTS)
    ce = eng.continuous()

    # warm every fixed shape both paths use (bucketed wave prefill at each
    # batch size, chunk-prefill + paged decode for continuous)
    for b in range(1, SLOTS + 1):
        eng.generate([_pad(prompts[0])] * b, max_new=2, continuous=False)
    ce.warmup()

    # calibrate the Poisson rate to ~80% of measured decode capacity
    ce.steps = ce.active_slot_steps = 0
    t0 = time.perf_counter()
    ce.generate(prompts[:SLOTS], max_new=8)
    t_cal = time.perf_counter() - t0
    t_step = t_cal / max(ce.steps, 1)               # engine step wall time
    steps_per_req = np.mean([len(p) // ce.prefill_chunk + 1
                             for p in prompts]) + float(np.mean(gens))
    service_s = steps_per_req * t_step / SLOTS      # per request, amortised
    rate = 0.8 / max(service_s, 1e-4)
    arrivals = _arrivals(len(prompts), rate, seed=0)

    lat_w = _run_wave(eng, prompts, gens, arrivals)
    lat_c, ttft_c = _run_continuous(ce, prompts, gens, arrivals)

    p50w, p95w = np.percentile(lat_w, [50, 95])
    p50c, p95c = np.percentile(lat_c, [50, 95])
    emit("serving.wave", p50w * 1e6,
         f"p95_ms={p95w * 1e3:.0f};n={len(prompts)};rate={rate:.1f}qps")
    emit("serving.continuous", p50c * 1e6,
         f"p95_ms={p95c * 1e3:.0f};"
         f"ttft_p50_ms={np.percentile(ttft_c, 50) * 1e3:.1f};"
         f"slot_util={ce.utilisation():.2f}")
    emit("serving.p95_speedup", (p95w / max(p95c, 1e-9)) * 1e6,
         f"continuous_beats_wave={bool(p95c < p95w)}")

    _run_variants(mode, prompts, gens)
    run_prefix(mode)
    run_chaos(mode)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="quick", choices=["quick", "full"])
    ap.add_argument("--chaos", action="store_true",
                    help="goodput-under-chaos section only")
    ap.add_argument("--prefix", action="store_true",
                    help="shared-prefix TTFT sweep only")
    ap.add_argument("--trace-overhead", action="store_true",
                    help="tracing-overhead gate (< 5%% p50) only")
    ap.add_argument("--trace-export", default=None, metavar="PATH",
                    help="with --chaos: export the run's TraceSink as "
                         "JSONL for tools/trace_check.py")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    if a.chaos:
        run_chaos(a.mode, a.seed, trace_export=a.trace_export)
    elif a.prefix:
        run_prefix(a.mode, a.seed)
    elif a.trace_overhead:
        run_trace_overhead(a.mode, a.seed)
    else:
        run(a.mode)
