"""Shared benchmark fixtures: datasets scaled for a 1-core CPU container
(paper runs SIFT-1M on a phone; we distribution-match at reduced N and keep
every derived quantity in the analytical models at the paper's N too)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import make_index
from repro.data.synthetic import nytimes_like, sift_like

SIZES = {"quick": (2500, 40), "full": (30000, 200)}

_INDEX_CACHE: dict = {}
_DATA_CACHE: dict = {}

IDX_KW = {
    "IVF": lambda nc: {"n_clusters": nc},
    "IVFPQ": lambda nc: {"n_clusters": nc, "m_pq": 8},
    "HNSW": lambda nc: {},
    "HNSWPQ": lambda nc: {"m_pq": 8},
    "IVF-DISK": lambda nc: {"n_clusters": nc},
    "IVFPQ-DISK": lambda nc: {"n_clusters": nc, "m_pq": 8},
    "IVF-HNSW": lambda nc: {"n_clusters": nc},
    "EcoVector": lambda nc: {"n_clusters": nc},
}


def datasets(mode="quick"):
    if mode not in _DATA_CACHE:
        n, nq = SIZES[mode]
        sX, sQ = sift_like(n=n, nq=nq)
        nX, nQ = nytimes_like(n=max(n // 2, 1000), nq=nq)
        _DATA_CACHE[mode] = {"SIFT-like": (sX, sQ), "NYTimes-like": (nX, nQ)}
    return _DATA_CACHE[mode]


def build(name, X, nc=None):
    """Build (or fetch the cached) index — suites share builds since the
    graph-based builds dominate benchmark wall time."""
    nc = nc or max(16, len(X) // 256)
    key = (name, id(X), nc)
    if key in _INDEX_CACHE:
        return _INDEX_CACHE[key]
    kw = dict(IDX_KW[name](nc))
    if name in ("HNSW", "HNSWPQ", "EcoVector"):
        kw.setdefault("M", 12)
        kw.setdefault("ef_construction", 60)
    idx = make_index(name, X.shape[1], **kw)
    t0 = time.perf_counter()
    idx.build(X)
    _INDEX_CACHE[key] = (idx, time.perf_counter() - t0)
    return _INDEX_CACHE[key]


def ground_truth(X, Q, k=10):
    out = []
    for q in Q:
        d = np.sum((X - q) ** 2, axis=1)
        out.append(set(np.argsort(d)[:k].tolist()))
    return out


def recall_and_qps(idx, Q, gt, k=10, **search_kw):
    t0 = time.perf_counter()
    recs = []
    for q, g in zip(Q, gt):
        ids, _ = idx.search(q, k=k, **search_kw)
        recs.append(len(set(map(int, ids)) & g) / k)
    dt = time.perf_counter() - t0
    return float(np.mean(recs)), len(Q) / dt, dt / len(Q)


def recall_and_qps_batched(idx, Q, gt, k=10, n_probe=4, fused=True):
    """One fused batched device call for the whole query set."""
    # warm once at the full batch shape (jit cache keys on B)
    idx.search_device_batched(Q, k=k, n_probe=n_probe, fused=fused)
    t0 = time.perf_counter()
    ids_b, _ = idx.search_device_batched(Q, k=k, n_probe=n_probe,
                                         fused=fused)
    dt = time.perf_counter() - t0
    recs = [len(set(map(int, ids)) & g) / k for ids, g in zip(ids_b, gt)]
    return float(np.mean(recs)), len(Q) / dt, dt / len(Q)


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
