"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--mode quick|full] [--only X]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("memory", "benchmarks.bench_memory"),            # Fig 6 / Table 1
    ("recall_qps", "benchmarks.bench_recall_qps"),    # Fig 7 / Fig 8
    ("power", "benchmarks.bench_power"),              # Fig 9 / §3.4.3
    ("update", "benchmarks.bench_update"),            # Fig 10
    ("centroids", "benchmarks.bench_centroids"),      # Fig 11
    ("scr", "benchmarks.bench_scr"),                  # Table 4 / Fig 12
    ("rag_e2e", "benchmarks.bench_rag_e2e"),          # Table 5
    ("battery", "benchmarks.bench_battery"),          # Table 6
    ("kernels", "benchmarks.bench_kernels"),          # kernels (extra)
    ("serving", "benchmarks.bench_serving"),          # wave vs continuous
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="quick", choices=["quick", "full"])
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, module in SUITES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run(args.mode)
            print(f"suite.{name},{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"suite.{name},{(time.time()-t0)*1e6:.0f},"
                  f"FAILED:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
