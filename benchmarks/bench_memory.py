"""Figure 6 / Table 1: measured RAM footprint vs the analytical model, per
algorithm per dataset (plus the paper-scale analytical numbers at N=1M)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import IDX_KW, build, datasets, emit
from repro.core.analytical import memory_bytes
from repro.core.baselines import ALL_BASELINES


def run(mode="quick"):
    for dset, (X, Q) in datasets(mode).items():
        nc = max(16, len(X) // 256)
        for name in ALL_BASELINES:
            idx, t_build = build(name, X, nc)
            measured = idx.ram_bytes()
            model = memory_bytes(name, N=len(X), d=X.shape[1], Nc=nc)
            emit(f"memory.{dset}.{name}", t_build * 1e6,
                 f"measured_MB={measured/1e6:.3f};model_MB={model/1e6:.3f}")
    # paper-scale analytical rows (SIFT-1M regime)
    for name in ALL_BASELINES:
        model = memory_bytes(name, N=1_000_000, d=128, Nc=4096)
        emit(f"memory.model@1M.{name}", 0.0, f"model_MB={model/1e6:.1f}")


if __name__ == "__main__":
    run()
