"""Table 6: prompt-eval/generation speed and battery impact. Paper values
are constants (measured on a Galaxy S24); we add a *measured* tokens/s
column from the reduced sLM running its real decode loop on this host."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_reduced
from repro.models import model
from repro.serving.engine import Engine
from repro.serving.rag import BATTERY_J, SLM_SPEEDS


def run(mode="quick"):
    for slm, row in SLM_SPEEDS.items():
        emit(f"battery.paper.{slm}", 0.0,
             f"prompt_tps={row['prompt_tps']};gen_tps={row['gen_tps']};"
             f"battery_pct_per_1k={row['batt_pct_1k']};"
             f"J_per_1k={row['batt_pct_1k']/100*BATTERY_J:.1f}")
    # measured decode throughput of the reduced on-device sLM (this host)
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=160)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, 100, 64).astype(np.int32) for _ in range(4)]
    eng.generate(prompts, max_new=4)  # warmup/compile
    t0 = time.perf_counter()
    res = eng.generate(prompts, max_new=24)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in res)
    emit("battery.measured.reduced-slm", dt / max(toks, 1) * 1e6,
         f"host_gen_tps={toks/dt:.1f}")


if __name__ == "__main__":
    run()
