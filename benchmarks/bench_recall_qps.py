"""Figure 7 (recall vs QPS) + Figure 8 (cluster sizes, efSearch width)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (build, datasets, emit, ground_truth,
                               recall_and_qps, recall_and_qps_batched)
from repro.core.baselines import ALL_BASELINES

SWEEPS = {
    "IVF": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "IVFPQ": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "IVF-DISK": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "IVFPQ-DISK": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "IVF-HNSW": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "HNSW": [{"ef_search": e} for e in (8, 16, 32, 64, 128)],
    "HNSWPQ": [{"ef_search": e} for e in (8, 16, 32, 64, 128)],
    "EcoVector": [{"n_probe": p, "ef_search": e}
                  for p, e in ((1, 8), (2, 16), (4, 16), (8, 32), (16, 64))],
}


def run(mode="quick"):
    for dset, (X, Q) in datasets(mode).items():
        gt = ground_truth(X, Q)
        for name in ALL_BASELINES:
            idx, _ = build(name, X)
            for kw in SWEEPS[name]:
                rec, qps, per = recall_and_qps(idx, Q, gt, **kw)
                tag = ";".join(f"{k}={v}" for k, v in kw.items())
                emit(f"recall_qps.{dset}.{name}.{tag}", per * 1e6,
                     f"recall@10={rec:.3f};qps={qps:.1f}")
            if name == "EcoVector":
                # fused batched device path: route + scan in one jitted
                # call over the whole query batch
                for p in (1, 2, 4, 8):
                    rec, qps, per = recall_and_qps_batched(idx, Q, gt,
                                                           n_probe=p)
                    emit(f"recall_qps.{dset}.EcoVector-device.n_probe={p}",
                         per * 1e6, f"recall@10={rec:.3f};qps={qps:.1f}")
                # before/after per-query latency: host-routed two-step vs
                # the fused single-call pipeline at the paper's n_probe=4
                _, _, per_two = recall_and_qps_batched(idx, Q, gt,
                                                       n_probe=4,
                                                       fused=False)
                _, _, per_fused = recall_and_qps_batched(idx, Q, gt,
                                                         n_probe=4)
                emit(f"recall_qps.{dset}.EcoVector-device.route_fusion",
                     per_fused * 1e6,
                     f"two_step_us={per_two*1e6:.1f};"
                     f"fused_us={per_fused*1e6:.1f};"
                     f"speedup={per_two / max(per_fused, 1e-12):.2f}x")
                sizes = idx.cluster_sizes()
                emit(f"cluster_sizes.{dset}", 0.0,
                     f"mean={sizes.mean():.1f};p90="
                     f"{np.percentile(sizes, 90):.0f};max={sizes.max()}")
                # Fig 8b: efSearch width needed for >=0.9 recall
                for ef in (4, 8, 16, 32, 64):
                    rec, _, per = recall_and_qps(idx, Q, gt, n_probe=8,
                                                 ef_search=ef)
                    if rec >= 0.9:
                        emit(f"ef_width.{dset}.EcoVector", per * 1e6,
                             f"ef_for_0.9={ef}")
                        break


if __name__ == "__main__":
    run()
