"""Figure 7 (recall vs QPS) + Figure 8 (cluster sizes, efSearch width),
plus the tiered hot/cold sweep (DESIGN.md §14): `--tiered` serves the
same corpus under shrinking device budgets and checks that recall is
unchanged (candidates are tier-invariant, so ids/dists are bit-identical
at equal n_probe) while reporting resident-device-bytes and the
tier-hit-rate."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (build, datasets, emit, ground_truth,
                               recall_and_qps, recall_and_qps_batched)
from repro.core.baselines import ALL_BASELINES

SWEEPS = {
    "IVF": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "IVFPQ": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "IVF-DISK": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "IVFPQ-DISK": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "IVF-HNSW": [{"n_probe": p} for p in (1, 2, 4, 8, 16)],
    "HNSW": [{"ef_search": e} for e in (8, 16, 32, 64, 128)],
    "HNSWPQ": [{"ef_search": e} for e in (8, 16, 32, 64, 128)],
    "EcoVector": [{"n_probe": p, "ef_search": e}
                  for p, e in ((1, 8), (2, 16), (4, 16), (8, 32), (16, 64))],
}


def run(mode="quick"):
    for dset, (X, Q) in datasets(mode).items():
        gt = ground_truth(X, Q)
        for name in ALL_BASELINES:
            idx, _ = build(name, X)
            for kw in SWEEPS[name]:
                rec, qps, per = recall_and_qps(idx, Q, gt, **kw)
                tag = ";".join(f"{k}={v}" for k, v in kw.items())
                emit(f"recall_qps.{dset}.{name}.{tag}", per * 1e6,
                     f"recall@10={rec:.3f};qps={qps:.1f}")
            if name == "EcoVector":
                # fused batched device path: route + scan in one jitted
                # call over the whole query batch
                for p in (1, 2, 4, 8):
                    rec, qps, per = recall_and_qps_batched(idx, Q, gt,
                                                           n_probe=p)
                    emit(f"recall_qps.{dset}.EcoVector-device.n_probe={p}",
                         per * 1e6, f"recall@10={rec:.3f};qps={qps:.1f}")
                # before/after per-query latency: host-routed two-step vs
                # the fused single-call pipeline at the paper's n_probe=4
                _, _, per_two = recall_and_qps_batched(idx, Q, gt,
                                                       n_probe=4,
                                                       fused=False)
                _, _, per_fused = recall_and_qps_batched(idx, Q, gt,
                                                         n_probe=4)
                emit(f"recall_qps.{dset}.EcoVector-device.route_fusion",
                     per_fused * 1e6,
                     f"two_step_us={per_two*1e6:.1f};"
                     f"fused_us={per_fused*1e6:.1f};"
                     f"speedup={per_two / max(per_fused, 1e-12):.2f}x")
                sizes = idx.cluster_sizes()
                emit(f"cluster_sizes.{dset}", 0.0,
                     f"mean={sizes.mean():.1f};p90="
                     f"{np.percentile(sizes, 90):.0f};max={sizes.max()}")
                # Fig 8b: efSearch width needed for >=0.9 recall
                for ef in (4, 8, 16, 32, 64):
                    rec, _, per = recall_and_qps(idx, Q, gt, n_probe=8,
                                                 ef_search=ef)
                    if rec >= 0.9:
                        emit(f"ef_width.{dset}.EcoVector", per * 1e6,
                             f"ef_for_0.9={ef}")
                        break


def _batched_p50(idx, Q, k, n_probe, repeats=5):
    idx.search_device_batched(Q, k=k, n_probe=n_probe)     # jit warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        ids, dists = idx.search_device_batched(Q, k=k, n_probe=n_probe)
        times.append((time.perf_counter() - t0) / len(Q))
    return ids, dists, float(np.median(times))


def run_tiered(mode="quick", budgets=(1.0, 0.5, 0.25)):
    """Tiered sweep: one TieredEcoVector, shrinking device budgets.

    Emits resident-device-bytes, tier-hit-rate, recall and p50-vs-resident
    columns per budget, and raises if the tiered results are not
    bit-identical to the all-resident reference at equal n_probe."""
    from repro.core.tiered import TieredEcoVector

    X, Q = datasets(mode)["SIFT-like"]
    gt = ground_truth(X, Q)
    k, n_probe = 10, 4
    idx = TieredEcoVector(X.shape[1], n_clusters=max(16, len(X) // 256),
                          M=12, ef_construction=60)
    t0 = time.perf_counter()
    idx.build(X)
    emit("tiered.build", (time.perf_counter() - t0) * 1e6,
         f"n={len(X)};clusters={idx.n_clusters}")

    ref_ids, ref_dists, ref_p50 = _batched_p50(idx, Q, k, n_probe)
    recs = [len(set(map(int, ids)) & g) / k for ids, g in zip(ref_ids, gt)]
    full = idx.all_resident_bytes()
    emit("tiered.SIFT-like.budget=100%", ref_p50 * 1e6,
         f"recall@10={np.mean(recs):.3f};resident_bytes={full};"
         f"hot_hit_rate=1.00;p50_vs_resident=1.00x")

    for frac in budgets:
        idx.set_device_budget(int(frac * full))
        s = idx.stats
        h0, c0 = s.tier_hot_hits, s.tier_cold_hits
        ids, dists, p50 = _batched_p50(idx, Q, k, n_probe)
        if not (np.array_equal(ids, ref_ids)
                and np.array_equal(dists, ref_dists)):
            raise AssertionError(
                f"tiered results diverged from all-resident at "
                f"budget={frac:.0%} (n_probe={n_probe})")
        recs = [len(set(map(int, i)) & g) / k for i, g in zip(ids, gt)]
        hits_h, hits_c = s.tier_hot_hits - h0, s.tier_cold_hits - c0
        rate = hits_h / max(hits_h + hits_c, 1)
        emit(f"tiered.SIFT-like.budget={frac:.0%}", p50 * 1e6,
             f"recall@10={np.mean(recs):.3f};"
             f"resident_bytes={idx.device_resident_bytes()};"
             f"hot={len(idx.hot_clusters())};cold={len(idx.cold_clusters())};"
             f"hot_hit_rate={rate:.2f};"
             f"p50_vs_resident={p50 / max(ref_p50, 1e-12):.2f}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="quick", choices=("quick", "full"))
    ap.add_argument("--tiered", action="store_true",
                    help="run only the tiered hot/cold budget sweep")
    a = ap.parse_args()
    if a.tiered:
        run_tiered(a.mode)
    else:
        run(a.mode)
        run_tiered(a.mode)
