"""Figure 10: insertion and deletion latency per algorithm."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build, datasets, emit

UPDATABLE = ["IVF", "IVF-DISK", "IVF-HNSW", "HNSW", "EcoVector"]


def run(mode="quick"):
    for dset, (X, Q) in datasets(mode).items():
        rng = np.random.default_rng(0)
        new_vecs = X[rng.choice(len(X), 32)] + 0.01 * rng.normal(
            size=(32, X.shape[1])).astype(np.float32)
        for name in UPDATABLE:
            idx, _ = build(name, X)
            base = 1_000_000
            t0 = time.perf_counter()
            for i, v in enumerate(new_vecs):
                idx.insert(base + i, v)
            t_ins = (time.perf_counter() - t0) / len(new_vecs)
            t0 = time.perf_counter()
            for i in range(len(new_vecs)):
                idx.delete(base + i)
            t_del = (time.perf_counter() - t0) / len(new_vecs)
            emit(f"update.{dset}.{name}", (t_ins + t_del) / 2 * 1e6,
                 f"insert_ms={t_ins*1e3:.3f};delete_ms={t_del*1e3:.3f}")


if __name__ == "__main__":
    run()
