"""Figure 10: insertion and deletion latency per algorithm."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build, datasets, emit

UPDATABLE = ["IVF", "IVF-DISK", "IVF-HNSW", "HNSW", "EcoVector"]


def _repack_cost(idx, new_vecs, base, full):
    """Per-update cost of keeping the device pack fresh: insert a vector,
    re-pack (incrementally or from scratch), time the repack; then delete
    to restore the index."""
    idx.device_pack()                       # warm: pack exists
    t_pack = 0.0
    for i, v in enumerate(new_vecs):
        idx.insert(base + i, v)
        t0 = time.perf_counter()
        idx.device_pack(force_full=full)
        t_pack += time.perf_counter() - t0
    for i in range(len(new_vecs)):
        idx.delete(base + i)
    idx.device_pack()                       # restore a clean pack
    return t_pack / len(new_vecs)


def run(mode="quick"):
    for dset, (X, Q) in datasets(mode).items():
        rng = np.random.default_rng(0)
        new_vecs = X[rng.choice(len(X), 32)] + 0.01 * rng.normal(
            size=(32, X.shape[1])).astype(np.float32)
        for name in UPDATABLE:
            idx, _ = build(name, X)
            # arbitrary huge external ids: HNSW remaps ids to dense
            # internal slots, so sparse id spaces no longer balloon the
            # vector arrays or the on-disk cluster pickles
            base = 10**9
            t0 = time.perf_counter()
            for i, v in enumerate(new_vecs):
                idx.insert(base + i, v)
            t_ins = (time.perf_counter() - t0) / len(new_vecs)
            t0 = time.perf_counter()
            for i in range(len(new_vecs)):
                idx.delete(base + i)
            t_del = (time.perf_counter() - t0) / len(new_vecs)
            emit(f"update.{dset}.{name}", (t_ins + t_del) / 2 * 1e6,
                 f"insert_ms={t_ins*1e3:.3f};delete_ms={t_del*1e3:.3f}")
            if name == "EcoVector":
                # incremental dirty-cluster repack vs full [NC, CAP, d]
                # rebuild after each update (the pre-refactor behavior)
                sub = new_vecs[:8]
                t_full = _repack_cost(idx, sub, base, full=True)
                t_incr = _repack_cost(idx, sub, base, full=False)
                emit(f"update.{dset}.EcoVector.repack", t_incr * 1e6,
                     f"incremental_us={t_incr*1e6:.1f};"
                     f"full_us={t_full*1e6:.1f};"
                     f"speedup={t_full / max(t_incr, 1e-12):.1f}x")


if __name__ == "__main__":
    run()
