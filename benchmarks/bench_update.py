"""Figure 10: insertion and deletion latency per algorithm, plus the
durability tax: EcoVector generation save, cold load, and WAL-replay
recovery time (DESIGN.md §12)."""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import build, datasets, emit

UPDATABLE = ["IVF", "IVF-DISK", "IVF-HNSW", "HNSW", "EcoVector"]


def _repack_cost(idx, new_vecs, base, full):
    """Per-update cost of keeping the device pack fresh: insert a vector,
    re-pack (incrementally or from scratch), time the repack; then delete
    to restore the index."""
    idx.device_pack()                       # warm: pack exists
    t_pack = 0.0
    for i, v in enumerate(new_vecs):
        idx.insert(base + i, v)
        t0 = time.perf_counter()
        idx.device_pack(force_full=full)
        t_pack += time.perf_counter() - t0
    for i in range(len(new_vecs)):
        idx.delete(base + i)
    idx.device_pack()                       # restore a clean pack
    return t_pack / len(new_vecs)


def _persistence_cost(idx, new_vecs, base):
    """Durability columns: full generation save (checksummed segments +
    manifest + fsync), cold load from the committed snapshot, and
    recovery load with a WAL of journaled mutations to replay."""
    from repro.core.ecovector import EcoVector

    root = tempfile.mkdtemp(prefix="bench_save_")
    try:
        t0 = time.perf_counter()
        idx.save(root)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        EcoVector.load(root)
        t_load = time.perf_counter() - t0
        for i, v in enumerate(new_vecs):    # journaled (WAL'd) mutations
            idx.insert(base + i, v)
        t0 = time.perf_counter()
        ev = EcoVector.load(root)           # snapshot + WAL replay
        t_recover = time.perf_counter() - t0
        assert ev.stats.wal_replayed == len(new_vecs)
        for i in range(len(new_vecs)):      # restore the index
            idx.delete(base + i)
        idx.save()                          # compact: drops the WAL
        disk = sum(os.path.getsize(os.path.join(dp, f))
                   for dp, _, fs in os.walk(root) for f in fs)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return t_save, t_load, t_recover, disk


def run(mode="quick"):
    for dset, (X, Q) in datasets(mode).items():
        rng = np.random.default_rng(0)
        new_vecs = X[rng.choice(len(X), 32)] + 0.01 * rng.normal(
            size=(32, X.shape[1])).astype(np.float32)
        for name in UPDATABLE:
            idx, _ = build(name, X)
            # arbitrary huge external ids: HNSW remaps ids to dense
            # internal slots, so sparse id spaces no longer balloon the
            # vector arrays or the on-disk cluster pickles
            base = 10**9
            t0 = time.perf_counter()
            for i, v in enumerate(new_vecs):
                idx.insert(base + i, v)
            t_ins = (time.perf_counter() - t0) / len(new_vecs)
            t0 = time.perf_counter()
            for i in range(len(new_vecs)):
                idx.delete(base + i)
            t_del = (time.perf_counter() - t0) / len(new_vecs)
            emit(f"update.{dset}.{name}", (t_ins + t_del) / 2 * 1e6,
                 f"insert_ms={t_ins*1e3:.3f};delete_ms={t_del*1e3:.3f}")
            if name == "EcoVector":
                # incremental dirty-cluster repack vs full [NC, CAP, d]
                # rebuild after each update (the pre-refactor behavior)
                sub = new_vecs[:8]
                t_full = _repack_cost(idx, sub, base, full=True)
                t_incr = _repack_cost(idx, sub, base, full=False)
                emit(f"update.{dset}.EcoVector.repack", t_incr * 1e6,
                     f"incremental_us={t_incr*1e6:.1f};"
                     f"full_us={t_full*1e6:.1f};"
                     f"speedup={t_full / max(t_incr, 1e-12):.1f}x")
                t_save, t_load, t_rec, disk = _persistence_cost(
                    idx, new_vecs[:8], base)
                emit(f"update.{dset}.EcoVector.persist", t_save * 1e3,
                     f"save_ms={t_save*1e3:.2f};"
                     f"load_ms={t_load*1e3:.2f};"
                     f"recover_ms={t_rec*1e3:.2f};"
                     f"wal_replayed=8;disk_kb={disk/1024:.0f}")


if __name__ == "__main__":
    run()
