"""Table 5: Accuracy / TTFT / Power per (sLM x RAG method x dataset).

Accuracy = answer-in-final-context proxy (retrieval+SCR quality; no phone
sLM here). TTFT/Power combine measured retrieval/post-processing time with
the paper's Table-6 prompt-eval speeds and battery-impact coefficients.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.data.synthetic import make_qa_corpus
from repro.serving.embedder import HashEmbedder
from repro.serving.rag import PIPELINES, SLM_SPEEDS, accuracy

STYLES = {"SQuAD-like": "squad", "HotpotQA-like": "hotpot",
          "TriviaQA-like": "trivia"}


def run(mode="quick"):
    nq = 20 if mode == "quick" else 80
    for label, style in STYLES.items():
        corpus = make_qa_corpus(style, n_docs=150, n_questions=nq, seed=0)
        emb = HashEmbedder(dim=128).fit(corpus.docs)
        for slm in SLM_SPEEDS:
            for pname, cls in PIPELINES.items():
                pipe = cls(corpus.docs, emb, top_k=3, slm=slm)
                acc = accuracy(pipe, corpus.examples, max_q=nq)
                answers = [pipe.answer(e.question)
                           for e in corpus.examples[:nq]]
                ttft = np.mean([a.ttft_model_s for a in answers])
                power = np.mean([a.energy_model_j for a in answers])
                tok = np.mean([a.prompt_tokens for a in answers])
                emit(f"rag.{slm}.{label}.{pname}", ttft * 1e6,
                     f"acc={acc:.2f};ttft_s={ttft:.2f};"
                     f"power_J={power:.2f};tokens={tok:.0f}")


if __name__ == "__main__":
    run()
