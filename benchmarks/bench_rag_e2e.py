"""Table 5: Accuracy / TTFT / Power per (sLM x RAG method x dataset).

Accuracy = answer-in-final-context proxy (retrieval+SCR quality; no phone
sLM here). TTFT/Power combine measured retrieval/post-processing time with
the paper's Table-6 prompt-eval speeds and battery-impact coefficients.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.data.synthetic import make_qa_corpus
from repro.serving.embedder import HashEmbedder
from repro.serving.rag import PIPELINES, SLM_SPEEDS, answer_in_context
from repro.serving.slm import ReducedSLM

STYLES = {"SQuAD-like": "squad", "HotpotQA-like": "hotpot",
          "TriviaQA-like": "trivia"}


def run(mode="quick"):
    nq = 20 if mode == "quick" else 80
    # Real-generation TTFT reference: Engine prefill + first token on the
    # reduced on-device sLM (one shared instance -> one compile), reported
    # beside the analytical Table-6 ttft estimate on every row.
    slm_real = ReducedSLM()
    slm_real.warmup()
    # measured once per (style, pipeline): the real engine/prompts are
    # identical for every Table-6 slm row, only the analytical column
    # differs, so re-measuring per slm would triple the Engine waves
    real_ttft_cache = {}
    for label, style in STYLES.items():
        corpus = make_qa_corpus(style, n_docs=150, n_questions=nq, seed=0)
        emb = HashEmbedder(dim=128).fit(corpus.docs)
        for slm in SLM_SPEEDS:
            for pname, cls in PIPELINES.items():
                pipe = cls(corpus.docs, emb, top_k=3, slm=slm)
                # Table-5 rows: host retrieval for EVERY pipeline so the
                # per-query TTFT/power/accuracy comparison stays
                # apples-to-apples (the interpret-mode Pallas path on
                # non-TPU hosts is correctness-grade, not timing-grade)
                pipe.device_retrieval = False
                questions = [e.question for e in corpus.examples[:nq]]
                answers = [pipe.answer(q) for q in questions]
                # answer-in-final-context accuracy from the same answers
                # (no second per-query pass)
                acc = float(np.mean(
                    [answer_in_context(ex, a)
                     for ex, a in zip(corpus.examples[:nq], answers)]))
                ttft = np.mean([a.ttft_model_s for a in answers])
                power = np.mean([a.energy_model_j for a in answers])
                tok = np.mean([a.prompt_tokens for a in answers])
                # measured LM-side TTFT on this pipeline's actual prompts
                if (label, pname) not in real_ttft_cache:
                    n_real = min(3, len(answers))
                    real_ttft_cache[label, pname] = float(np.mean(
                        [slm_real.measure_ttft(a.prompt)
                         for a in answers[:n_real]]))
                real_ttft = real_ttft_cache[label, pname]
                emit(f"rag.{slm}.{label}.{pname}", ttft * 1e6,
                     f"acc={acc:.2f};ttft_s={ttft:.2f};"
                     f"real_ttft_s={real_ttft:.3f};"
                     f"real_arch={slm_real.arch}-reduced;"
                     f"power_J={power:.2f};tokens={tok:.0f}")
                # batched-serving throughput for pipelines with batched
                # retrieval (one embed + one fused device retrieval)
                if pipe._finish is not None:
                    pipe.device_retrieval = cls.device_retrieval
                    retrieval_mode = ("device"
                                      if pipe._use_device_retrieval()
                                      else "host")
                    if retrieval_mode == "device":
                        # warm the fused route->scan jit at batch shape
                        # B=nq (jit caches key on B) to exclude compile
                        pipe._retrieve_batch(pipe.doc_vecs[:nq], pipe.top_k)
                    t0 = time.perf_counter()
                    batch = pipe.answer_batch(questions)
                    wall = time.perf_counter() - t0
                    bttft = np.mean([a.ttft_model_s for a in batch])
                    emit(f"rag_batched.{slm}.{label}.{pname}",
                         wall / nq * 1e6,
                         f"amortized_ttft_s={bttft:.2f};"
                         f"batch_wall_s={wall:.2f};B={nq};"
                         f"retrieval={retrieval_mode}")


if __name__ == "__main__":
    run()
