"""Config system: typed dataclasses + flat-override CLI parsing.

Every architecture in ``repro.configs`` produces a :class:`ModelConfig`;
launchers combine it with a :class:`ShapeConfig` and :class:`MeshConfig`
into a :class:`RunConfig`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # Arctic-style: a dense FFN runs in parallel with the MoE residual.
    dense_residual: bool = False
    router_jitter: float = 0.0
    capacity_factor: float = 1.25
    # ZeRO++-style int8 quantised FSDP weight gathers (halves ICI bytes;
    # straight-through custom_vjp keeps the backward identical)
    int8_gather: bool = False


@dataclass(frozen=True)
class MambaConfig:
    ssm_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256  # SSD chunked scan block


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # defaults to d_model
    local_window: int = 2048
    # repeating block pattern; "r"=recurrent, "a"=local attention
    pattern: str = "rra"
    conv_width: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 12
    decoder_layers: int = 12
    cross_kv_len: int = 1500      # whisper: 30s audio -> 1500 frames


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | encdec | rglru | mamba2
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    qkv_bias: bool = False
    act: str = "swiglu"           # swiglu | sq_relu | gelu
    sliding_window: Optional[int] = None
    rope_type: str = "rope"       # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    norm_eps: float = 1e-5
    causal: bool = True           # False -> bidirectional encoder (gte)
    tie_embeddings: bool = False
    modality: str = "text"        # text | audio | vision
    moe: MoEConfig = field(default_factory=MoEConfig)
    mamba: MambaConfig = field(default_factory=MambaConfig)
    rglru: RGLRUConfig = field(default_factory=RGLRUConfig)
    encdec: EncDecConfig = field(default_factory=EncDecConfig)
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # int8 KV cache (dense family): halves decode HBM traffic + footprint;
    # per-token-per-head scales applied on the score/probability side so
    # the cache operand feeds the MXU through free converts (see
    # EXPERIMENTS.md §Perf hillclimb 2)
    kv_quant: bool = False
    # long_500k eligibility: sub-quadratic attention path exists
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to 256 so embedding/lm_head shard on any mesh."""
        return -(-self.vocab_size // 256) * 256

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer weights)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        att = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        if self.family == "mamba2":
            m = self.mamba
            d_in = m.expand * d
            nheads = d_in // m.head_dim
            # in_proj: d -> (2*d_in + 2*ssm_state + nheads); out_proj: d_in -> d
            per = d * (2 * d_in + 2 * m.ssm_state + nheads) + d_in * d + nheads
            return emb + self.num_layers * (per + 2 * d)
        ffn_mult = 3 if self.act == "swiglu" else 2
        ffn = ffn_mult * d * self.d_ff if self.d_ff else 0
        per = att + ffn + 2 * d
        if self.family == "moe":
            e_ffn = ffn_mult * d * self.moe.expert_d_ff
            per = att + 2 * d + self.moe.num_experts * e_ffn + d * self.moe.num_experts
            if self.moe.dense_residual:
                per += ffn
        if self.family == "encdec":
            # decoder adds cross-attention
            per_dec = per + att
            return emb + self.encdec.encoder_layers * per + self.encdec.decoder_layers * per_dec
        if self.family == "rglru":
            w = self.rglru.lru_width or d
            rec = d * w * 2 + w * d + 2 * w * w + 3 * w + w * self.rglru.conv_width
            n_att = sum(1 for c in (self.rglru.pattern * self.num_layers)[: self.num_layers] if c == "a")
            n_rec = self.num_layers - n_att
            return emb + n_att * per + n_rec * (rec + ffn + 2 * d)
        return emb + self.num_layers * per

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top_k experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        ffn_mult = 3 if self.act == "swiglu" else 2
        e_ffn = ffn_mult * d * self.moe.expert_d_ff
        inactive = (self.moe.num_experts - self.moe.top_k) * e_ffn
        return self.param_count() - self.num_layers * inactive

    def reduced(self, **over: Any) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        kw: dict[str, Any] = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
        )
        if self.family == "moe":
            kw["moe"] = MoEConfig(
                num_experts=4, top_k=min(self.moe.top_k, 2), expert_d_ff=64,
                dense_residual=self.moe.dense_residual)
        if self.family == "mamba2":
            kw["mamba"] = MambaConfig(ssm_state=16, head_dim=32, expand=2,
                                      chunk_size=32)
            kw["num_heads"] = 8  # d_inner/head_dim = 256/32
        if self.family == "rglru":
            kw["rglru"] = RGLRUConfig(lru_width=128, local_window=64,
                                      pattern=self.rglru.pattern)
        if self.family == "encdec":
            kw["encdec"] = EncDecConfig(encoder_layers=2, decoder_layers=2,
                                        cross_kv_len=enc_len_for_tests())
        if self.sliding_window:
            kw["sliding_window"] = 64
        kw.update(over)
        return dataclasses.replace(self, **kw)


def enc_len_for_tests() -> int:
    return 24


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient accumulation steps
    zero3: bool = True               # shard params/opt state over data axis
    grad_compression: str = "none"   # none | int8_ef
    z_loss: float = 0.0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    seed: int = 0


def apply_overrides(cfg: Any, overrides: dict[str, Any]) -> Any:
    """Apply dotted-key overrides to nested frozen dataclasses."""
    for key, val in overrides.items():
        parts = key.split(".")
        cfg = _set_path(cfg, parts, val)
    return cfg


def _set_path(obj: Any, parts: list, val: Any) -> Any:
    if len(parts) == 1:
        fld = {f.name: f for f in dataclasses.fields(obj)}[parts[0]]
        typ = fld.type
        if isinstance(val, str):
            if typ in ("int", int):
                val = int(val)
            elif typ in ("float", float):
                val = float(val)
            elif typ in ("bool", bool):
                val = val.lower() in ("1", "true", "yes")
        return dataclasses.replace(obj, **{parts[0]: val})
    child = getattr(obj, parts[0])
    return dataclasses.replace(obj, **{parts[0]: _set_path(child, parts[1:], val)})
