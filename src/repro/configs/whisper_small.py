"""Whisper-small backbone: enc-dec transformer; conv frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]
"""
from repro.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    rope_type="none",          # whisper uses learned/sinusoidal positions
    modality="audio",
    encdec=EncDecConfig(encoder_layers=12, decoder_layers=12,
                        cross_kv_len=1500),
    subquadratic=False,
)
