"""GTE-Small-like encoder (~33M params): the paper's embedding model for
queries/documents/SCR windows (384-d sentence embeddings). [arXiv:2308.03281]
Implemented as a bidirectional (non-causal) mean-pooled encoder.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gte-small",
    family="dense",
    num_layers=12,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=30522,
    head_dim=64,
    act="gelu",
    rope_type="rope",
    causal=False,
    tie_embeddings=True,
    subquadratic=False,
)
