"""IBM Granite 3.0 1B-A400M base: 32 experts, top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe=MoEConfig(num_experts=32, top_k=8, expert_d_ff=512,
                  dense_residual=False),
    tie_embeddings=True,
    subquadratic=False,
)
