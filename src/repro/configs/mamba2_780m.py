"""Mamba2-780m: attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]  d_inner = 2*d_model = 3072, head_dim 64
-> 48 SSD heads; ssm_state 128. O(1) decode state -> long_500k runs.
"""
from repro.config import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="mamba2",
    num_layers=48,
    d_model=1536,
    num_heads=48,            # = d_inner / mamba.head_dim
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    rope_type="none",
    mamba=MambaConfig(ssm_state=128, head_dim=64, expand=2, conv_width=4,
                      chunk_size=256),
    tie_embeddings=True,
    subquadratic=True,
)
