"""Qwen2.5-0.5B-like sLM: the paper's on-device generator. [arXiv:2412.15115]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen25-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    subquadratic=False,
)
