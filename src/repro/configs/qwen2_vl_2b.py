"""Qwen2-VL-2B backbone: M-RoPE, dynamic-resolution vision frontend is a
STUB (input_specs provides precomputed patch embeddings). [arXiv:2409.12191]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    modality="vision",
    tie_embeddings=True,
    subquadratic=False,
)
