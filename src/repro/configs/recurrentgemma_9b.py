"""RecurrentGemma-9B: RG-LRU recurrent blocks + local attention, 2:1
pattern (r,r,a repeating). [arXiv:2402.19427; unverified]
Sub-quadratic: recurrent state + bounded local window -> long_500k runs.
"""
from repro.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="rglru",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    rglru=RGLRUConfig(lru_width=4096, local_window=2048, pattern="rra"),
    tie_embeddings=True,
    subquadratic=True,
)
