"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full-size :class:`ModelConfig`;
``get_reduced(arch_id)`` returns the CPU smoke-test configuration.
"""
from __future__ import annotations

import importlib

from repro.config import ModelConfig, ShapeConfig, SHAPES

ARCH_IDS = [
    "arctic_480b",
    "granite_moe_1b_a400m",
    "qwen2_72b",
    "mistral_large_123b",
    "nemotron_4_15b",
    "h2o_danube_1_8b",
    "whisper_small",
    "qwen2_vl_2b",
    "recurrentgemma_9b",
    "mamba2_780m",
]

# Paper-side models (MobileRAG's own components)
PAPER_IDS = ["gte_small", "qwen25_0_5b"]


def _norm(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch)}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return get_config(arch).reduced()


def cells(arch: str) -> list[ShapeConfig]:
    """The (arch x shape) cells this arch participates in.

    long_500k requires a sub-quadratic attention path; decode shapes are
    skipped for encoder-only archs (none assigned here).
    """
    cfg = get_config(arch)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(s)
    return out


def skipped_cells(arch: str) -> list[str]:
    cfg = get_config(arch)
    return [] if cfg.subquadratic else ["long_500k"]
