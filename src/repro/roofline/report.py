"""Aggregate dry-run JSONs into the §Roofline table (markdown + CSV)."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

HDRS = ["arch", "shape", "mesh", "chips", "t_compute_s", "t_memory_s",
        "t_collective_s", "dominant", "model_flops", "hlo_flops_total",
        "useful_ratio", "roofline_frac", "peak_GB_per_dev", "fits_16g"]


def load(outdir: Path):
    rows = []
    for f in sorted(outdir.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "error": r.get("error")})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "chips": r["chips"],
            "t_compute_s": r["t_compute_s"],
            "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "dominant": r["dominant"],
            "model_flops": r["model_flops"],
            "hlo_flops_total": r["hlo_flops_total"],
            "useful_ratio": r.get("useful_flops_ratio"),
            "roofline_frac": r.get("roofline_fraction"),
            "peak_GB_per_dev": (r.get("peak_bytes_per_device") or 0) / 1e9,
            "fits_16g": r.get("fits_hbm_16g"),
        })
    return rows


def fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def markdown(rows, mesh="pod1"):
    out = ["| " + " | ".join(HDRS) + " |",
           "|" + "---|" * len(HDRS)]
    for r in rows:
        if r.get("mesh") != mesh or "error" in r:
            continue
        out.append("| " + " | ".join(fmt(r.get(h.replace("frac", "frac"),
                                               r.get(h, "")))
                                     for h in [
            "arch", "shape", "mesh", "chips", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "model_flops", "hlo_flops_total",
            "useful_ratio", "roofline_frac", "peak_GB_per_dev", "fits_16g"])
            + " |")
    return "\n".join(out)


def interesting(rows):
    """Pick the three hillclimb cells: worst-fitting / worst roofline,
    most collective-bound, most representative of the paper (decode on
    the sLM-class generator MobileRAG serves)."""
    ok = [r for r in rows if r.get("mesh") == "pod1" and "error" not in r
          and r.get("roofline_frac")]
    over = [r for r in ok if not r.get("fits_16g", True)]
    worst = max(over, key=lambda r: r["peak_GB_per_dev"]) if over else \
        min(ok, key=lambda r: r["roofline_frac"])
    coll = max(ok, key=lambda r: r["t_collective_s"] /
               max(r["t_compute_s"], r["t_memory_s"], 1e-12))
    rep = next((r for r in ok if r["arch"] == "h2o_danube_1_8b"
                and r["shape"] == "decode_32k"), ok[0])
    return {"worst_fit_or_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    rows = load(Path(args.dir))
    print(markdown(rows, "pod1"))
    print()
    print("## multi-pod (pod2)")
    print(markdown(rows, "pod2"))
    sel = interesting(rows)
    print()
    for why, r in sel.items():
        print(f"hillclimb[{why}]: {r['arch']} x {r['shape']} "
              f"(dominant={r['dominant']}, frac={fmt(r['roofline_frac'])})")
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=HDRS + ["error"],
                               extrasaction="ignore")
            w.writeheader()
            w.writerows(rows)


if __name__ == "__main__":
    main()
