"""Structural cost analysis of post-optimization (per-device SPMD) HLO text.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
while-loop body ONCE, so anything under ``lax.scan`` (our layer stacks and
microbatch accumulation) is undercounted by the trip count (up to ~700x for
an 88-layer x 8-microbatch step). This module re-derives costs from the HLO
text itself:

  * builds the computation call graph (fusion/call/while/conditional),
  * detects while trip counts from the loop condition's ``compare(iv,
    constant(N)), direction=LT`` pattern,
  * multiplies per-computation costs by call multiplicity,
  * counts dot FLOPs exactly from shapes + contracting dims,
  * counts HBM bytes as operands+outputs per instruction (fusion internals
    excluded - they are register/VMEM-resident; dynamic-update-slice counts
    only the updated window, matching in-place TPU semantics),
  * sums collective operand bytes per opcode (also multiplied by trip
    counts - a per-layer all-gather inside a scan really happens L times).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([\w\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

# ops whose operands/outputs are not real HBM traffic
_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "iota", "partition-id", "replica-id", "copy",
             "copy-start", "copy-done",
             # XLA:CPU legalizes bf16 compute via f32 round-trips; on TPU
             # dtype converts fuse into producers/consumers.
             "convert"}


def _shape_dims(dims: str) -> Tuple[int, ...]:
    if not dims.strip():
        return ()
    return tuple(int(d) for d in dims.split(","))


def _tok_bytes(t: str, d: str) -> int:
    n = 1
    for x in _shape_dims(d):
        n *= x
    return n * DTYPE_BYTES.get(t, 4)


@dataclass
class Instr:
    name: str
    opcode: str
    out_bytes: int
    out_shape: Tuple[Tuple[str, Tuple[int, ...]], ...]
    operands: List[str]
    rhs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and line.strip().endswith("{") and " = " not in line:
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE_RE.match(rhs)
        opcode = om.group(1) if om else "?"
        # output type(s): prefix of rhs before the opcode
        head = rhs[: om.start(1)] if om else rhs.split(" ", 1)[0]
        out_types = _TYPE_RE.findall(head)
        out_bytes = sum(_tok_bytes(t, d) for t, d in out_types)
        # operand names: inside the first paren group after opcode
        p0 = rhs.find("(", om.end(1) if om else 0)
        p1 = rhs.find(")", p0) if p0 >= 0 else -1
        operands = _NAME_RE.findall(rhs[p0:p1]) if p0 >= 0 else []
        cur.instrs.append(Instr(
            name, opcode, out_bytes,
            tuple((t, _shape_dims(d)) for t, d in out_types), operands, rhs))
    return comps


def _global_shapes(comps) -> Dict[str, Instr]:
    out = {}
    for c in comps.values():
        for i in c.instrs:
            out[i.name] = i
    return out


def _trip_count(cond: Computation) -> int:
    """Detect `iv < constant(N)` loop bounds; default 1 if unknown."""
    const = None
    for i in cond.instrs:
        m = _CONST_RE.search(i.rhs)
        if m:
            const = int(m.group(1))
    for i in cond.instrs:
        if i.opcode == "compare" and "direction=LT" in i.rhs and const:
            return const
    return const or 1


def _dot_flops(instr: Instr, shapes: Dict[str, Instr]) -> int:
    out_elems = 1
    for _, dims in instr.out_shape:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rhs)
    if not m or not instr.operands:
        return 2 * out_elems  # fallback
    lhs = shapes.get(instr.operands[0])
    if lhs is None or not lhs.out_shape:
        return 2 * out_elems
    lhs_dims = lhs.out_shape[0][1]
    k = 1
    for idx in _shape_dims(m.group(1)):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2 * out_elems * k


def _conv_flops(instr: Instr, shapes: Dict[str, Instr]) -> int:
    out_elems = 1
    for _, dims in instr.out_shape:
        for d in dims:
            out_elems *= d
    rhs_op = shapes.get(instr.operands[1]) if len(instr.operands) > 1 else None
    kelems = 1
    if rhs_op and rhs_op.out_shape:
        for d in rhs_op.out_shape[0][1]:
            kelems *= d
    return 2 * out_elems * max(kelems, 1)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_n: Dict[str, float] = field(default_factory=lambda: defaultdict(float))


def _analyze_comp(comp: Computation, comps, shapes, memo) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    cost = Cost()
    memo[comp.name] = cost  # guard cycles (shouldn't exist)
    for i in comp.instrs:
        op = i.opcode
        if op in _FREE_OPS:
            continue
        coll_match = next((c for c in COLLECTIVES if op.startswith(c)), None)
        if coll_match:
            if op.endswith("-done"):
                continue
            in_bytes = sum(shapes[o].out_bytes for o in i.operands
                           if o in shapes)
            cost.coll[coll_match] += _wire_bytes(coll_match, i, in_bytes)
            cost.coll_n[coll_match] += 1
            cost.bytes += in_bytes + i.out_bytes
            continue
        if op == "fusion":
            m = _CALLS_RE.search(i.rhs)
            sub = comps.get(m.group(1)) if m else None
            if sub is not None:
                subcost = _analyze_comp(sub, comps, shapes, memo)
                cost.flops += subcost.flops  # dots inside fusions
                _merge_coll(cost, subcost, 1)
                cost.bytes += fusion_bytes(i, sub, shapes)
            else:
                cost.bytes += i.out_bytes + sum(
                    shapes[o].out_bytes for o in i.operands if o in shapes)
            continue
        if op == "while":
            body = _BODY_RE.search(i.rhs)
            cond = _COND_RE.search(i.rhs)
            trips = 1
            if cond and cond.group(1) in comps:
                trips = _trip_count(comps[cond.group(1)])
            if body and body.group(1) in comps:
                sub = _analyze_comp(comps[body.group(1)], comps, shapes, memo)
                cost.flops += trips * sub.flops
                cost.bytes += trips * sub.bytes
                _merge_coll(cost, sub, trips)
            continue
        if op in ("call", "custom-call", "conditional"):
            for rgx in (_CALLS_RE, _TOAPPLY_RE):
                m = rgx.search(i.rhs)
                if m and m.group(1) in comps:
                    sub = _analyze_comp(comps[m.group(1)], comps, shapes, memo)
                    cost.flops += sub.flops
                    cost.bytes += sub.bytes
                    _merge_coll(cost, sub, 1)
            cost.bytes += i.out_bytes + sum(
                shapes[o].out_bytes for o in i.operands if o in shapes)
            continue
        if op == "dot":
            cost.flops += _dot_flops(i, shapes)
            cost.bytes += i.out_bytes + sum(
                shapes[o].out_bytes for o in i.operands if o in shapes)
            continue
        if op == "convolution":
            cost.flops += _conv_flops(i, shapes)
            cost.bytes += i.out_bytes + sum(
                shapes[o].out_bytes for o in i.operands if o in shapes)
            continue
        if op == "dynamic-update-slice":
            upd = shapes.get(i.operands[1]) if len(i.operands) > 1 else None
            ub = upd.out_bytes if upd else i.out_bytes
            cost.bytes += 2 * ub  # in-place window write
            continue
        if op == "dynamic-slice":
            cost.bytes += 2 * i.out_bytes
            continue
        # default: elementwise / reduce / copy etc.
        cost.bytes += i.out_bytes + sum(
            shapes[o].out_bytes for o in i.operands if o in shapes)
        if op in ("reduce", "reduce-window", "sort", "scatter", "gather",
                  "select-and-scatter"):
            pass
    return cost


_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")


def _group_size(instr: Instr) -> int:
    m = _GROUP_RE.search(instr.rhs)
    if not m:
        return 2
    return max(len(m.group(1).split(",")), 1)


def _wire_bytes(kind: str, instr: Instr, in_bytes: int) -> float:
    """Per-device ICI wire traffic (ring algorithms):
      all-gather:          out*(N-1)/N  (input is the shard)
      reduce-scatter:      in*(N-1)/N
      all-reduce:          2*in*(N-1)/N
      all-to-all:          in*(N-1)/N
      collective-permute:  in
    """
    n = _group_size(instr)
    f = (n - 1) / n
    if kind == "all-gather":
        return instr.out_bytes * f
    if kind == "reduce-scatter":
        return in_bytes * f
    if kind == "all-reduce":
        return 2 * in_bytes * f
    if kind == "all-to-all":
        return in_bytes * f
    return in_bytes  # collective-permute


def _fusion_root(comp: Optional[Computation]):
    if comp is None or not comp.instrs:
        return None
    return comp.instrs[-1]  # ROOT is the last instruction in HLO text


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def fusion_bytes(instr: Instr, sub: Computation, shapes) -> int:
    """HBM traffic of one fusion call with window-access awareness:

      * a fusion parameter consumed ONLY by dynamic-slice ops is billed at
        the window sizes (TPU reads just the windows),
      * a parameter that is only the in-place base of the root
        dynamic-update-slice is billed 0 (aliased),
      * a root dynamic-update-slice (possibly behind convert/copy) bills
        the update window, not the full output.
    """
    sub_map = {i.name: i for i in sub.instrs}
    uses: dict = defaultdict(list)
    for ins in sub.instrs:
        for o in ins.operands:
            uses[o].append(ins)
    PASS = ("convert", "copy", "bitcast")

    def effective_uses(name):
        """Consumers, looking through dtype/layout pass-through ops."""
        out = []
        stack = list(uses.get(name, []))
        seen = set()
        while stack:
            c = stack.pop()
            if c.name in seen:
                continue
            seen.add(c.name)
            if c.opcode in PASS:
                stack.extend(uses.get(c.name, []))
            else:
                out.append(c)
        return out

    def resolve(name):
        """Producer, looking through pass-through ops."""
        ins = sub_map.get(name)
        while ins is not None and ins.opcode in PASS and ins.operands:
            ins = sub_map.get(ins.operands[0])
        return ins

    root = resolve(sub.instrs[-1].name) or sub.instrs[-1]
    root_is_dus = root.opcode == "dynamic-update-slice"
    dus_base = resolve(root.operands[0]) if root_is_dus and root.operands \
        else None

    total = 0
    for p in sub.instrs:
        if p.opcode != "parameter":
            continue
        m = _PARAM_IDX_RE.search(p.rhs)
        k = int(m.group(1)) if m else -1
        opname = instr.operands[k] if 0 <= k < len(instr.operands) else None
        full = shapes[opname].out_bytes if opname in shapes else p.out_bytes
        cons = effective_uses(p.name)
        if root_is_dus and dus_base is not None and dus_base.name == p.name \
                and all(c is root for c in cons):
            continue  # in-place DUS base: aliased, no traffic
        if cons and all(c.opcode == "dynamic-slice" for c in cons):
            # windowed reads only: bill window sizes
            total += sum(c.out_bytes for c in cons)
        else:
            total += full
    if root_is_dus:
        upd = (resolve(root.operands[1]) if len(root.operands) > 1 else None)
        total += upd.out_bytes if upd is not None else instr.out_bytes
    else:
        total += instr.out_bytes
    return total


def _merge_coll(dst: Cost, src: Cost, mult: float):
    for k, v in src.coll.items():
        dst.coll[k] += mult * v
    for k, v in src.coll_n.items():
        dst.coll_n[k] += mult * v


def _entry_name(comps: Dict[str, Computation], text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    return m.group(1) if m else None


def structural_cost(hlo_text: str) -> dict:
    """Full-module cost with loop trip counts applied."""
    comps = parse_module(hlo_text)
    shapes = _global_shapes(comps)
    entry = _entry_name(comps, hlo_text)
    if entry is None or entry not in comps:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    memo: dict = {}
    cost = _analyze_comp(comps[entry], comps, shapes, memo)
    coll_total = sum(cost.coll.values())
    out = {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_total": coll_total,
        "collective_ops": sum(cost.coll_n.values()),
    }
    for k, v in cost.coll.items():
        out[f"coll_{k}"] = v
        out[f"n_{k}"] = cost.coll_n[k]
    return out


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Back-compat wrapper returning the collective summary only."""
    c = structural_cost(hlo_text)
    res = {k[5:]: v for k, v in c.items() if k.startswith("coll_")}
    res["total"] = c["collective_total"]
    res["ops"] = c["collective_ops"]
    for k, v in c.items():
        if k.startswith("n_"):
            res[k] = v
    return res
