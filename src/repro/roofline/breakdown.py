"""Per-op byte/flop breakdown of a dry-run cell — the 'profile' used by the
§Perf hypothesis loop (no wall-clock on CPU; structure is the profile)."""
from __future__ import annotations

import argparse
from collections import defaultdict

from repro.roofline import hlo as H


def breakdown(hlo_text: str, top: int = 25):
    comps = H.parse_module(hlo_text)
    shapes = H._global_shapes(comps)
    entry = H._entry_name(comps, hlo_text)
    # compute call multiplicity per computation
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        for ins in comp.instrs:
            trips = 1
            subs = []
            if ins.opcode == "while":
                b = H._BODY_RE.search(ins.rhs)
                c = H._COND_RE.search(ins.rhs)
                if c and c.group(1) in comps:
                    trips = H._trip_count(comps[c.group(1)])
                if b:
                    subs.append(b.group(1))
            else:
                for rgx in (H._CALLS_RE, H._TOAPPLY_RE):
                    m = rgx.search(ins.rhs)
                    if m:
                        subs.append(m.group(1))
            for s in subs:
                if s in comps:
                    mult[s] += mult[cname] * trips
                    if s not in seen:
                        seen.add(s)
                        order.append(s)

    per_op_bytes = defaultdict(float)
    per_instr = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0:
            continue
        for ins in comp.instrs:
            op = ins.opcode
            if op in H._FREE_OPS or op in ("while", "call", "conditional"):
                continue
            if op == "dynamic-update-slice":
                upd = shapes.get(ins.operands[1]) if len(ins.operands) > 1 \
                    else None
                b = 2 * (upd.out_bytes if upd else ins.out_bytes)
            elif op == "dynamic-slice":
                b = 2 * ins.out_bytes
            elif op == "fusion":
                mm = H._CALLS_RE.search(ins.rhs)
                sub = comps.get(mm.group(1)) if mm else None
                if sub is not None:
                    b = H.fusion_bytes(ins, sub, shapes)
                else:
                    b = ins.out_bytes + sum(shapes[o].out_bytes
                                            for o in ins.operands
                                            if o in shapes)
            else:
                b = ins.out_bytes + sum(shapes[o].out_bytes
                                        for o in ins.operands if o in shapes)
            per_op_bytes[op] += m * b
            per_instr.append((m * b, m, ins.name, op,
                              ins.rhs[:110]))
    print("== bytes by opcode ==")
    for op, b in sorted(per_op_bytes.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{op:28s} {b/1e9:10.2f} GB")
    print("\n== top instructions (bytes x trips) ==")
    for b, m, name, op, rhs in sorted(per_instr, key=lambda x: -x[0])[:top]:
        print(f"{b/1e9:9.2f} GB x{m:7.0f} {op:22s} {rhs}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo_file")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    breakdown(open(args.hlo_file).read(), args.top)


if __name__ == "__main__":
    main()
