"""The paper's seven retrieval baselines (§3.4, Tables 1-2, Figures 6-10):
IVF, IVFPQ, HNSW, HNSWPQ, IVF-DISK, IVFPQ-DISK, IVF-HNSW.

Common interface: build / search / insert / delete / ram_bytes, plus a
`stats` counter of distance ops and disk traffic so the power model
(§3.4.3) can be evaluated per search.
"""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import store
from repro.core.hnsw import HNSW
from repro.core.kmeans import kmeans
from repro.core.pq import PQ


@dataclass
class SearchStats:
    distance_ops: int = 0
    disk_loads: int = 0
    disk_bytes: int = 0
    disk_time_s: float = 0.0

    def reset(self):
        self.distance_ops = 0
        self.disk_loads = 0
        self.disk_bytes = 0
        self.disk_time_s = 0.0


def _topk(ids, d2, k):
    order = np.argsort(d2)[:k]
    return ids[order].astype(np.int64), d2[order].astype(np.float32)


class _ClusteredBase:
    """Shared IVF machinery: k-means + inverted lists."""

    def __init__(self, dim, n_clusters=64, seed=0):
        self.dim = dim
        self.n_clusters = n_clusters
        self.seed = seed
        self.centroids: Optional[np.ndarray] = None
        self.lists: List[np.ndarray] = []      # ids per cluster
        self.stats = SearchStats()

    def _partition(self, vectors, ids):
        self.centroids, assign = kmeans(vectors, min(self.n_clusters,
                                                     len(vectors)),
                                        seed=self.seed)
        self.n_clusters = self.centroids.shape[0]
        self.lists = [ids[assign == c] for c in range(self.n_clusters)]
        return assign

    def _probe(self, q, n_probe):
        d2 = np.sum((self.centroids - q) ** 2, axis=1)
        self.stats.distance_ops += self.n_clusters
        return np.argsort(d2)[:n_probe]

    def _nearest_cluster(self, vec):
        return int(np.argmin(np.sum((self.centroids - vec) ** 2, axis=1)))


class IVF(_ClusteredBase):
    name = "IVF"
    on_disk = False

    def build(self, vectors, ids=None):
        vectors = np.asarray(vectors, np.float32)
        ids = np.arange(len(vectors), dtype=np.int64) if ids is None else ids
        self._partition(vectors, ids)
        self.vecs: Dict[int, np.ndarray] = {int(i): v for i, v in
                                            zip(ids, vectors)}
        return self

    def _cluster_vectors(self, c):
        ids = self.lists[c]
        return ids, np.stack([self.vecs[int(i)] for i in ids]) \
            if len(ids) else (ids, np.zeros((0, self.dim), np.float32))

    def search(self, q, k=10, n_probe=4, **kw):
        q = np.asarray(q, np.float32)
        probes = self._probe(q, n_probe)
        all_ids, all_d = [], []
        for c in probes:
            ids = self.lists[c]
            if not len(ids):
                continue
            vecs = np.stack([self.vecs[int(i)] for i in ids])
            d2 = np.sum((vecs - q) ** 2, axis=1)
            self.stats.distance_ops += len(ids)
            all_ids.append(ids)
            all_d.append(d2)
        if not all_ids:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        return _topk(np.concatenate(all_ids), np.concatenate(all_d), k)

    def insert(self, vid, vec):
        c = self._nearest_cluster(vec)
        self.lists[c] = np.append(self.lists[c], vid)
        self.vecs[int(vid)] = np.asarray(vec, np.float32)

    def delete(self, vid):
        for c in range(self.n_clusters):
            m = self.lists[c] != vid
            if m.sum() != len(self.lists[c]):
                self.lists[c] = self.lists[c][m]
        self.vecs.pop(int(vid), None)

    def ram_bytes(self):
        n = len(self.vecs)
        return (self.n_clusters * self.dim * 4 + n * 8 + n * self.dim * 4)


class IVFPQ(IVF):
    name = "IVFPQ"

    def __init__(self, dim, n_clusters=64, m_pq=8, nbits=8, seed=0):
        super().__init__(dim, n_clusters, seed)
        self.pq = PQ(dim, m_pq, nbits)

    def build(self, vectors, ids=None):
        vectors = np.asarray(vectors, np.float32)
        ids = np.arange(len(vectors), dtype=np.int64) if ids is None else ids
        self._partition(vectors, ids)
        self.pq.train(vectors[np.random.default_rng(0).choice(
            len(vectors), min(len(vectors), 4096), replace=False)])
        self.codes: Dict[int, np.ndarray] = {
            int(i): c for i, c in zip(ids, self.pq.encode(vectors))}
        return self

    def search(self, q, k=10, n_probe=4, **kw):
        q = np.asarray(q, np.float32)
        probes = self._probe(q, n_probe)
        tabs = self.pq.adc_table(q)
        all_ids, all_d = [], []
        for c in probes:
            ids = self.lists[c]
            if not len(ids):
                continue
            codes = np.stack([self.codes[int(i)] for i in ids])
            d = tabs[np.arange(self.pq.m)[None, :],
                     codes.astype(np.int64)].sum(axis=1)
            self.stats.distance_ops += len(ids)
            all_ids.append(ids)
            all_d.append(d)
        if not all_ids:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        return _topk(np.concatenate(all_ids), np.concatenate(all_d), k)

    def insert(self, vid, vec):
        c = self._nearest_cluster(vec)
        self.lists[c] = np.append(self.lists[c], vid)
        self.codes[int(vid)] = self.pq.encode(vec[None])[0]

    def delete(self, vid):
        super().delete(vid)
        self.codes.pop(int(vid), None)

    def ram_bytes(self):
        n = len(self.codes)
        return (self.n_clusters * self.dim * 4 + n * 8
                + n * self.pq.m * self.pq.nbits // 8
                + self.pq.ksub * self.dim * 4)


class HNSWIndex:
    name = "HNSW"
    on_disk = False

    def __init__(self, dim, M=16, ef_construction=100, seed=0, **kw):
        self.dim = dim
        self.g = HNSW(dim, M=M, ef_construction=ef_construction, seed=seed)
        self.stats = SearchStats()

    def build(self, vectors, ids=None):
        vectors = np.asarray(vectors, np.float32)
        ids = np.arange(len(vectors), dtype=np.int64) if ids is None else ids
        for i, v in zip(ids, vectors):
            self.g.insert(int(i), v)
        return self

    def search(self, q, k=10, ef_search=64, **kw):
        ids, d = self.g.search(np.asarray(q, np.float32), k, ef_search)
        self.stats.distance_ops += ef_search * self.g.M
        return ids, d

    def insert(self, vid, vec):
        self.g.insert(int(vid), np.asarray(vec, np.float32))

    def delete(self, vid):
        self.g.delete(int(vid))

    def ram_bytes(self):
        return self.g.memory_bytes()


class HNSWPQ(HNSWIndex):
    name = "HNSWPQ"

    def __init__(self, dim, M=16, ef_construction=100, m_pq=8, nbits=8,
                 seed=0):
        super().__init__(dim, M, ef_construction, seed)
        self.pq = PQ(dim, m_pq, nbits)
        self.codes: Dict[int, np.ndarray] = {}

    def build(self, vectors, ids=None):
        vectors = np.asarray(vectors, np.float32)
        ids = np.arange(len(vectors), dtype=np.int64) if ids is None else ids
        self.pq.train(vectors[np.random.default_rng(0).choice(
            len(vectors), min(len(vectors), 4096), replace=False)])
        # graph built over reconstructed (quantised) vectors
        recon = self.pq.decode(self.pq.encode(vectors))
        for i, v, c in zip(ids, recon, self.pq.encode(vectors)):
            self.g.insert(int(i), v)
            self.codes[int(i)] = c
        return self

    def ram_bytes(self):
        n = len(self.codes)
        links = self.g.memory_bytes() - len(self.g) * self.dim * 4
        return (n * self.pq.m * self.pq.nbits // 8 + links
                + self.pq.ksub * self.dim * 4)


class _DiskListMixin:
    """Store inverted lists (vectors or codes) on real disk files.

    Lists go through `core/store.py`: checksummed segments written with
    the atomic tmp→fsync→rename protocol. The old in-place
    ``pickle.dump`` destroyed the previous list if the process died
    mid-write; now a crash leaves the prior file intact, and a
    truncated/bit-flipped list raises `store.CorruptSegmentError`
    instead of feeding garbage to pickle."""

    LIST_KIND = "ivf.list"

    def _init_disk(self, tag):
        self.storage_dir = tempfile.mkdtemp(prefix=f"{tag}_")
        self.on_disk = True

    def _lpath(self, c):
        return os.path.join(self.storage_dir, f"list_{c:05d}.bin")

    def _store_list(self, c, payload):
        store.dump_obj(self._lpath(c), payload, kind=self.LIST_KIND)

    def _load_list(self, c):
        t0 = time.perf_counter()
        payload = store.load_obj(self._lpath(c), kind=self.LIST_KIND)
        self.stats.disk_loads += 1
        self.stats.disk_bytes += os.path.getsize(self._lpath(c))
        self.stats.disk_time_s += time.perf_counter() - t0
        return payload


class IVFDisk(_ClusteredBase, _DiskListMixin):
    name = "IVF-DISK"

    def __init__(self, dim, n_clusters=64, seed=0):
        super().__init__(dim, n_clusters, seed)
        self._init_disk("ivfdisk")

    def build(self, vectors, ids=None):
        vectors = np.asarray(vectors, np.float32)
        ids = np.arange(len(vectors), dtype=np.int64) if ids is None else ids
        assign = self._partition(vectors, ids)
        for c in range(self.n_clusters):
            m = assign == c
            self._store_list(c, (ids[m], vectors[m]))
        self.n_total = len(vectors)
        return self

    def search(self, q, k=10, n_probe=4, **kw):
        q = np.asarray(q, np.float32)
        probes = self._probe(q, n_probe)
        all_ids, all_d = [], []
        for c in probes:
            lids, lvecs = self._load_list(int(c))
            if not len(lids):
                continue
            d2 = np.sum((lvecs - q) ** 2, axis=1)
            self.stats.distance_ops += len(lids)
            all_ids.append(lids)
            all_d.append(d2)
        if not all_ids:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        return _topk(np.concatenate(all_ids), np.concatenate(all_d), k)

    def insert(self, vid, vec):
        c = self._nearest_cluster(vec)
        lids, lvecs = self._load_list(c)
        self._store_list(c, (np.append(lids, vid),
                             np.vstack([lvecs, vec[None]])))
        self.lists[c] = np.append(self.lists[c], vid)
        self.n_total += 1

    def delete(self, vid):
        for c in range(self.n_clusters):
            if vid in self.lists[c]:
                lids, lvecs = self._load_list(c)
                m = lids != vid
                self._store_list(c, (lids[m], lvecs[m]))
                self.lists[c] = self.lists[c][m]
                self.n_total -= 1
                return

    def ram_bytes(self):
        # centroids + ids + one loaded list (Table 1 IVF-DISK row)
        avg = int(np.mean([len(l) for l in self.lists])) if self.lists else 0
        return (self.n_clusters * self.dim * 4 + self.n_total * 8
                + avg * self.dim * 4)


class IVFPQDisk(IVFPQ, _DiskListMixin):
    name = "IVFPQ-DISK"

    def __init__(self, dim, n_clusters=64, m_pq=8, nbits=8, seed=0):
        super().__init__(dim, n_clusters, m_pq, nbits, seed)
        self._init_disk("ivfpqdisk")

    def build(self, vectors, ids=None):
        super().build(vectors, ids)
        for c in range(self.n_clusters):
            lids = self.lists[c]
            codes = (np.stack([self.codes[int(i)] for i in lids])
                     if len(lids) else np.zeros((0, self.pq.m), np.uint8))
            self._store_list(c, (lids, codes))
        self.codes = {}  # codes live on disk now
        return self

    def search(self, q, k=10, n_probe=4, **kw):
        q = np.asarray(q, np.float32)
        probes = self._probe(q, n_probe)
        tabs = self.pq.adc_table(q)
        all_ids, all_d = [], []
        for c in probes:
            lids, codes = self._load_list(int(c))
            if not len(lids):
                continue
            d = tabs[np.arange(self.pq.m)[None, :],
                     codes.astype(np.int64)].sum(axis=1)
            self.stats.distance_ops += len(lids)
            all_ids.append(lids)
            all_d.append(d)
        if not all_ids:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        return _topk(np.concatenate(all_ids), np.concatenate(all_d), k)

    def ram_bytes(self):
        n = sum(len(l) for l in self.lists)
        avg = int(np.mean([len(l) for l in self.lists])) if self.lists else 0
        return (self.n_clusters * self.dim * 4 + n * 8
                + avg * self.pq.m * self.pq.nbits // 8
                + self.pq.ksub * self.dim * 4)


class IVFHNSW(IVFDisk):
    """Centroid HNSW + flat inverted lists on disk."""
    name = "IVF-HNSW"

    def build(self, vectors, ids=None):
        super().build(vectors, ids)
        self.centroid_graph = HNSW(self.dim, M=self.M_cent,
                                   ef_construction=64, seed=self.seed,
                                   max_elements=self.n_clusters)
        for c in range(self.n_clusters):
            self.centroid_graph.insert(c, self.centroids[c])
        return self

    def __init__(self, dim, n_clusters=64, M_cent=16, seed=0):
        super().__init__(dim, n_clusters, seed)
        self.M_cent = M_cent

    def _probe(self, q, n_probe):
        cids, _ = self.centroid_graph.search(q, n_probe,
                                             ef_search=max(16, 2 * n_probe))
        self.stats.distance_ops += 16 * self.M_cent
        return cids

    def ram_bytes(self):
        avg = int(np.mean([len(l) for l in self.lists])) if self.lists else 0
        return (self.centroid_graph.memory_bytes() + self.n_total * 8
                + avg * self.dim * 4)


def make_index(name: str, dim: int, **kw):
    table = {
        "IVF": IVF, "IVFPQ": IVFPQ, "HNSW": HNSWIndex, "HNSWPQ": HNSWPQ,
        "IVF-DISK": IVFDisk, "IVFPQ-DISK": IVFPQDisk, "IVF-HNSW": IVFHNSW,
    }
    if name == "EcoVector":
        from repro.core.ecovector import EcoVector
        return EcoVector(dim, **kw)
    return table[name](dim, **kw)


ALL_BASELINES = ["IVF", "IVFPQ", "HNSW", "HNSWPQ", "IVF-DISK", "IVFPQ-DISK",
                 "IVF-HNSW", "EcoVector"]
