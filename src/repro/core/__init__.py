"""MobileRAG core: EcoVector index, SCR, baselines, analytical models."""
from repro.core.ecovector import EcoVector  # noqa: F401
from repro.core.scr import SCRConfig, apply_scr, build_prompt  # noqa: F401
