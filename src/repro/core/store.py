"""Durable retrieval state: checksummed segments, atomic commits, WAL.

On a phone, power loss mid-write and bit-rot are the common case, not the
exception — the paper's "partition and partially load" thesis assumes the
on-flash index is trustworthy, so this module makes it so (DESIGN.md §12):

  * **Checksummed segment files** — every spilled blob (cluster graphs,
    inverted lists, index state) is framed as magic + version + JSON meta
    + per-record CRC32. `read_segment` refuses anything truncated,
    bit-flipped, or foreign with `CorruptSegmentError`; raw `pickle.loads`
    of untagged bytes no longer exists anywhere in the retrieval stack.
  * **Atomic writes** — segments stage to `path + ".tmp"`, fsync, then
    `os.replace`; a crash mid-write can only ever leave the previous file
    (or nothing), never a torn one.
  * **Generation-numbered snapshots** (`Journal`) — a full index save is
    a `gen_XXXXXXXX/` directory with a `MANIFEST.json` of per-file CRCs,
    committed with the same stage→rename protocol as
    `dist/checkpoint.py`'s step dirs (whose commit/list primitives —
    `atomic_replace_dir` / `numbered_dirs` — now live here and are reused
    by the checkpointer). Readers only trust directories whose manifest
    exists at the final path.
  * **A write-ahead log** per generation (`wal_XXXXXXXX.log`) — an
    incremental mutation is appended + fsync'd *before* it is applied, so
    every acknowledged `insert`/`delete`/`add`/`update`/`remove` survives
    kill -9; load replays the WAL on top of the generation, and the next
    `save()` (compaction) folds it into a new generation and rotates the
    log. A torn tail (crash mid-append) is discarded silently — by
    construction it was never acknowledged.

Crash points are observable: every durability-relevant filesystem step
calls `_fs_event(name)`, which `core/store_faults.py` hooks to inject
deterministic op-indexed crashes (in-process raise or hard `os._exit`,
the latter armed by the ``REPRO_STORE_CRASH_AT`` env var for subprocess
kill-9 tests). This module deliberately has no jax dependency.
"""
from __future__ import annotations

import json
import os
import pickle
import re
import shutil
import struct
import zlib
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

MAGIC = b"RSG1"          # repro segment, format v1
WAL_MAGIC = b"RWL1"      # repro write-ahead log, format v1
VERSION = 1
_HDR = struct.Struct("<4sHHII")    # magic, version, flags, meta_len, meta_crc
_REC = struct.Struct("<QI")        # record length, record crc32
_WAL_HDR = struct.Struct("<4sHHQ")  # magic, version, flags, generation
_WAL_REC = struct.Struct("<II")    # frame length, frame crc32

MANIFEST = "MANIFEST.json"
GEN_PREFIX = "gen_"
_GEN_RE = re.compile(r"^gen_(\d{8})$")


class StoreError(Exception):
    """Base class for durable-store failures."""


class CorruptSegmentError(StoreError):
    """A file failed magic/version/length/CRC validation (bit-rot,
    truncation, or a foreign file where a segment was expected)."""


# --------------------------------------------------------------- crash hooks

_crash_hook: Optional[Callable[[str, int], None]] = None
_fs_ops = 0


def set_crash_hook(fn: Optional[Callable[[str, int], None]]) -> None:
    """Install (or clear) the fault-injection hook. The hook receives
    (event_name, op_index) before each durability-relevant fs step and
    may raise or `os._exit` to simulate a crash at exactly that point."""
    global _crash_hook
    _crash_hook = fn


def reset_fs_ops() -> None:
    global _fs_ops
    _fs_ops = 0


def fs_ops() -> int:
    return _fs_ops


def _fs_event(name: str) -> None:
    global _fs_ops
    _fs_ops += 1
    if _crash_hook is not None:
        _crash_hook(name, _fs_ops)


def _env_crash_hook() -> None:
    """Arm a hard-exit crash hook from the environment — the subprocess
    kill-9 harness sets REPRO_STORE_CRASH_AT=<n> (and optionally
    REPRO_STORE_CRASH_EXIT=<code>) so the Nth fs op terminates the
    process without cleanup, exactly like a power cut."""
    at = int(os.environ.get("REPRO_STORE_CRASH_AT", "0") or 0)
    if at <= 0:
        return
    code = int(os.environ.get("REPRO_STORE_CRASH_EXIT", "42"))

    def hook(name: str, count: int) -> None:
        if count >= at:
            os._exit(code)

    set_crash_hook(hook)


_env_crash_hook()


def _fsync_dir(path: str) -> None:
    """Best-effort directory fsync so a rename survives power loss."""
    try:
        fd = os.open(path or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


# ------------------------------------------------------------ segment format

def _encode_segment(records: List[bytes], meta: Dict[str, Any]) -> bytes:
    mb = json.dumps(meta, sort_keys=True).encode()
    out = [_HDR.pack(MAGIC, VERSION, 0, len(mb), zlib.crc32(mb)), mb,
           struct.pack("<I", len(records))]
    for r in records:
        out.append(_REC.pack(len(r), zlib.crc32(r)))
        out.append(r)
    return b"".join(out)


def write_segment(path: str, records: List[bytes],
                  meta: Optional[Dict[str, Any]] = None, *,
                  kind: str = "blob") -> None:
    """Atomically write a checksummed segment: stage to `.tmp`, fsync,
    rename over `path`, fsync the directory. A crash at any point leaves
    either the previous file or the new one — never a torn mix."""
    meta = dict(meta or {})
    meta.setdefault("kind", kind)
    blob = _encode_segment(records, meta)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        _fs_event("segment.write")
        f.flush()
        os.fsync(f.fileno())
    _fs_event("segment.fsync")
    os.replace(tmp, path)
    _fs_event("segment.rename")
    _fsync_dir(os.path.dirname(path))


def atomic_write_bytes(path: str, blob: bytes) -> None:
    """Atomically replace `path` with raw bytes (no segment framing):
    stage to `.tmp`, fsync, rename, fsync the directory. For payloads
    whose integrity is tracked externally (e.g. the tiered cold pack,
    whose per-cluster CRCs live in a companion manifest segment)."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        _fs_event("raw.write")
        f.flush()
        os.fsync(f.fileno())
    _fs_event("raw.fsync")
    os.replace(tmp, path)
    _fs_event("raw.rename")
    _fsync_dir(os.path.dirname(path))


def decode_segment(blob: bytes,
                   path: str = "<bytes>") -> Tuple[Dict[str, Any],
                                                   List[bytes]]:
    """Validate and decode segment bytes (magic, version, meta CRC, every
    record CRC, exact length). Raises CorruptSegmentError on anything
    short of a byte-perfect segment."""
    def bad(reason: str) -> CorruptSegmentError:
        return CorruptSegmentError(f"{path}: {reason}")

    if len(blob) < _HDR.size:
        raise bad(f"truncated header ({len(blob)} bytes)")
    magic, ver, flags, mlen, mcrc = _HDR.unpack_from(blob, 0)
    if magic != MAGIC:
        raise bad(f"bad magic {magic!r} (expected {MAGIC!r})")
    if ver != VERSION:
        raise bad(f"unsupported segment version {ver}")
    if flags != 0:
        # no flags are defined in v1; a nonzero value is either a newer
        # writer or a bit-flip in the (un-CRC'd) header — refuse both
        raise bad(f"unsupported flags 0x{flags:04x}")
    off = _HDR.size
    if len(blob) < off + mlen + 4:
        raise bad("truncated metadata")
    mb = blob[off:off + mlen]
    if zlib.crc32(mb) != mcrc:
        raise bad("metadata CRC mismatch")
    try:
        meta = json.loads(mb.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise bad(f"metadata undecodable: {e}") from None
    off += mlen
    (nrec,) = struct.unpack_from("<I", blob, off)
    off += 4
    records: List[bytes] = []
    for i in range(nrec):
        if len(blob) < off + _REC.size:
            raise bad(f"truncated at record {i} header")
        rlen, rcrc = _REC.unpack_from(blob, off)
        off += _REC.size
        if len(blob) < off + rlen:
            raise bad(f"truncated at record {i} payload "
                      f"({len(blob) - off} of {rlen} bytes)")
        payload = blob[off:off + rlen]
        if zlib.crc32(payload) != rcrc:
            raise bad(f"record {i} CRC mismatch")
        records.append(payload)
        off += rlen
    if off != len(blob):
        raise bad(f"{len(blob) - off} trailing bytes after last record")
    return meta, records


def read_segment(path: str,
                 kind: Optional[str] = None) -> Tuple[Dict[str, Any],
                                                      List[bytes]]:
    """Read + fully validate a segment file. `kind` (when given) must
    match the writer's, so a cluster file can't be fed where an index
    manifest was expected."""
    with open(path, "rb") as f:
        blob = f.read()
    meta, records = decode_segment(blob, path)
    if kind is not None and meta.get("kind") != kind:
        raise CorruptSegmentError(
            f"{path}: kind {meta.get('kind')!r} where {kind!r} expected")
    return meta, records


def verify_segment(path: str, kind: Optional[str] = None) -> bytes:
    """Validate a segment file and return its raw bytes (used when
    copying spill files into a generation snapshot: the copy is refused
    if the source no longer checks out)."""
    with open(path, "rb") as f:
        blob = f.read()
    meta, _ = decode_segment(blob, path)
    if kind is not None and meta.get("kind") != kind:
        raise CorruptSegmentError(
            f"{path}: kind {meta.get('kind')!r} where {kind!r} expected")
    return blob


def dump_obj(path: str, obj: Any, *, kind: str = "pickle") -> None:
    """Atomic, checksummed replacement for a bare ``pickle.dump`` to a
    path (single-record segment)."""
    write_segment(path,
                  [pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)],
                  kind=kind)


def load_obj(path: str, *, kind: Optional[str] = None) -> Any:
    """Validated replacement for a bare ``pickle.loads`` of a file:
    magic + length + CRC are checked before any byte reaches pickle."""
    _, records = read_segment(path, kind=kind)
    if len(records) != 1:
        raise CorruptSegmentError(
            f"{path}: expected 1 record, found {len(records)}")
    return pickle.loads(records[0])


def array_record(a: np.ndarray) -> Tuple[bytes, Dict[str, Any]]:
    """(payload bytes, spec) for storing a numpy array as one record."""
    a = np.ascontiguousarray(a)
    return a.tobytes(), {"dtype": str(a.dtype), "shape": list(a.shape)}


def record_array(payload: bytes, spec: Dict[str, Any]) -> np.ndarray:
    a = np.frombuffer(payload, dtype=np.dtype(spec["dtype"]))
    expect = int(np.prod(spec["shape"])) if spec["shape"] else 1
    if a.size != expect:
        raise CorruptSegmentError(
            f"array record: {a.size} elements where shape "
            f"{spec['shape']} implies {expect}")
    return a.reshape(spec["shape"]).copy()


# ------------------------------------------------- atomic dir commit helpers

def atomic_replace_dir(tmp: str, final: str) -> None:
    """Commit a fully-staged directory over `final` with one rename
    (removing a previous `final` first — re-commit of the same number).
    Shared by Journal generations and dist/checkpoint step dirs."""
    if os.path.isdir(final):
        shutil.rmtree(final)
    _fs_event("dir.replace")
    os.replace(tmp, final)
    _fs_event("dir.replaced")
    _fsync_dir(os.path.dirname(final))


def numbered_dirs(root: str, prefix: str, gate_file: str) -> List[int]:
    """Committed `<prefix>NNNNNNNN` directories under `root`, ascending.
    Only directories containing `gate_file` count — a crash mid-commit
    leaves at worst a `.tmp` (or a gate-less dir) that is ignored."""
    if not os.path.isdir(root):
        return []
    pat = re.compile(r"^" + re.escape(prefix) + r"(\d{8})$")
    out = []
    for name in os.listdir(root):
        m = pat.match(name)
        if not m:
            continue
        if not os.path.isfile(os.path.join(root, name, gate_file)):
            continue
        out.append(int(m.group(1)))
    return sorted(out)


# ------------------------------------------------------------ write-ahead log

class WriteAheadLog:
    """Append-only, CRC-framed mutation log. `append` is durable when it
    returns (frame written + flushed + fsync'd); `replay` yields every
    intact frame and silently discards a torn tail — a torn record was
    by definition never acknowledged."""

    def __init__(self, path: str, generation: int = 0):
        self.path = path
        self.generation = generation
        self._f = None

    def append(self, payload: bytes) -> None:
        if self._f is None:
            fresh = (not os.path.exists(self.path)
                     or os.path.getsize(self.path) == 0)
            self._f = open(self.path, "ab")
            if fresh:
                self._f.write(_WAL_HDR.pack(WAL_MAGIC, VERSION, 0,
                                            self.generation))
        frame = _WAL_REC.pack(len(payload), zlib.crc32(payload)) + payload
        self._f.write(frame)
        _fs_event("wal.write")
        self._f.flush()
        os.fsync(self._f.fileno())
        _fs_event("wal.fsync")

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    @staticmethod
    def replay(path: str) -> Tuple[List[bytes], bool]:
        """(intact frames, torn_tail). A missing/empty/torn-header log
        replays as no ops: nothing in it was ever acknowledged."""
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except OSError:
            return [], False
        if len(blob) < _WAL_HDR.size:
            return [], len(blob) > 0
        magic, ver, _flags, _gen = _WAL_HDR.unpack_from(blob, 0)
        if magic != WAL_MAGIC or ver != VERSION:
            return [], True
        off = _WAL_HDR.size
        ops: List[bytes] = []
        while off < len(blob):
            if len(blob) < off + _WAL_REC.size:
                return ops, True
            rlen, rcrc = _WAL_REC.unpack_from(blob, off)
            off += _WAL_REC.size
            if len(blob) < off + rlen:
                return ops, True
            payload = blob[off:off + rlen]
            if zlib.crc32(payload) != rcrc:
                # nothing after a corrupt frame can be trusted
                return ops, True
            ops.append(payload)
            off += rlen
        return ops, False


# ------------------------------------------------------- generation journal

class Journal:
    """Generation-numbered snapshot directory + per-generation WAL.

    Layout under `root`::

        gen_00000000/           committed snapshot (MANIFEST.json gate)
        gen_00000001.tmp/       crashed partial commit (ignored)
        wal_00000001.log        mutations since gen 1 was committed

    `begin()` stages a tmp dir the caller fills with files; `commit()`
    writes a manifest of per-file CRC32s, renames the dir into place and
    rotates the WAL (mutations folded into the new generation are
    dropped). `append()`/`replay()` journal mutations against the
    current generation."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._staged: Optional[Tuple[int, str]] = None
        self._wal: Optional[WriteAheadLog] = None
        self._gen: Optional[int] = self.latest()

    # ------------------------------------------------------------ naming

    def gen_dir(self, g: int) -> str:
        return os.path.join(self.root, f"{GEN_PREFIX}{g:08d}")

    def wal_path(self, g: int) -> str:
        return os.path.join(self.root, f"wal_{g:08d}.log")

    def generations(self) -> List[int]:
        return numbered_dirs(self.root, GEN_PREFIX, MANIFEST)

    def latest(self) -> Optional[int]:
        gens = self.generations()
        return gens[-1] if gens else None

    @property
    def generation(self) -> Optional[int]:
        return self._gen

    # ----------------------------------------------------------- snapshot

    def begin(self) -> str:
        """Stage the next generation; returns the tmp dir to fill. A
        stale tmp from a crashed previous commit is discarded."""
        g = (self.latest() if self.latest() is not None else -1) + 1
        tmp = self.gen_dir(g) + ".tmp"
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        self._staged = (g, tmp)
        return tmp

    def commit(self) -> int:
        """Manifest + atomic rename + WAL rotation. Crash before the
        rename: loader keeps the previous generation + its full WAL (no
        acknowledged op lost). Crash after: the new generation already
        contains every folded op, the stale WAL is ignored by name and
        cleaned up on the next commit."""
        if self._staged is None:
            raise StoreError("commit() without begin()")
        g, tmp = self._staged
        files = {}
        for name in sorted(os.listdir(tmp)):
            p = os.path.join(tmp, name)
            with open(p, "rb") as f:
                blob = f.read()
            files[name] = {"size": len(blob), "crc32": zlib.crc32(blob)}
        man = {"generation": g, "files": files}
        mp = os.path.join(tmp, MANIFEST)
        with open(mp, "w") as f:
            json.dump(man, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        _fs_event("gen.manifest")
        atomic_replace_dir(tmp, self.gen_dir(g))
        _fs_event("gen.commit")
        self._staged = None
        # rotate: the committed snapshot subsumes every logged mutation
        if self._wal is not None:
            self._wal.close()
        self._gen = g
        self._wal = None
        for name in os.listdir(self.root):
            if name.startswith("wal_") and name != f"wal_{g:08d}.log":
                try:
                    os.remove(os.path.join(self.root, name))
                except OSError:
                    pass
        _fs_event("wal.rotate")
        return g

    def manifest(self, g: int) -> Dict[str, Any]:
        with open(os.path.join(self.gen_dir(g), MANIFEST)) as f:
            return json.load(f)

    def read_file(self, g: int, name: str, verify: bool = True) -> bytes:
        """A generation file's bytes, checked against the manifest CRC."""
        path = os.path.join(self.gen_dir(g), name)
        with open(path, "rb") as f:
            blob = f.read()
        if verify:
            ent = self.manifest(g)["files"].get(name)
            if ent is None:
                raise CorruptSegmentError(f"{path}: not in manifest")
            if len(blob) != ent["size"] or zlib.crc32(blob) != ent["crc32"]:
                raise CorruptSegmentError(
                    f"{path}: manifest CRC/size mismatch (bit-rot inside "
                    f"a committed generation)")
        return blob

    # ---------------------------------------------------------------- WAL

    def append(self, payload: bytes) -> None:
        if self._gen is None:
            raise StoreError(
                "WAL append before any committed generation: call save() "
                "once to establish the base snapshot")
        if self._wal is None:
            self._wal = WriteAheadLog(self.wal_path(self._gen), self._gen)
        self._wal.append(payload)

    def replay(self) -> Tuple[List[bytes], bool]:
        if self._gen is None:
            return [], False
        return WriteAheadLog.replay(self.wal_path(self._gen))

    def wal_records(self) -> int:
        return len(self.replay()[0])

    def close(self) -> None:
        if self._wal is not None:
            self._wal.close()
            self._wal = None

    # -------------------------------------------------------------- scrub

    def scrub(self, deep: bool = True) -> List[Dict[str, Any]]:
        """Verify every committed generation (manifest CRCs, and with
        `deep` every segment's internal record CRCs) and the active WAL.
        Returns one report dict per checked item; `ok=False` entries are
        corruption."""
        out: List[Dict[str, Any]] = []
        for g in self.generations():
            try:
                man = self.manifest(g)
            except (OSError, json.JSONDecodeError) as e:
                out.append({"item": self.gen_dir(g), "ok": False,
                            "error": f"unreadable manifest: {e}"})
                continue
            for name in man["files"]:
                path = os.path.join(self.gen_dir(g), name)
                rep = {"item": path, "ok": True}
                try:
                    blob = self.read_file(g, name)
                    if deep and name.endswith((".seg", ".bin")):
                        decode_segment(blob, path)
                except (OSError, StoreError) as e:
                    rep = {"item": path, "ok": False, "error": str(e)}
                out.append(rep)
        if self._gen is not None:
            wp = self.wal_path(self._gen)
            if os.path.exists(wp):
                ops, torn = WriteAheadLog.replay(wp)
                out.append({"item": wp, "ok": not torn, "records": len(ops),
                            **({"error": "torn/corrupt tail"} if torn
                               else {})})
        return out


def scrub_path(path: str, deep: bool = True) -> List[Dict[str, Any]]:
    """Scrub either a Journal root (has gen_* dirs / wal_* logs) or a
    plain spill directory of segment files."""
    if not os.path.isdir(path):
        meta_ok: Dict[str, Any] = {"item": path, "ok": True}
        try:
            read_segment(path)
        except (OSError, StoreError) as e:
            meta_ok = {"item": path, "ok": False, "error": str(e)}
        return [meta_ok]
    names = os.listdir(path)
    if any(_GEN_RE.match(n) for n in names) or any(
            n.startswith("wal_") for n in names):
        return Journal(path).scrub(deep=deep)
    out = []
    for name in sorted(names):
        p = os.path.join(path, name)
        if not os.path.isfile(p) or name.endswith(
                (".tmp", ".quarantined", ".raw")):
            # .raw payloads carry no segment framing; their per-cluster
            # CRCs live in a companion manifest (core/tiered.py scrubs
            # them via `scrub_cold_pack`)
            continue
        try:
            read_segment(p)
            out.append({"item": p, "ok": True})
        except (OSError, StoreError) as e:
            out.append({"item": p, "ok": False, "error": str(e)})
    return out


def quarantine_file(path: str) -> Optional[str]:
    """Move a corrupt file aside (``path + ".quarantined"``) so readers
    stop tripping on it but the bytes stay for forensics/rebuild."""
    dst = path + ".quarantined"
    try:
        os.replace(path, dst)
        return dst
    except OSError:
        return None
