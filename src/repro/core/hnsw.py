"""Faithful HNSW with the paper's update algorithms.

Insertion follows Algorithm 1 (greedy descent -> expandCandidates ->
robustPrune -> connectTwoWay); deletion follows Algorithm 2 (entry-point /
max-level maintenance -> recNeighbors with robust pruning -> physical
removal). This is the host-side index-maintenance structure: on a real TPU
deployment it lives on the host CPUs that own the index, and devices consume
immutable snapshots (DESIGN.md §2).

External vector ids are remapped to dense internal slots at the API
boundary (`insert`/`delete`/`search`/`reconstruct`/`graph_arrays` speak
external ids; every internal structure — `vectors`, `levels`,
`neighbors`, `is_deleted` — is slot-indexed). A caller may therefore use
arbitrary 64-bit ids (timestamps, shard-prefixed ids) without the
`vectors` array or its pickled form growing past the number of live +
tombstoned nodes; slots freed by deletion are recycled by later inserts.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Set

import numpy as np


class HNSW:
    def __init__(self, dim: int, M: int = 16, ef_construction: int = 100,
                 alpha: float = 1.0, seed: int = 0, max_elements: int = 1024):
        self.dim = dim
        self.M = M
        self.M0 = 2 * M
        self.efc = ef_construction
        self.alpha = alpha
        self.rng = np.random.default_rng(seed)
        self.ml = 1.0 / math.log(M)
        self.vectors = np.zeros((max_elements, dim), np.float32)
        self.levels: Dict[int, int] = {}
        # neighbors[level][node] -> list of node ids (internal slots)
        self.neighbors: List[Dict[int, List[int]]] = [dict()]
        self.is_deleted: Dict[int, bool] = {}
        self.entry_point = -1
        self.max_level = 0
        self._count = 0
        self.n_dist = 0  # distance-computation counter (power model)
        # external id <-> dense internal slot maps (slots index `vectors`)
        self._ext2int: Dict[int, int] = {}
        self._int2ext: List[int] = []
        self._free: List[int] = []       # recycled slots of deleted nodes

    # ------------------------------------------------------------ utils

    def __len__(self):
        return sum(1 for v in self.is_deleted.values() if not v)

    def _dist(self, vid: int, vec: np.ndarray) -> float:
        self.n_dist += 1
        d = self.vectors[vid] - vec
        return float(d @ d)

    def _dists(self, ids: List[int], vec: np.ndarray) -> np.ndarray:
        self.n_dist += len(ids)
        arr = self.vectors[np.asarray(ids, np.int64)]
        diff = arr - vec
        return np.einsum("nd,nd->n", diff, diff)

    def _ensure_capacity(self, vid: int):
        if vid >= self.vectors.shape[0]:
            new = np.zeros((max(vid + 1, 2 * self.vectors.shape[0]),
                            self.dim), np.float32)
            new[: self.vectors.shape[0]] = self.vectors
            self.vectors = new

    def _nbrs(self, vid: int, level: int) -> List[int]:
        if level >= len(self.neighbors):
            return []
        return self.neighbors[level].get(vid, [])

    def _slot_for(self, vid: int) -> int:
        """Resolve (or allocate) the dense internal slot for an external
        id — recycled slots are reused so the arrays stay dense under
        insert/delete churn."""
        slot = self._ext2int.get(vid)
        if slot is not None:
            return slot
        if self._free:
            slot = self._free.pop()
            self.levels.pop(slot, None)   # stale level of the old tenant
            self._int2ext[slot] = vid
        else:
            slot = len(self._int2ext)
            self._int2ext.append(vid)
        self._ext2int[vid] = slot
        return slot

    def reconstruct(self, vid: int) -> np.ndarray:
        return self.vectors[self._ext2int[vid]]

    def get_random_level(self) -> int:
        return int(-math.log(max(self.rng.random(), 1e-12)) * self.ml)

    # ----------------------------------------------------------- search

    def _greedy_descend(self, vec, cur: int, level: int) -> int:
        cur_d = self._dist(cur, vec)
        while True:
            nbrs = [nb for nb in self._nbrs(cur, level)
                    if nb >= 0 and not self.is_deleted.get(nb, False)]
            if not nbrs:
                return cur
            ds = self._dists(nbrs, vec)                 # batched
            j = int(np.argmin(ds))
            if ds[j] >= cur_d:
                return cur
            cur, cur_d = nbrs[j], float(ds[j])

    def _search_layer(self, vec, entries: List[int], ef: int,
                      level: int) -> List[int]:
        """Beam search on one layer (batched neighbor distances)."""
        import heapq
        visited: Set[int] = set(entries)
        cand = [(self._dist(e, vec), e) for e in entries]
        heapq.heapify(cand)
        best = sorted([(-d, e) for d, e in cand])  # max-heap of results
        heapq.heapify(best)
        while cand:
            d, e = heapq.heappop(cand)
            if best and d > -best[0][0] and len(best) >= ef:
                break
            fresh = [nb for nb in self._nbrs(e, level)
                     if nb >= 0 and nb not in visited]
            if not fresh:
                continue
            visited.update(fresh)
            ds = self._dists(fresh, vec)               # one numpy call
            for nb, nd in zip(fresh, ds):
                nd = float(nd)
                if len(best) < ef or nd < -best[0][0]:
                    heapq.heappush(cand, (nd, nb))
                    heapq.heappush(best, (-nd, nb))
                    if len(best) > ef:
                        heapq.heappop(best)
        out = sorted([(-d, e) for d, e in best])
        return [e for _, e in out]

    def expand_candidates(self, cur: int, vec, level: int,
                          ef: int) -> List[int]:
        return self._search_layer(vec, [cur], ef, level)

    def robust_prune(self, cand: List[int], vec, max_m: int) -> List[int]:
        """Select up to max_m diverse neighbors (alpha-pruning heuristic)."""
        cand = [c for c in cand if not self.is_deleted.get(c, False)]
        if not cand:
            return []
        dq = self._dists(cand, vec)
        order = np.argsort(dq)
        ordered = [cand[i] for i in order]
        dq_ord = dq[order]
        chosen: List[int] = []
        for c, dqc in zip(ordered, dq_ord):
            if len(chosen) >= max_m:
                break
            if chosen:
                diffs = self.vectors[np.asarray(chosen)] - self.vectors[c]
                dd = np.einsum("nd,nd->n", diffs, diffs)
                if np.any(dd * self.alpha < dqc):
                    continue
            chosen.append(c)
        # backfill with nearest if diversity pruned too much
        for c in ordered:
            if len(chosen) >= max_m:
                break
            if c not in chosen:
                chosen.append(c)
        return chosen

    def _connect_two_way(self, vid: int, fnbr: List[int], level: int):
        while level >= len(self.neighbors):
            self.neighbors.append(dict())
        layer = self.neighbors[level]
        layer[vid] = list(fnbr)
        cap = self.M0 if level == 0 else self.M
        for nb in fnbr:
            lst = layer.setdefault(nb, [])
            if vid not in lst:
                lst.append(vid)
            if len(lst) > cap:
                layer[nb] = self.robust_prune(lst, self.vectors[nb], cap)

    # -------------------------------------------------- Algorithm 1: insert

    def insert(self, vid: int, vec: np.ndarray, level: Optional[int] = None):
        slot = self._slot_for(int(vid))
        self._ensure_capacity(slot)
        self.vectors[slot] = vec
        lvl = self.levels.get(slot, 0) if level is None else level
        if lvl <= 0:
            lvl = self.get_random_level()
        self.levels[slot] = lvl
        self.is_deleted[slot] = False
        self._count += 1

        if self.entry_point == -1:
            self.entry_point = slot
            self.max_level = lvl
            for l in range(lvl + 1):
                while l >= len(self.neighbors):
                    self.neighbors.append(dict())
                self.neighbors[l][slot] = []
            return

        cur = self.entry_point
        for l in range(self.max_level, lvl, -1):
            cur = self._greedy_descend(vec, cur, l)
        for l in range(min(lvl, self.max_level), -1, -1):
            cand = self.expand_candidates(cur, vec, l, self.efc)
            max_m = self.M0 if l == 0 else self.M
            fnbr = self.robust_prune(cand, vec, max_m)
            self._connect_two_way(slot, fnbr, l)
            if cand:
                cur = cand[0]
        for l in range(self.max_level + 1, lvl + 1):
            while l >= len(self.neighbors):
                self.neighbors.append(dict())
            self.neighbors[l][slot] = []
        if lvl > self.max_level:
            self.max_level = lvl
            self.entry_point = slot

    # ------------------------------------------------- Algorithm 2: delete

    def _rec_neighbors(self, vid: int, old_neighbors: List[int], level: int):
        """Reconnect the ex-neighbors of a deleted node on one layer."""
        layer = self.neighbors[level]
        alive = [n for n in old_neighbors
                 if not self.is_deleted.get(n, False) and n != vid]
        for n in alive:
            lst = [x for x in layer.get(n, []) if x != vid and
                   not self.is_deleted.get(x, False)]
            # candidate set: existing neighbors + the deleted node's other
            # neighbors (restores connectivity through the hole)
            cand = set(lst)
            cand.update(a for a in alive if a != n)
            cap = self.M0 if level == 0 else self.M
            layer[n] = self.robust_prune(list(cand), self.vectors[n], cap)

    def _check_and_decrease_max_level(self):
        while self.max_level > 0:
            layer = self.neighbors[self.max_level]
            occupied = [v for v, l in self.levels.items()
                        if l >= self.max_level and
                        not self.is_deleted.get(v, False)]
            if occupied:
                break
            self.max_level -= 1
        # keep entry point consistent
        if self.entry_point != -1 and \
                self.levels.get(self.entry_point, 0) < self.max_level:
            for v, l in self.levels.items():
                if l >= self.max_level and not self.is_deleted.get(v, False):
                    self.entry_point = v
                    break

    def delete(self, vid: int):
        slot = self._ext2int.get(int(vid))
        if slot is None or self.is_deleted.get(slot, True):
            return
        if slot == self.entry_point:
            new_ep, new_max = -1, -1
            for v, l in sorted(self.levels.items(), key=lambda kv: -kv[1]):
                if v != slot and not self.is_deleted.get(v, False):
                    new_ep, new_max = v, l
                    break
            if new_ep == -1:
                self.entry_point = -1
                self.max_level = 0
            else:
                self.entry_point = new_ep
                self.max_level = new_max
        elif self.levels.get(slot, 0) == self.max_level:
            pass  # handled below by _check_and_decrease_max_level
        self.is_deleted[slot] = True
        for l in range(len(self.neighbors)):
            layer = self.neighbors[l]
            old = layer.pop(slot, [])
            # robustPrune during connectTwoWay can leave asymmetric edges:
            # also collect nodes that still point at the victim
            incoming = [n for n, lst in layer.items() if slot in lst]
            for n in incoming:
                layer[n] = [x for x in layer[n] if x != slot]
            affected = list(dict.fromkeys(list(old) + incoming))
            if affected:
                self._rec_neighbors(slot, affected, l)
        self._check_and_decrease_max_level()
        del self._ext2int[int(vid)]
        self._free.append(slot)

    # ----------------------------------------------------------- queries

    def search(self, vec: np.ndarray, k: int, ef_search: int = 64):
        if self.entry_point == -1:
            return np.zeros((0,), np.int64), np.zeros((0,), np.float32)
        cur = self.entry_point
        for l in range(self.max_level, 0, -1):
            cur = self._greedy_descend(vec, cur, l)
        cand = self._search_layer(vec, [cur], max(ef_search, k), 0)
        cand = [c for c in cand if not self.is_deleted.get(c, False)][:k]
        return (np.asarray([self._int2ext[c] for c in cand], np.int64),
                self._dists(cand, vec) if cand else np.zeros((0,), np.float32))

    # -------------------------------------------------------- persistence

    SEGMENT_KIND = "hnsw.graph"

    def save(self, path: str) -> None:
        """Persist the full graph (vectors, levels, neighbors, id maps,
        RNG state) as one checksummed segment, written atomically — the
        durable form of the index that previously died with the
        process."""
        from repro.core import store
        store.dump_obj(path, self, kind=self.SEGMENT_KIND)

    @classmethod
    def load(cls, path: str) -> "HNSW":
        """Validated restore of `save()` output: magic/length/CRC are
        checked before any byte reaches pickle; raises
        `store.CorruptSegmentError` on truncation or bit-rot."""
        from repro.core import store
        g = store.load_obj(path, kind=cls.SEGMENT_KIND)
        if not isinstance(g, cls):
            raise store.CorruptSegmentError(
                f"{path}: decoded {type(g).__name__}, not {cls.__name__}")
        return g

    # --------------------------------------------------------- accounting

    def memory_bytes(self) -> int:
        """Vectors + neighbor links (paper Table 1 convention)."""
        n_links = sum(len(lst) for layer in self.neighbors
                      for lst in layer.values())
        n = len(self)
        return n * self.dim * 4 + n_links * 4

    def graph_arrays(self):
        """Export (external) ids and vectors for device-side dense scans."""
        slots = [v for v, d in self.is_deleted.items() if not d]
        ids = np.asarray([self._int2ext[s] for s in slots], np.int64)
        return ids, self.vectors[np.asarray(slots, np.int64)]
