"""The paper's analytical models (§3.4): memory (Table 1), search latency
(Table 2: CPU + disk I/O), and energy (§3.4.3).

Constants follow the paper's setting: 500 CPU cycles per 128-d distance at
2.4 GHz; UFS 4.0 disk (T_seek 0.025 ms, T_cmd 0.015 ms, 3.6e-7 ms/B);
I_cpu 2300 uA, I_disk 800 uA at V = 3.8 V.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareModel:
    cpu_cycles_per_dist_128d: float = 500.0
    cpu_hz: float = 2.4e9
    t_seek_ms: float = 0.025
    t_cmd_ms: float = 0.015
    t_transfer_ms_per_byte: float = 3.6e-7
    i_cpu_ua: float = 2300.0
    i_disk_ua: float = 800.0
    volt: float = 3.8

    def t_op_ms(self, dim: int) -> float:
        cycles = self.cpu_cycles_per_dist_128d * dim / 128.0
        return cycles / self.cpu_hz * 1e3


HW = HardwareModel()
P0 = None  # computed from M per call


def _p0(M: int) -> float:
    return 1.0 / math.log(max(M, 2))


# ------------------------------------------------------------- Table 1


def memory_bytes(alg: str, *, N: int, d: int, Nc: int = 64, M: int = 16,
                 M_pq: int = 8, nbits: int = 8, M_cent: int = 16) -> float:
    p0 = _p0(M)
    p0c = _p0(M_cent)
    if alg == "IVF":
        return Nc * 4 * d + 8 * N + N * 4 * d
    if alg == "IVFPQ":
        return Nc * 4 * d + 8 * N + N * (M_pq * nbits / 8) + 2 ** nbits * 4 * d
    if alg == "HNSW":
        return N * 4 * d + 4 * N * M / (1 - p0)
    if alg == "HNSWPQ":
        return (N * (M_pq * nbits / 8) + 4 * N * M / (1 - p0)
                + 2 ** nbits * 4 * d)
    if alg == "IVF-DISK":
        return Nc * 4 * d + 8 * N + 4 * d * (N / Nc)
    if alg == "IVFPQ-DISK":
        return (Nc * 4 * d + 8 * N + (N / Nc) * M_pq * nbits / 8
                + 2 ** nbits * 4 * d)
    if alg == "IVF-HNSW":
        return 4 * Nc * (d + M_cent / (1 - p0c)) + 8 * N + 4 * d * (N / Nc)
    if alg == "EcoVector":
        return (4 * Nc * (d + M_cent / (1 - p0c)) + 8 * N
                + (N / Nc) * 4 * (d + M / (1 - p0)))
    raise ValueError(alg)


# ------------------------------------------------------------- Table 2


def n_search_ops(alg: str, *, N: int, Nc: int = 64, n_probe: int = 4,
                 M: int = 16, M_pq: int = 8, nbits: int = 8, d: int = 128,
                 ef_h: int = 64, ef_c: int = 16, ef_l: int = 16,
                 M_cent: int = 16) -> float:
    """Equivalent 128-d-unit distance computations per query (Table 2)."""
    if alg == "IVF" or alg == "IVF-DISK":
        return Nc + n_probe * N / Nc
    if alg == "IVFPQ" or alg == "IVFPQ-DISK":
        return (Nc + n_probe * (N / Nc) * (M_pq / d) * (nbits / 8)
                + 2 ** nbits)
    if alg == "HNSW":
        return ef_h * M
    if alg == "HNSWPQ":
        return ef_h * M * (M_pq / d) * (nbits / 8) + 2 ** nbits
    if alg == "IVF-HNSW":
        return ef_c * M_cent + n_probe * N / Nc
    if alg == "EcoVector":
        return ef_c * M_cent + n_probe * ef_l * M
    raise ValueError(alg)


def disk_bytes_per_probe(alg: str, *, N: int, d: int, Nc: int, M: int = 16,
                         M_pq: int = 8, nbits: int = 8) -> float:
    avg = N / Nc
    if alg in ("IVF-DISK", "IVF-HNSW"):
        return avg * 4 * d
    if alg == "IVFPQ-DISK":
        return avg * M_pq * nbits / 8
    if alg == "EcoVector":
        p0 = _p0(M)
        return avg * 4 * (d + M / (1 - p0))
    return 0.0


def search_latency_ms(alg: str, *, N: int, d: int, Nc: int = 64,
                      n_probe: int = 4, hw: HardwareModel = HW,
                      **kw) -> dict:
    """T_search = t_s + t_d (§3.4.2). Returns both parts + total (ms)."""
    ops_ = n_search_ops(alg, N=N, Nc=Nc, n_probe=n_probe, d=d, **kw)
    t_s = ops_ * hw.t_op_ms(d)
    dbytes = disk_bytes_per_probe(alg, N=N, d=d, Nc=Nc,
                                  M=kw.get("M", 16),
                                  M_pq=kw.get("M_pq", 8),
                                  nbits=kw.get("nbits", 8))
    n_seek = n_probe if dbytes else 0
    t_d = n_seek * (hw.t_seek_ms + hw.t_cmd_ms
                    + dbytes * hw.t_transfer_ms_per_byte)
    return {"t_s_ms": t_s, "t_d_ms": t_d, "total_ms": t_s + t_d}


# ------------------------------------------------------------- §3.4.3


def energy_mj(t_s_ms: float, t_d_ms: float, hw: HardwareModel = HW) -> float:
    """E = V * (I_cpu * t_s + I_disk * t_d), in millijoules."""
    return hw.volt * (hw.i_cpu_ua * 1e-6 * t_s_ms
                      + hw.i_disk_ua * 1e-6 * t_d_ms)


def search_energy_mj(alg: str, **kw) -> float:
    lat = search_latency_ms(alg, **kw)
    return energy_mj(lat["t_s_ms"], lat["t_d_ms"])
