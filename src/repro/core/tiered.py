"""Tiered hot/cold EcoVector index: serve corpora larger than device memory.

The device fast path (`device_pack` + the fused ecoscan route->scan kernel)
assumes the whole [NC, CAP, d] cluster pack fits on device. This module
splits it under an explicit ``device_budget_bytes`` knob (DESIGN.md §14):

  * **Hot tier** — a device-resident block pack holding the most-accessed
    clusters, scanned by the exact same `ecoscan` kernel through its
    ``block_map`` cluster->row indirection.
  * **Cold tier** — a checksummed, mmap'd host pack (`ColdPack`): raw f32
    rows in ``cold_payload.raw`` plus a `core/store.py` segment manifest
    with per-cluster CRCs. Cold probes are gathered from the mmap into a
    per-batch scratch and scanned by the SAME kernel call, so candidates
    — and therefore results — are bit-identical to the all-resident pack
    at equal ``n_probe``. Tiering changes cost, never candidates.
  * **TierManager** — per-cluster EMA of route hits (seeded from the LRU
    cluster-graph cache) drives asynchronous promotion/demotion at search
    boundaries, bounded by ``moves_per_sync``: promotions ride the
    dirty-cluster incremental repack machinery (one row rewritten in
    place, never a full rebuild), demotions write through to the cold
    pack *before* freeing the device row.

Durability: `save()` stages the cold pack (verified + compacted) and a
``tiering.seg`` (hot set, EMA, cap, budget) into the PR 7 generation
snapshot; `load()` restores tier assignment and the cold pack before the
WAL replays, so replayed mutations land on the restored layout. Spill
files remain the durable authority for BOTH tiers — the cold pack is
derived data, healable from the spill graphs on checksum failure (and
quarantined + probed-around, PR 7 semantics, when those are rotten too).
`insert`/`delete` on a cold cluster mark it dirty in place and the next
sync writes through — mutation never forces promotion.
"""
from __future__ import annotations

import os
import pickle
import warnings
import zlib
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import store
from repro.core.ecovector import EcoVector
from repro.kernels import ops

_COLD_KIND = "ecovector.coldpack"
_TIER_KIND = "ecovector.tiering"
COLD_MANIFEST = "cold_manifest.seg"
COLD_PAYLOAD = "cold_payload.raw"
TIER_STATE = "tiering.seg"


class ColdPack:
    """Checksummed, mmap'd host pack of cold clusters' vectors.

    ``cold_payload.raw`` holds raw float32 rows (no framing — reads are
    random-access mmap slices); ``cold_manifest.seg`` is a checksummed
    store segment mapping cluster -> (row offset, row count, payload
    CRC32, external ids). `put` appends payload (fsync) and THEN commits
    the manifest atomically — the manifest is the linearization point, a
    crash mid-append leaves unreferenced garbage rows that the next
    `compact()`/save folds away. Per-cluster CRCs are verified on first
    touch per process; a mismatch raises `CorruptSegmentError`.
    """

    def __init__(self, dirpath: str, dim: int):
        self.dir = dirpath
        self.dim = dim
        self.entries: Dict[int, Dict[str, Any]] = {}
        self.payload_rows = 0            # committed rows (manifest view)
        self._mm: Optional[np.memmap] = None
        self._verified: Set[int] = set()
        if os.path.exists(self.manifest_path):
            self._read_manifest()

    @property
    def manifest_path(self) -> str:
        return os.path.join(self.dir, COLD_MANIFEST)

    @property
    def payload_path(self) -> str:
        return os.path.join(self.dir, COLD_PAYLOAD)

    # ------------------------------------------------------------- manifest

    def _read_manifest(self) -> None:
        state = store.load_obj(self.manifest_path, kind=_COLD_KIND)
        if state.get("dim") != self.dim:
            raise store.CorruptSegmentError(
                f"{self.manifest_path}: dim {state.get('dim')} where "
                f"{self.dim} expected")
        self.entries = {int(c): e for c, e in state["entries"].items()}
        self.payload_rows = int(state["payload_rows"])

    def _flush_manifest(self) -> None:
        store.dump_obj(self.manifest_path,
                       {"dim": self.dim, "payload_rows": self.payload_rows,
                        "entries": self.entries}, kind=_COLD_KIND)

    # --------------------------------------------------------------- access

    def has(self, c: int) -> bool:
        return c in self.entries

    def clusters(self) -> Set[int]:
        return set(self.entries)

    def _mmap(self) -> Optional[np.memmap]:
        if self._mm is None and os.path.exists(self.payload_path) \
                and os.path.getsize(self.payload_path) > 0:
            self._mm = np.memmap(self.payload_path, dtype=np.uint8,
                                 mode="r")
        return self._mm

    def _row_bytes(self) -> int:
        return self.dim * 4

    def get(self, c: int, verify: Optional[bool] = None
            ) -> Tuple[np.ndarray, np.ndarray]:
        """(ids [n] i64, vecs [n, d] f32) for cluster `c`. The payload
        CRC is checked on the first touch per process (or always with
        ``verify=True``); a mismatch raises CorruptSegmentError."""
        e = self.entries[c]
        rb = self._row_bytes()
        a, b = e["off"] * rb, (e["off"] + e["n"]) * rb
        mm = self._mmap()
        if mm is None or len(mm) < b:
            raise store.CorruptSegmentError(
                f"{self.payload_path}: cluster {c} span [{a}:{b}] beyond "
                f"payload ({0 if mm is None else len(mm)} bytes)")
        raw = bytes(mm[a:b])
        if verify or (verify is None and c not in self._verified):
            if zlib.crc32(raw) != e["crc"]:
                raise store.CorruptSegmentError(
                    f"{self.payload_path}: cluster {c} payload CRC "
                    f"mismatch (bit-rot in the cold pack)")
            self._verified.add(c)
        vecs = np.frombuffer(raw, np.float32).reshape(e["n"], self.dim)
        return np.asarray(e["ids"], np.int64), vecs

    # ------------------------------------------------------------- mutation

    def put(self, c: int, ids: np.ndarray, vecs: np.ndarray,
            flush: bool = True) -> None:
        """Write-through one cluster: append payload rows (fsync), then
        commit the manifest. Replaces any previous entry (the old rows
        become garbage until compaction)."""
        vecs = np.ascontiguousarray(vecs, np.float32)
        if vecs.ndim != 2 or vecs.shape[1] != self.dim:
            raise ValueError(f"cold put: vecs {vecs.shape} vs dim "
                             f"{self.dim}")
        raw = vecs.tobytes()
        rb = self._row_bytes()
        with open(self.payload_path, "ab") as f:
            size = f.tell()
            if size % rb:                # torn unacknowledged tail: pad to
                pad = rb - size % rb     # the next row boundary
                f.write(b"\0" * pad)
                size += pad
            off = size // rb
            f.write(raw)
            store._fs_event("cold.append")
            f.flush()
            os.fsync(f.fileno())
        store._fs_event("cold.fsync")
        self._mm = None                  # remap: the file grew
        self.entries[c] = {"off": off, "n": int(vecs.shape[0]),
                           "crc": zlib.crc32(raw),
                           "ids": np.asarray(ids, np.int64)}
        self._verified.add(c)
        self.payload_rows = max(self.payload_rows, off + vecs.shape[0])
        if flush:
            self._flush_manifest()

    def drop(self, c: int, flush: bool = True) -> None:
        if self.entries.pop(c, None) is not None:
            self._verified.discard(c)
            if flush:
                self._flush_manifest()

    def live_rows(self) -> int:
        return sum(e["n"] for e in self.entries.values())

    def file_bytes(self) -> int:
        try:
            return os.path.getsize(self.payload_path)
        except OSError:
            return 0

    def write_snapshot(self, dst_dir: str) -> None:
        """Stage a verified, compacted copy of the pack into `dst_dir`
        (generation commit). Every entry's CRC is re-checked on the way
        out — bit-rot is never laundered into a snapshot."""
        rows: List[bytes] = []
        entries: Dict[int, Dict[str, Any]] = {}
        off = 0
        for c in sorted(self.entries):
            ids, vecs = self.get(c, verify=True)
            raw = vecs.tobytes()
            rows.append(raw)
            entries[c] = {"off": off, "n": int(vecs.shape[0]),
                          "crc": zlib.crc32(raw),
                          "ids": np.asarray(ids, np.int64)}
            off += int(vecs.shape[0])
        store.atomic_write_bytes(os.path.join(dst_dir, COLD_PAYLOAD),
                                 b"".join(rows))
        store.write_segment(
            os.path.join(dst_dir, COLD_MANIFEST),
            [pickle.dumps({"dim": self.dim, "payload_rows": off,
                           "entries": entries},
                          protocol=pickle.HIGHEST_PROTOCOL)],
            kind=_COLD_KIND)

    def compact(self) -> None:
        """Rewrite the payload with only live rows (drops garbage from
        crashed appends and replaced entries)."""
        self.write_snapshot(self.dir)
        self._mm = None
        self._verified.clear()
        self._read_manifest()


class TierManager:
    """Per-cluster access-frequency EMA + promotion/demotion planning.

    `record` folds one search batch's probe counts into the EMA;
    `plan` returns (promote, demote) lists that move the hot set toward
    the top-``budget_rows`` clusters by EMA, with a hysteresis ratio so
    a cluster must be decisively hotter than the coldest resident before
    a swap is worth the copy traffic."""

    def __init__(self, n_clusters: int, alpha: float = 0.3,
                 hysteresis: float = 1.25):
        self.n = n_clusters
        self.alpha = alpha
        self.hysteresis = hysteresis
        self.ema = np.zeros(n_clusters, np.float64)

    def seed_from_cache(self, lru_keys) -> None:
        """Seed from the LRU cluster-graph cache: recency order is the
        only access signal that exists before the first device search
        (later == more recently used == hotter)."""
        keys = [c for c in lru_keys if 0 <= c < self.n]
        for i, c in enumerate(keys):
            self.ema[c] = max(self.ema[c], 0.5 * (i + 1) / max(len(keys), 1))

    def record(self, probes: np.ndarray) -> None:
        flat = np.asarray(probes).reshape(-1)
        flat = flat[(flat >= 0) & (flat < self.n)]
        counts = np.bincount(flat, minlength=self.n).astype(np.float64)
        self.ema *= (1.0 - self.alpha)
        self.ema += self.alpha * counts

    def plan(self, hot: Set[int], budget_rows: int,
             blocked: Set[int]) -> Tuple[List[int], List[int]]:
        elig = [c for c in range(self.n) if c not in blocked]
        hot_l = sorted((c for c in elig if c in hot),
                       key=lambda c: (self.ema[c], -c))      # coldest first
        cold_l = sorted((c for c in elig if c not in hot),
                        key=lambda c: (-self.ema[c], c))     # hottest first
        demote: List[int] = []
        while len(hot_l) > budget_rows:                      # over budget
            demote.append(hot_l.pop(0))
        promote: List[int] = []
        free = budget_rows - len(hot_l)
        while free > 0 and cold_l:                           # fill free rows
            promote.append(cold_l.pop(0))
            free -= 1
        for cand in cold_l:                                  # swaps
            if not hot_l or self.ema[cand] <= 0:
                break
            victim = hot_l[0]
            if self.ema[cand] > self.hysteresis * self.ema[victim] + 1e-9:
                demote.append(hot_l.pop(0))
                promote.append(cand)
            else:
                break
        return promote, demote


class TieredEcoVector(EcoVector):
    """EcoVector whose device pack is split hot/cold under an explicit
    ``device_budget_bytes``. ``None`` keeps every cluster hot (behaviour
    and results identical to the base class); any budget serves the same
    candidates — cold probes are gathered from the mmap'd `ColdPack` and
    scanned by the same kernel call via ``block_map`` (DESIGN.md §14)."""

    def __init__(self, *args, device_budget_bytes: Optional[int] = None,
                 ema_alpha: float = 0.3, hysteresis: float = 1.25,
                 moves_per_sync: int = 4, **kw):
        self.device_budget_bytes = device_budget_bytes
        self.ema_alpha = ema_alpha
        self.hysteresis = hysteresis
        self.moves_per_sync = moves_per_sync
        super().__init__(*args, **kw)

    # ------------------------------------------------------- tier state

    def _reset_pack_state(self):
        super()._reset_pack_state()
        self._tier_live = False
        self._cap: int = 0
        self._hot_data: Optional[np.ndarray] = None   # [R, cap, d] f32
        self._hot_ids: Optional[np.ndarray] = None    # [R, cap] i64
        self._hot_lens: Optional[np.ndarray] = None   # [R] i32
        self._hot_row: Optional[np.ndarray] = None    # [NC] i32, -1 = cold
        self._row_cluster: List[int] = []             # row -> cluster / -1
        self._free_rows: List[int] = []
        self._hot_mirror = None                       # jnp (data, lens)
        self._hot_mirror_dirty: Set[int] = set()      # stale device rows
        self._cold: Optional[ColdPack] = None
        self._tm: Optional[TierManager] = None
        self._restored_tiering = getattr(self, "_restored_tiering", None)

    def _pack_live(self) -> bool:
        # record dirty marks from the moment the index has content, even
        # before the first device search: the cold pack restored by
        # load() must see WAL-replayed mutations at the first sync
        return self.centroid_graph is not None

    def device_pack(self, cap=None, force_full=False):
        raise store.StoreError(
            "TieredEcoVector has no monolithic device pack — the hot/cold "
            "split is managed by device_budget_bytes; use "
            "search_device_batched / device_resident_bytes")

    def hot_clusters(self) -> Set[int]:
        if not self._tier_live:
            return set()
        return {c for c in self._row_cluster if c >= 0}

    def cold_clusters(self) -> Set[int]:
        if self._cold is None:
            return set()
        return self._cold.clusters()

    # ------------------------------------------------------ budget math

    def _fixed_device_bytes(self) -> int:
        """Device bytes independent of the hot-row count: centroids for
        routing + the [NC] block_map + the [R] lens vector is counted
        per-row below."""
        cent = (int(self.centroids.size) * 4
                if self.centroids is not None else 0)
        return cent + self.n_clusters * 4

    def _row_device_bytes(self) -> int:
        return self._cap * self.dim * 4 + 4      # data row + lens entry

    def _budget_rows(self) -> Optional[int]:
        if self.device_budget_bytes is None:
            return None
        spare = self.device_budget_bytes - self._fixed_device_bytes()
        if spare < 0:
            warnings.warn(
                f"device_budget_bytes={self.device_budget_bytes} does not "
                f"even cover the routing centroids "
                f"({self._fixed_device_bytes()} B); serving all-cold",
                stacklevel=3)
            return 0
        return int(spare // self._row_device_bytes())

    def device_resident_bytes(self) -> int:
        if not self._tier_live:
            return super().device_resident_bytes()
        R = len(self._row_cluster)
        return self._fixed_device_bytes() + R * self._row_device_bytes()

    def all_resident_bytes(self) -> int:
        """What the ALL-hot layout would cost on device — the reference
        a fractional budget (e.g. 25% of the pack) is resolved against.
        Computable before activation."""
        cap = max(self._cap, 8, self._cluster_need())
        row = cap * self.dim * 4 + 4
        return self._fixed_device_bytes() + self.n_clusters * row

    def ram_bytes(self) -> int:
        total = super().ram_bytes()
        if self._cold is not None:
            # the mmap'd payload is page-cache, not anonymous RAM; count
            # the manifest's id arrays which are resident
            total += sum(e["ids"].nbytes + 64
                         for e in self._cold.entries.values())
        return total

    # ------------------------------------------------------- activation

    def set_device_budget(self, budget: Optional[int]) -> None:
        """Re-budget at runtime: recompute the row budget and demote /
        promote to fit. ``None`` lifts the budget (all clusters hot)."""
        self.device_budget_bytes = budget
        if self._tier_live:
            self._retier()

    def _cluster_need(self) -> int:
        sizes = [len(m) for m in self.cluster_members]
        return int(max(sizes)) if sizes else 0

    def _ensure_tiers(self) -> None:
        if not self._tier_live:
            self._activate()
        self._tier_sync()

    def _activate(self) -> None:
        """Build the initial hot/cold split: restore the persisted tier
        assignment when one was loaded, else pick the top-budget clusters
        by (cache-seeded) EMA. Every healthy non-hot cluster is written
        through to the cold pack."""
        self._cap = max(8, self._cluster_need())
        self._tm = TierManager(self.n_clusters, alpha=self.ema_alpha,
                               hysteresis=self.hysteresis)
        self._tm.seed_from_cache(list(self._cache))
        self._cold = ColdPack(self.storage_dir, self.dim)
        restored = self._restored_tiering
        if restored is not None:
            self._cap = max(self._cap, int(restored["cap"]))
            ema = np.asarray(restored["ema"], np.float64)
            if ema.shape[0] == self.n_clusters:
                self._tm.ema = np.maximum(self._tm.ema, ema)
            if self.device_budget_bytes is None:
                self.device_budget_bytes = restored["budget"]
        budget_rows = self._budget_rows()
        want_hot: List[int]
        healthy = [c for c in range(self.n_clusters)
                   if c not in self._quarantined]
        if budget_rows is None:
            want_hot = healthy
        else:
            pref = (sorted((c for c in restored["hot"] if c in
                            set(healthy)),
                           key=lambda c: (-self._tm.ema[c], c))
                    if restored is not None else
                    sorted(healthy, key=lambda c: (-self._tm.ema[c], c)))
            rest = [c for c in healthy if c not in set(pref)]
            want_hot = (pref + sorted(
                rest, key=lambda c: (-self._tm.ema[c], c)))[:budget_rows]
        self._rebuild_hot(want_hot)
        # write-through every healthy cold cluster missing from the pack
        hot_set = set(want_hot)
        missing = [c for c in healthy
                   if c not in hot_set and not self._cold.has(c)]
        for c in missing:
            g = self._load_cluster_checked(c)
            if g is None:
                continue
            ids, vecs = g.graph_arrays()
            self._cold.put(c, ids, vecs, flush=False)
        if missing:
            self._cold._flush_manifest()
        self._tier_live = True

    def _rebuild_hot(self, want_hot: List[int]) -> None:
        """(Re)allocate the hot arrays for `want_hot`, copying rows from
        the previous hot arrays where possible, the cold pack or spill
        graphs otherwise. Demoted clusters write through to the cold
        pack BEFORE their device rows disappear."""
        budget_rows = self._budget_rows()
        R = (self.n_clusters if budget_rows is None
             else min(self.n_clusters, budget_rows))
        want_hot = want_hot[:R]
        old = (self._hot_data, self._hot_ids, self._hot_lens,
               self._hot_row)
        prev_hot = self.hot_clusters() if self._hot_row is not None else set()
        data = np.zeros((R, self._cap, self.dim), np.float32)
        ids_a = -np.ones((R, self._cap), np.int64)
        lens = np.zeros((R,), np.int32)
        hot_row = -np.ones((self.n_clusters,), np.int32)
        row_cluster = [-1] * R
        for row, c in enumerate(want_hot):
            got = self._fetch_cluster_rows(c, old)
            if got is None:
                continue                      # quarantined along the way
            cids, cvecs = got
            m = min(len(cids), self._cap)
            if len(cids) > self._cap:
                raise RuntimeError(
                    f"cluster {c} has {len(cids)} rows but tier cap is "
                    f"{self._cap}: members/graph bookkeeping diverged")
            data[row, :m] = cvecs[:m]
            ids_a[row, :m] = cids[:m]
            lens[row] = m
            hot_row[c] = row
            row_cluster[row] = c
        self._hot_data, self._hot_ids, self._hot_lens = data, ids_a, lens
        self._hot_row, self._row_cluster = hot_row, row_cluster
        self._free_rows = [r for r, c in enumerate(row_cluster) if c < 0]
        self._hot_mirror = None
        self._hot_mirror_dirty.clear()
        now_hot = {c for c in row_cluster if c >= 0}
        if self._cold is not None:
            # write-through newly-demoted clusters, then drop promoted
            # ones from the pack (one manifest commit each way)
            changed = False
            for c in sorted(prev_hot - now_hot):
                if c in self._quarantined:
                    continue
                got = self._fetch_cluster_rows(c, old)
                if got is not None:
                    self._cold.put(c, got[0], got[1], flush=False)
                    self.stats.demotions += 1
                    changed = True
            if changed:
                self._cold._flush_manifest()
            dropped = [c for c in sorted(now_hot) if self._cold.has(c)]
            for c in dropped:
                self._cold.drop(c, flush=False)
            if dropped:
                self._cold._flush_manifest()

    def _fetch_cluster_rows(self, c: int, old=None
                            ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(ids, vecs) for a healthy cluster from the cheapest source:
        previous hot arrays, the cold pack (healing it from the spill
        graph on CRC failure), else the spill graph."""
        if c in self._quarantined:
            return None
        if old is not None and old[3] is not None and old[3][c] >= 0:
            row = old[3][c]
            m = int(old[2][row])
            return old[1][row, :m].copy(), old[0][row, :m].copy()
        if self._cold is not None and self._cold.has(c):
            try:
                return self._cold.get(c)
            except store.CorruptSegmentError as e:
                self.stats.corrupt_reads += 1
                warnings.warn(f"cold pack entry for cluster {c} failed "
                              f"validation ({e}); healing from the spill "
                              f"graph", stacklevel=3)
        g = self._load_cluster_checked(c)     # may quarantine
        if g is None:
            return None
        ids, vecs = g.graph_arrays()
        if self._cold is not None and self._cold.has(c):
            self._cold.put(c, ids, vecs)      # heal the rotten entry
            self.stats.rebuilt += 1
        return ids, vecs

    # ------------------------------------------------------------- sync

    def _tier_sync(self, moves: Optional[int] = None) -> None:
        """Search-boundary maintenance: (1) flush dirty clusters into
        their current tier — hot rows rewritten in place (the incremental
        repack machinery), cold entries written through, never promoting;
        (2) apply up to `moves` planned promotions/demotions."""
        if not self._tier_live:
            return
        if self._dirty:
            need = max((len(self.cluster_members[c]) for c in self._dirty),
                       default=0)
            if need > self._cap:
                new_cap = self._cap
                while new_cap < need:
                    new_cap *= 2
                self._cap = new_cap
                self.stats.pack_grows += 1
                # row size changed: the budget buys fewer rows now
                self._rebuild_hot(sorted(
                    self.hot_clusters(),
                    key=lambda c: (-self._tm.ema[c], c)))
            dirty = sorted(self._dirty)
            self._dirty.clear()
            cold_touched = False
            for c in dirty:
                if c in self._quarantined:
                    continue
                g = self._pending_graphs.pop(c, None)
                if g is None:
                    g = self._load_cluster_checked(c)
                if g is None:
                    continue
                ids, vecs = g.graph_arrays()
                row = int(self._hot_row[c])
                if row >= 0:
                    m = len(ids)
                    self._hot_data[row, :m] = vecs
                    self._hot_data[row, m:] = 0.0
                    self._hot_ids[row, :m] = ids
                    self._hot_ids[row, m:] = -1
                    self._hot_lens[row] = m
                    self._hot_mirror_dirty.add(row)
                    self.stats.pack_cluster_repacks += 1
                else:
                    self._cold.put(c, ids, vecs, flush=False)
                    cold_touched = True
            if cold_touched:
                self._cold._flush_manifest()
        budget_rows = self._budget_rows()
        n = self.moves_per_sync if moves is None else moves
        if n <= 0 or budget_rows is None:
            return
        promote, demote = self._tm.plan(self.hot_clusters(), budget_rows,
                                        self._quarantined)
        for c in demote:
            if n <= 0:
                break
            self._demote(c)
            n -= 1
        for c in promote:
            if n <= 0 or not self._free_rows:
                break
            self._promote(c)
            n -= 1

    def _demote(self, c: int) -> None:
        row = int(self._hot_row[c])
        if row < 0:
            return
        m = int(self._hot_lens[row])
        # write-through BEFORE freeing the device row: a crash in between
        # leaves the cluster in both tiers, which reload reconciles
        self._cold.put(c, self._hot_ids[row, :m].copy(),
                       self._hot_data[row, :m].copy())
        self._hot_data[row] = 0.0
        self._hot_ids[row] = -1
        self._hot_lens[row] = 0
        self._hot_row[c] = -1
        self._row_cluster[row] = -1
        self._free_rows.append(row)
        self._hot_mirror_dirty.add(row)
        self.stats.demotions += 1

    def _promote(self, c: int) -> None:
        got = self._fetch_cluster_rows(c)
        if got is None:
            return
        ids, vecs = got
        m = min(len(ids), self._cap)
        row = self._free_rows.pop()
        self._hot_data[row, :m] = vecs[:m]
        self._hot_data[row, m:] = 0.0
        self._hot_ids[row, :m] = ids[:m]
        self._hot_ids[row, m:] = -1
        self._hot_lens[row] = m
        self._hot_row[c] = row
        self._row_cluster[row] = c
        self._hot_mirror_dirty.add(row)
        self._cold.drop(c)
        self.stats.promotions += 1

    def _retier(self) -> None:
        """Apply a budget change now (unbounded moves): demote overflow,
        then fill free rows with the hottest cold clusters."""
        budget_rows = self._budget_rows()
        if budget_rows is None:
            budget_rows = self.n_clusters
        target = sorted(
            (c for c in range(self.n_clusters)
             if c not in self._quarantined),
            key=lambda c: (-self._tm.ema[c],
                           0 if self._hot_row[c] >= 0 else 1, c))
        self._rebuild_hot(target[:budget_rows])

    # ------------------------------------------------------------ search

    def _quarantine(self, c: int):
        if c in self._quarantined:
            return
        if self._tier_live:
            row = int(self._hot_row[c])
            if row >= 0:
                m = int(self._hot_lens[row])
                if m > 0 and c not in self._salvage:
                    self._salvage[c] = (self._hot_ids[row, :m].copy(),
                                        self._hot_data[row, :m].copy())
                self._hot_data[row] = 0.0
                self._hot_ids[row] = -1
                self._hot_lens[row] = 0
                self._hot_row[c] = -1
                self._row_cluster[row] = -1
                self._free_rows.append(row)
                self._hot_mirror_dirty.add(row)
            elif self._cold is not None and self._cold.has(c):
                if c not in self._salvage:
                    try:
                        self._salvage[c] = self._cold.get(c, verify=False)
                    except store.CorruptSegmentError:
                        pass
                self._cold.drop(c)
            self._dirty.discard(c)
        super()._quarantine(c)

    def _hot_arrays(self):
        import jax.numpy as jnp
        if (self._hot_mirror is None
                or self._hot_mirror[0].shape != self._hot_data.shape):
            # jnp.array (copy), not asarray: repacks mutate the host pack
            # in place and a zero-copy alias would change under callers
            self._hot_mirror = (jnp.array(self._hot_data),
                                jnp.array(self._hot_lens))
            self._hot_mirror_dirty.clear()
        elif self._hot_mirror_dirty:
            rows = sorted(self._hot_mirror_dirty)
            mdata, _ = self._hot_mirror
            mdata = mdata.at[jnp.asarray(rows)].set(
                jnp.asarray(self._hot_data[rows]))
            self._hot_mirror = (mdata, jnp.array(self._hot_lens))
            self._hot_mirror_dirty.clear()
        if self._centroids_dev is None:
            self._centroids_dev = jnp.array(
                np.asarray(self.centroids, np.float32))
        return self._hot_mirror[0], self._hot_mirror[1], self._centroids_dev

    def _route_device(self, q: np.ndarray, n_probe: int) -> np.ndarray:
        """Device routing over ALL centroids — the same `route_topk` the
        fused all-resident path uses, so probes are bitwise-identical.
        Freshly-quarantined clusters widen the ask (PR 7 semantics) and
        are filtered out, keeping the probe budget met when possible."""
        import jax.numpy as jnp
        _, _, cent_j = self._hot_arrays()
        if not self._quarantined:
            return np.asarray(ops.route_topk(jnp.asarray(q), cent_j,
                                             n_probe=n_probe))
        ask = min(self.n_clusters, n_probe + len(self._quarantined))
        ranked = np.asarray(ops.route_topk(jnp.asarray(q), cent_j,
                                           n_probe=ask))
        out = -np.ones((q.shape[0], n_probe), np.int32)
        for b in range(q.shape[0]):
            keep = [c for c in ranked[b] if c not in self._quarantined]
            out[b, :len(keep[:n_probe])] = keep[:n_probe]
        return out

    def _gather_cold(self, cold_cids: List[int]):
        """Scratch [Ncold_padded, cap, d] + ids + lens for this batch's
        cold probes, gathered from the mmap'd pack. Padded to a power of
        two of rows so ecoscan's jit cache sees few distinct shapes.
        Returns None for a cluster set that fully quarantined away."""
        n = len(cold_cids)
        padded = 1
        while padded < n:
            padded *= 2
        data = np.zeros((padded, self._cap, self.dim), np.float32)
        ids_a = -np.ones((padded, self._cap), np.int64)
        lens = np.zeros((padded,), np.int32)
        kept: List[int] = []
        for c in cold_cids:
            got = self._fetch_cluster_rows(c)
            if got is None:
                continue                      # quarantined: caller reroutes
            cids, cvecs = got
            i = len(kept)
            m = min(len(cids), self._cap)
            data[i, :m] = cvecs[:m]
            ids_a[i, :m] = cids[:m]
            lens[i] = m
            kept.append(c)
        return data, ids_a, lens, kept

    def search_device_batched(self, q: np.ndarray, k: int = 10,
                              n_probe: int = 4, use_pallas: bool = True,
                              fused: bool = True):
        """Tier-aware batched search: route over all centroids on device,
        scan hot probes from the resident pack and cold probes from a
        host-gathered scratch — ONE ecoscan call over the concatenated
        blocks via `block_map`, so candidates, distances and tie-breaks
        are bit-identical to the all-resident index (DESIGN.md §14)."""
        import jax.numpy as jnp
        q = np.atleast_2d(np.asarray(q, np.float32))
        if q.shape[0] == 0:
            return (np.zeros((0, k), np.int64),
                    np.zeros((0, k), np.float32))
        n_probe = min(n_probe, self.n_clusters)
        self._ensure_tiers()
        probes = self._route_device(q, n_probe)
        for _attempt in range(self.n_clusters + 1):
            flat = probes.reshape(-1)
            valid = flat[flat >= 0]
            hot_mask = self._hot_row[valid] >= 0
            cold_cids = sorted(set(map(int, valid[~hot_mask]))
                               - self._quarantined)
            if not cold_cids:
                scratch = None
                break
            scratch = self._gather_cold(cold_cids)
            if len(scratch[3]) == len(cold_cids):
                break
            # a cold probe quarantined mid-gather: re-route wider
            probes = self._route_device(q, n_probe)
        else:
            scratch = None
        flat = probes.reshape(-1)
        valid = flat[flat >= 0]
        n_hot = int((self._hot_row[valid] >= 0).sum())
        self.stats.tier_hot_hits += n_hot
        self.stats.tier_cold_hits += int(valid.size) - n_hot
        self._tm.record(probes)

        R = len(self._row_cluster)
        bmap = self._hot_row.astype(np.int32).copy()
        hot_j, hot_lens_j, _ = self._hot_arrays()
        if scratch is not None:
            sdata, sids, slens, kept = scratch
            for i, c in enumerate(kept):
                bmap[c] = R + i
            scan_data = jnp.concatenate([hot_j, jnp.asarray(sdata)], axis=0)
            scan_lens = jnp.concatenate(
                [hot_lens_j, jnp.asarray(slens)], axis=0)
            slot_ids = np.concatenate([self._hot_ids, sids], axis=0)
        else:
            scan_data, scan_lens = hot_j, hot_lens_j
            slot_ids = self._hot_ids
        if int(scan_data.shape[0]) == 0:
            return (np.full((q.shape[0], k), -1, np.int64),
                    np.zeros((q.shape[0], k), np.float32))
        dists, slots = ops.ecoscan(
            jnp.asarray(q), scan_data, scan_lens, jnp.asarray(probes),
            k=k, use_pallas=use_pallas, block_map=jnp.asarray(bmap))
        # power-model accounting: dense routing + scanned candidates
        self.stats.distance_ops += q.shape[0] * self.n_clusters
        csizes = np.asarray([len(m) for m in self.cluster_members],
                            np.int64)
        self.stats.distance_ops += int(csizes[valid].sum())
        slots = np.asarray(slots)
        ids = np.where(slots >= 0,
                       slot_ids.reshape(-1)[np.clip(slots, 0, None)], -1)
        return ids, np.asarray(dists)

    # ------------------------------------------------------ persistence

    def _write_state(self, d: str):
        if self._tier_live:
            self._tier_sync(moves=0)          # fold dirty into the tiers
        super()._write_state(d)
        if not self._tier_live:
            return
        self._cold.write_snapshot(d)          # verified + compacted
        store.write_segment(
            os.path.join(d, TIER_STATE),
            [pickle.dumps({"hot": sorted(self.hot_clusters()),
                           "cap": self._cap,
                           "ema": self._tm.ema,
                           "budget": self.device_budget_bytes},
                          protocol=pickle.HIGHEST_PROTOCOL)],
            kind=_TIER_KIND)

    def _restore_extra(self, j: "store.Journal", g: int) -> None:
        files = j.manifest(g)["files"]
        if TIER_STATE not in files:
            return
        meta, recs = store.decode_segment(
            j.read_file(g, TIER_STATE),
            os.path.join(j.gen_dir(g), TIER_STATE))
        if meta.get("kind") != _TIER_KIND or len(recs) != 1:
            raise store.CorruptSegmentError(
                f"generation {g}: malformed {TIER_STATE}")
        self._restored_tiering = pickle.loads(recs[0])
        if self.device_budget_bytes is None:
            self.device_budget_bytes = self._restored_tiering["budget"]
        for name in (COLD_MANIFEST, COLD_PAYLOAD):
            if name in files:
                with open(os.path.join(self.storage_dir, name), "wb") as f:
                    f.write(j.read_file(g, name))


# ------------------------------------------------------------------ scrub

def scrub_cold_pack(dirpath: str, dim: Optional[int] = None
                    ) -> List[Dict[str, Any]]:
    """Verify a cold pack in `dirpath`: manifest segment integrity, every
    cluster's payload span in bounds, every per-cluster CRC. One report
    dict per item, PR 7 `scrub_path` shape (`ok=False` == corruption)."""
    man = os.path.join(dirpath, COLD_MANIFEST)
    out: List[Dict[str, Any]] = []
    if not os.path.exists(man):
        return out
    try:
        state = store.load_obj(man, kind=_COLD_KIND)
        out.append({"item": man, "ok": True,
                    "clusters": len(state["entries"])})
    except (store.StoreError, OSError) as e:
        return [{"item": man, "ok": False, "error": str(e)}]
    pack = ColdPack(dirpath, dim if dim is not None else state["dim"])
    for c in sorted(pack.entries):
        item = f"{pack.payload_path}#cluster_{c}"
        try:
            ids, vecs = pack.get(c, verify=True)
            if len(ids) != vecs.shape[0]:
                raise store.CorruptSegmentError(
                    f"cluster {c}: {len(ids)} ids vs {vecs.shape[0]} rows")
            out.append({"item": item, "ok": True, "rows": int(len(ids))})
        except (store.StoreError, OSError) as e:
            out.append({"item": item, "ok": False, "error": str(e)})
    return out


def scrub_tier_state(root: str) -> List[Dict[str, Any]]:
    """Verify tier-assignment consistency for the latest generation of a
    Journal root: hot ∩ cold = ∅ and hot ∪ cold ∪ quarantined covers
    every cluster (each cluster in exactly one tier), plus the staged
    cold pack's per-cluster CRCs."""
    j = store.Journal(root)
    g = j.latest()
    out: List[Dict[str, Any]] = []
    if g is None:
        return out
    files = j.manifest(g)["files"]
    if TIER_STATE not in files:
        return out
    gen_dir = j.gen_dir(g)
    item = os.path.join(gen_dir, TIER_STATE)
    try:
        meta, recs = store.decode_segment(
            j.read_file(g, TIER_STATE), item)
        if meta.get("kind") != _TIER_KIND or len(recs) != 1:
            raise store.CorruptSegmentError(f"{item}: malformed")
        tiering = pickle.loads(recs[0])
        smeta, srecs = store.decode_segment(
            j.read_file(g, "state.seg"), os.path.join(gen_dir, "state.seg"))
        estate = pickle.loads(srecs[0])
    except (store.StoreError, OSError) as e:
        return out + [{"item": item, "ok": False, "error": str(e)}]
    out.extend(scrub_cold_pack(gen_dir, dim=estate["dim"]))
    hot = set(tiering["hot"])
    quarantined = set(estate["quarantined"])
    cold = set()
    if COLD_MANIFEST in files:
        try:
            cman = store.load_obj(os.path.join(gen_dir, COLD_MANIFEST),
                                  kind=_COLD_KIND)
            cold = {int(c) for c in cman["entries"]}
        except (store.StoreError, OSError):
            pass                     # already reported by scrub_cold_pack
    problems = []
    both = hot & cold
    if both:
        problems.append(f"clusters in BOTH tiers: {sorted(both)[:8]}")
    every = set(range(int(estate["n_clusters"])))
    missing = every - hot - cold - quarantined
    if missing:
        problems.append(f"clusters in NO tier: {sorted(missing)[:8]}")
    qhot = hot & quarantined
    if qhot:
        problems.append(f"quarantined clusters marked hot: "
                        f"{sorted(qhot)[:8]}")
    rep: Dict[str, Any] = {"item": item, "ok": not problems,
                           "hot": len(hot), "cold": len(cold),
                           "quarantined": len(quarantined)}
    if problems:
        rep["error"] = "; ".join(problems)
    out.append(rep)
    return out
