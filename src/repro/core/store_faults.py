"""Deterministic crash + corruption injection for the storage layer.

Same philosophy as `serving/faults.py`: faults are indexed by a
deterministic COUNTER — here the filesystem-op index that
`store._fs_event` advances — never by wall clock, so the same plan
crashes at the same byte boundary on any host speed.

Two crash modes:

  * `CrashPlan(at=k)` — in-process: the k-th fs op raises
    `InjectedCrash`. The test abandons the live object and re-loads from
    disk, which exercises exactly the on-disk states a kill -9 between
    two syscalls can produce (writes are only considered durable after
    the fsync events this module can land between).
  * `REPRO_STORE_CRASH_AT=<k>` env var — hard: the k-th fs op calls
    `os._exit`, no flush, no atexit. Used by the subprocess kill-9 tests
    and `python -m repro.core.store_faults` below, which is the driver
    those tests (and `tools/soak_store.py`) spawn.

Corruption fuzzing is byte-level: `flip_byte` / `truncate_file` mutate a
committed file in place, modeling bit-rot and torn flash pages.
"""
from __future__ import annotations

import os
from typing import Callable, Optional

import numpy as np

from repro.core import store


class InjectedCrash(RuntimeError):
    """Raised by in-process crash plans so tests can tell scripted
    crashes apart from real bugs."""


class CrashPlan:
    """Context manager: crash at the `at`-th filesystem op (1-based)
    counted from entry. `fired` records whether the plan triggered."""

    def __init__(self, at: int, exit_code: Optional[int] = None):
        self.at = at
        self.exit_code = exit_code
        self.fired = False

    def _hook(self, name: str, count: int) -> None:
        if count == self.at:
            self.fired = True
            if self.exit_code is not None:
                os._exit(self.exit_code)
            raise InjectedCrash(f"injected crash at fs op {count} ({name})")

    def __enter__(self) -> "CrashPlan":
        store.reset_fs_ops()
        store.set_crash_hook(self._hook)
        return self

    def __exit__(self, *exc) -> None:
        store.set_crash_hook(None)


def count_fs_ops(fn: Callable[[], None]) -> int:
    """Run `fn` with a counting (non-crashing) hook; return how many fs
    ops it performed — the sweep bound for a CrashPlan series."""
    store.reset_fs_ops()
    store.set_crash_hook(None)
    try:
        fn()
    finally:
        n = store.fs_ops()
    return n


# ----------------------------------------------------------- byte fuzzing

def flip_byte(path: str, offset: int, xor: int = 0xFF) -> None:
    """XOR one byte in place (bit-rot model). Offset is clamped into the
    file so seeded sweeps never miss."""
    size = os.path.getsize(path)
    if size == 0:
        return
    offset = int(offset) % size
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ (xor & 0xFF)]))


def truncate_file(path: str, keep_bytes: int) -> None:
    """Truncate to `keep_bytes` (torn-page / partial-write model)."""
    with open(path, "r+b") as f:
        f.truncate(max(0, int(keep_bytes)))


# ----------------------------------------------- subprocess kill-9 driver

def _driver_workload(root: str, stage: str, seed: int = 0,
                     n: int = 96, dim: int = 16, wal_ops: int = 12) -> None:
    """Deterministic EcoVector workload for the kill-9 harness.

    Stages (each includes the previous ones' on-disk effects):
      build_save : build + first generation save
      wal        : + `wal_ops` journaled insert/delete mutations, each
                   acknowledged into ``<root>/acked.txt`` AFTER the
                   store-level op returns (the parent's ground truth for
                   "zero acknowledged writes lost")
      compact    : + a second save() folding the WAL into gen 1

    The ack file is written with raw os-level appends + fsync on a side
    channel so it never perturbs the injected fs-op count.
    """
    from repro.core.ecovector import EcoVector

    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, dim)).astype(np.float32)
    ev = EcoVector(dim, n_clusters=8, M=8, ef_construction=32,
                   storage_dir=os.path.join(root, "live"), seed=seed)
    ev.build(X)
    ev.save(os.path.join(root, "journal"))
    if stage == "build_save":
        return
    ack_fd = os.open(os.path.join(root, "acked.txt"),
                     os.O_WRONLY | os.O_CREAT | os.O_APPEND)

    def ack(line: str) -> None:
        os.write(ack_fd, (line + "\n").encode())
        os.fsync(ack_fd)

    base = 10 ** 6
    for i in range(wal_ops):
        if i % 3 == 2:
            vid = base + i - 1
            ev.delete(vid)
            ack(f"delete {vid}")
        else:
            vec = rng.normal(size=(dim,)).astype(np.float32)
            ev.insert(base + i, vec)
            ack(f"insert {base + i}")
    if stage == "wal":
        return
    ev.save()
    ack("compacted")


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--root", required=True)
    p.add_argument("--stage", default="wal",
                   choices=["build_save", "wal", "compact"])
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--wal-ops", type=int, default=12)
    args = p.parse_args(argv)
    # REPRO_STORE_CRASH_AT in the environment arms the hard-exit hook at
    # store import time; an uninjected run completes and exits 0.
    _driver_workload(args.root, args.stage, seed=args.seed,
                     wal_ops=args.wal_ops)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
