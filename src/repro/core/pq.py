"""Product quantisation: codebook training, encoding, ADC tables.

Used by the IVFPQ / HNSWPQ / IVFPQ-DISK baselines the paper compares
against. ADC scoring on-device goes through the `pq_adc` kernel (one-hot
MXU matmul — see kernels/pq_adc.py).
"""
from __future__ import annotations

import numpy as np

from repro.core.kmeans import kmeans


class PQ:
    def __init__(self, dim: int, m: int = 8, nbits: int = 8):
        assert dim % m == 0, "dim must divide into m sub-vectors"
        self.dim = dim
        self.m = m
        self.nbits = nbits
        self.ksub = 2 ** nbits
        self.dsub = dim // m
        self.codebooks = np.zeros((m, self.ksub, self.dsub), np.float32)

    def train(self, x: np.ndarray, iters: int = 8, seed: int = 0):
        x = np.asarray(x, np.float32)
        for j in range(self.m):
            sub = x[:, j * self.dsub:(j + 1) * self.dsub]
            cent, _ = kmeans(sub, min(self.ksub, sub.shape[0]), iters,
                             seed + j, use_pallas=False)
            self.codebooks[j, : cent.shape[0]] = cent
        return self

    def encode(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        codes = np.zeros((x.shape[0], self.m), np.uint8)
        for j in range(self.m):
            sub = x[:, j * self.dsub:(j + 1) * self.dsub]
            d = (np.sum(sub ** 2, 1)[:, None]
                 - 2 * sub @ self.codebooks[j].T
                 + np.sum(self.codebooks[j] ** 2, 1)[None, :])
            codes[:, j] = np.argmin(d, axis=1)
        return codes

    def decode(self, codes: np.ndarray) -> np.ndarray:
        out = np.zeros((codes.shape[0], self.dim), np.float32)
        for j in range(self.m):
            out[:, j * self.dsub:(j + 1) * self.dsub] = \
                self.codebooks[j][codes[:, j].astype(np.int64)]
        return out

    def adc_table(self, q: np.ndarray) -> np.ndarray:
        """Distance LUT [m, ksub] for one query (squared L2 per subspace)."""
        tabs = np.zeros((self.m, self.ksub), np.float32)
        for j in range(self.m):
            sub = q[j * self.dsub:(j + 1) * self.dsub]
            diff = self.codebooks[j] - sub
            tabs[j] = np.einsum("kd,kd->k", diff, diff)
        return tabs

    def adc_scores(self, q: np.ndarray, codes: np.ndarray) -> np.ndarray:
        tabs = self.adc_table(q)
        return tabs[np.arange(self.m)[None, :],
                    codes.astype(np.int64)].sum(axis=1)

    def memory_bytes(self, n: int) -> int:
        return n * self.m * self.nbits // 8 + self.ksub * self.dim * 4
