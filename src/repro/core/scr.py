"""Selective Content Reduction (paper §4).

Three steps, post-retrieval:
  1. Similarity Computation — split each retrieved document into sentences,
     form overlapping sliding windows (`sliding_window_size`, step
     `sliding_window_size - overlap_size`), embed, score against the query
     (device path: `scr_score` kernel).
  2. Selecting & Merging — top-1 window per document, extended by
     `context_extension_size` sentences each side, merged with source
     attribution.
  3. Reordering — documents ordered by their best window score (the
     implicit re-ranker that replaces Advanced-RAG's model).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Callable, List, Sequence, Tuple

import numpy as np

from repro.kernels import ops

_SENT_RE = re.compile(r"(?<=[.!?])\s+")


def split_sentences(text: str) -> List[str]:
    parts = [s.strip() for s in _SENT_RE.split(text.strip()) if s.strip()]
    return parts or ([text.strip()] if text.strip() else [])


def sliding_windows(sentences: Sequence[str], window: int,
                    overlap: int) -> List[Tuple[int, int]]:
    """Return [start, end) sentence spans. step = window - overlap >= 1."""
    n = len(sentences)
    if n == 0:
        return []
    window = max(1, min(window, n))
    step = max(1, window - overlap)
    spans = []
    i = 0
    while True:
        spans.append((i, min(i + window, n)))
        if i + window >= n:
            break
        i += step
    return spans


@dataclass
class SCRConfig:
    sliding_window_size: int = 3
    overlap_size: int = 2
    context_extension_size: int = 1
    use_pallas: bool = True


@dataclass
class SCRResult:
    texts: List[str]             # condensed docs, reordered
    order: List[int]             # original doc index per output slot
    scores: List[float]          # best-window score per output doc
    spans: List[Tuple[int, int]]  # chosen extended span per output doc
    tokens_before: int
    tokens_after: int


def _count_tokens(text: str) -> int:
    return len(text.split())


def apply_scr(query: str, docs: Sequence[str], embed: Callable,
              cfg: SCRConfig = SCRConfig()) -> SCRResult:
    """embed: list[str] -> np.ndarray [n, d] (query embedded with the same
    model, paper §2.3)."""
    qv = np.asarray(embed([query]))[0]
    d = qv.shape[0]
    doc_sents = [split_sentences(t) for t in docs]
    doc_spans = [sliding_windows(s, cfg.sliding_window_size, cfg.overlap_size)
                 for s in doc_sents]
    # embed all windows of all docs in one batch
    win_texts, owners = [], []
    for di, (sents, spans) in enumerate(zip(doc_sents, doc_spans)):
        for (a, b) in spans:
            win_texts.append(" ".join(sents[a:b]))
            owners.append(di)
    if not win_texts:
        return SCRResult(list(docs), list(range(len(docs))),
                         [0.0] * len(docs), [(0, 0)] * len(docs), 0, 0)
    wv = np.asarray(embed(win_texts), np.float32)      # [NW, d]
    # device scoring: one batch row (padded) per query — here B=1
    scores = np.asarray(ops.scr_score(
        wv[None], qv[None].astype(np.float32), use_pallas=cfg.use_pallas))[0]

    out_texts, out_scores, out_spans = [], [], []
    for di, (sents, spans) in enumerate(zip(doc_sents, doc_spans)):
        idx = [i for i, o in enumerate(owners) if o == di]
        if not idx:
            out_texts.append(docs[di])
            out_scores.append(-np.inf)
            out_spans.append((0, len(sents)))
            continue
        best_local = max(idx, key=lambda i: scores[i])
        a, b = spans[idx.index(best_local)]
        # context extension both sides
        a2 = max(0, a - cfg.context_extension_size)
        b2 = min(len(sents), b + cfg.context_extension_size)
        out_texts.append(" ".join(sents[a2:b2]))
        out_scores.append(float(scores[best_local]))
        out_spans.append((a2, b2))

    order = sorted(range(len(docs)), key=lambda i: -out_scores[i])
    before = sum(_count_tokens(t) for t in docs)
    after = sum(_count_tokens(out_texts[i]) for i in order)
    return SCRResult([out_texts[i] for i in order], order,
                     [out_scores[i] for i in order],
                     [out_spans[i] for i in order], before, after)


def build_prompt(query: str, result: SCRResult) -> str:
    ctx = "\n\n".join(f"[Doc {result.order[i] + 1}] {t}"
                      for i, t in enumerate(result.texts))
    return f"Context:\n{ctx}\n\nQuestion: {query}\nAnswer:"
