"""Selective Content Reduction (paper §4).

Three steps, post-retrieval:
  1. Similarity Computation — split each retrieved document into sentences,
     form overlapping sliding windows (`sliding_window_size`, step
     `sliding_window_size - overlap_size`), embed, score against the query
     (device path: `scr_score` kernel).
  2. Selecting & Merging — top-1 window per document, extended by
     `context_extension_size` sentences each side, merged with source
     attribution.
  3. Reordering — documents ordered by their best window score (the
     implicit re-ranker that replaces Advanced-RAG's model).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels import ops

_SENT_RE = re.compile(r"(?<=[.!?])\s+")


def split_sentences(text: str) -> List[str]:
    parts = [s.strip() for s in _SENT_RE.split(text.strip()) if s.strip()]
    return parts or ([text.strip()] if text.strip() else [])


def sliding_windows(sentences: Sequence[str], window: int,
                    overlap: int) -> List[Tuple[int, int]]:
    """Return [start, end) sentence spans. step = window - overlap >= 1."""
    n = len(sentences)
    if n == 0:
        return []
    window = max(1, min(window, n))
    step = max(1, window - overlap)
    spans = []
    i = 0
    while True:
        spans.append((i, min(i + window, n)))
        if i + window >= n:
            break
        i += step
    return spans


@dataclass
class SCRConfig:
    sliding_window_size: int = 3
    overlap_size: int = 2
    context_extension_size: int = 1
    use_pallas: bool = True


@dataclass
class SCRResult:
    texts: List[str]             # condensed docs, reordered
    order: List[int]             # original doc index per output slot
    scores: List[float]          # best-window score per output doc
    spans: List[Tuple[int, int]]  # chosen extended span per output doc
    tokens_before: int
    tokens_after: int


def _count_tokens(text: str) -> int:
    return len(text.split())


def segment_best_windows(scores: np.ndarray, owners: Sequence[int],
                         n_docs: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-document argmax over a flat window score array: the host mirror
    of the `scr_select` kernel's per-block segment-argmax.

    scores: [NW] flat window scores; owners: [NW] owning doc per window.
    Returns (best [n_docs] — flat index of each doc's best window, valid
    only where the doc owns windows; counts [n_docs] — windows per doc).
    Ties resolve to the lowest flat index (first max), matching both the
    kernel's `argmax` and the previous Python `max()` scan.
    """
    scores = np.asarray(scores)
    owners = np.asarray(owners, np.int64)
    counts = np.bincount(owners, minlength=n_docs)[:n_docs]
    if len(owners) == 0:
        return np.zeros(n_docs, np.int64), counts
    # sort by (owner asc, score desc, flat index asc): the first row of
    # each owner group is that doc's first-max window
    srt = np.lexsort((np.arange(len(owners)), -scores, owners))
    starts = np.searchsorted(owners[srt], np.arange(n_docs), side="left")
    best = srt[np.minimum(starts, len(owners) - 1)]
    return best, counts


def apply_scr(query: str, docs: Sequence[str], embed: Callable,
              cfg: SCRConfig = SCRConfig()) -> SCRResult:
    """embed: list[str] -> np.ndarray [n, d] (query embedded with the same
    model, paper §2.3)."""
    qv = np.asarray(embed([query]))[0]
    doc_sents = [split_sentences(t) for t in docs]
    doc_spans = [sliding_windows(s, cfg.sliding_window_size, cfg.overlap_size)
                 for s in doc_sents]
    # embed all windows of all docs in one batch
    win_texts, owners = [], []
    for di, (sents, spans) in enumerate(zip(doc_sents, doc_spans)):
        for (a, b) in spans:
            win_texts.append(" ".join(sents[a:b]))
            owners.append(di)
    if not win_texts:
        return SCRResult(list(docs), list(range(len(docs))),
                         [0.0] * len(docs), [(0, 0)] * len(docs), 0, 0)
    wv = np.asarray(embed(win_texts), np.float32)      # [NW, d]
    # device scoring: one batch row (padded) per query — here B=1
    scores = np.asarray(ops.scr_score(
        wv[None], qv[None].astype(np.float32), use_pallas=cfg.use_pallas))[0]

    # per-doc best window via segment ops (shared selection semantics with
    # the scr_select device kernel), not an O(NW·docs) owner scan
    best, counts = segment_best_windows(scores, owners, len(docs))
    offsets = np.concatenate(([0], np.cumsum(counts)))
    out_texts, out_scores, out_spans = [], [], []
    for di, (sents, spans) in enumerate(zip(doc_sents, doc_spans)):
        if not counts[di]:
            out_texts.append(docs[di])
            out_scores.append(-np.inf)
            out_spans.append((0, len(sents)))
            continue
        a, b = spans[int(best[di]) - int(offsets[di])]
        # context extension both sides
        a2 = max(0, a - cfg.context_extension_size)
        b2 = min(len(sents), b + cfg.context_extension_size)
        out_texts.append(" ".join(sents[a2:b2]))
        out_scores.append(float(scores[best[di]]))
        out_spans.append((a2, b2))

    order = sorted(range(len(docs)), key=lambda i: -out_scores[i])
    before = sum(_count_tokens(t) for t in docs)
    after = sum(_count_tokens(out_texts[i]) for i in order)
    return SCRResult([out_texts[i] for i in order], order,
                     [out_scores[i] for i in order],
                     [out_spans[i] for i in order], before, after)


def apply_scr_batch(queries: Sequence[str],
                    doc_ids_per_query: Sequence[Sequence[int]],
                    index, embed: Callable,
                    qvs: Optional[np.ndarray] = None,
                    use_pallas: Optional[bool] = None) -> List[SCRResult]:
    """Batched SCR over a corpus-resident window index (DESIGN.md §6–§7).

    `index` is a `WindowIndex`: sentences, window spans, and window
    embeddings were computed at build time, so the only embed call here is
    for the queries (skipped too when `qvs` [B, d] is supplied by the
    caller, e.g. the retrieval stage). One fused `scr_select` kernel call
    scores every (query, retrieved doc) pair AND picks each doc's best
    window on device; the host does string assembly only.

    Returns one `SCRResult` per query, bit-identical in spans/order to
    per-query `apply_scr` on the same inputs.
    """
    cfg = index.cfg
    if use_pallas is None:
        use_pallas = cfg.use_pallas
    B = len(queries)
    if B == 0:
        return []
    if qvs is None:
        qvs = np.asarray(embed(list(queries)), np.float32)
    K = max((len(ids) for ids in doc_ids_per_query), default=0)
    data, lens = index.pack()
    if K == 0 or not lens.any():
        # no retrieved docs, or no doc has windows: pure host fallback
        return [_assemble(q, ids, None, None, index)
                for q, ids in zip(queries, doc_ids_per_query)]
    ids_m = np.full((B, K), -1, np.int64)
    for b, row in enumerate(doc_ids_per_query):
        ids_m[b, :len(row)] = row
    data_j, lens_j = index.device_arrays()
    index.record_select(ids_m)     # per-query DMA'd-block accounting
    scores, wins = ops.scr_select(qvs.astype(np.float32), data_j, lens_j,
                                  ids_m, use_pallas=use_pallas)
    scores = np.asarray(scores)
    wins = np.asarray(wins)
    return [_assemble(q, ids, scores[b], wins[b], index)
            for b, (q, ids) in enumerate(zip(queries, doc_ids_per_query))]


def _assemble(query: str, doc_ids: Sequence[int],
              scores_row: Optional[np.ndarray],
              wins_row: Optional[np.ndarray], index) -> SCRResult:
    """Host-side Selecting & Merging & Reordering (§4 steps 2–3) from the
    kernel's per-doc (score, window) pairs — string work only."""
    cfg = index.cfg
    n = len(doc_ids)
    if all(not index.spans[di] for di in doc_ids):
        # matches apply_scr's "no windows anywhere" early return
        docs = [index.texts[di] for di in doc_ids]
        return SCRResult(docs, list(range(n)), [0.0] * n, [(0, 0)] * n,
                         0, 0)
    out_texts, out_scores, out_spans = [], [], []
    for j, di in enumerate(doc_ids):
        sents, spans = index.sents[di], index.spans[di]
        if not spans:
            out_texts.append(index.texts[di])
            out_scores.append(-np.inf)
            out_spans.append((0, len(sents)))
            continue
        a, b = spans[int(wins_row[j])]
        a2 = max(0, a - cfg.context_extension_size)
        b2 = min(len(sents), b + cfg.context_extension_size)
        out_texts.append(" ".join(sents[a2:b2]))
        out_scores.append(float(scores_row[j]))
        out_spans.append((a2, b2))
    order = sorted(range(n), key=lambda i: -out_scores[i])
    before = sum(index.ntok[di] for di in doc_ids)
    after = sum(_count_tokens(out_texts[i]) for i in order)
    return SCRResult([out_texts[i] for i in order], order,
                     [out_scores[i] for i in order],
                     [out_spans[i] for i in order], before, after)


def build_prompt(query: str, result: SCRResult) -> str:
    ctx = "\n\n".join(f"[Doc {result.order[i] + 1}] {t}"
                      for i, t in enumerate(result.texts))
    return f"Context:\n{ctx}\n\nQuestion: {query}\nAnswer:"
