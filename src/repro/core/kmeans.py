"""Batched k-means for cluster partitioning (EcoVector §3.1.1).

Assignment runs on the device via the `kmeans_assign` Pallas kernel (MXU
distance matmuls); the update step is a segment-sum. k-means++-style
seeding by distance-weighted sampling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def kmeans_pp_init(x: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    centroids = [x[rng.integers(n)]]
    d2 = None
    for _ in range(1, k):
        c = np.asarray(centroids[-1])
        nd = np.sum((x - c) ** 2, axis=1)
        d2 = nd if d2 is None else np.minimum(d2, nd)
        p = d2 / max(d2.sum(), 1e-12)
        centroids.append(x[rng.choice(n, p=p)])
    return np.stack(centroids).astype(np.float32)


def kmeans(x, k: int, iters: int = 10, seed: int = 0, use_pallas: bool = True):
    """x: [N, d] -> (centroids [k, d], assign [N] i32)."""
    x = np.asarray(x, np.float32)
    n, d = x.shape
    k = min(k, n)
    cent = kmeans_pp_init(x, k, seed)
    xj = jnp.asarray(x)
    for _ in range(iters):
        assign, _ = ops.kmeans_assign(xj, jnp.asarray(cent),
                                      use_pallas=use_pallas)
        sums = jax.ops.segment_sum(xj, assign, num_segments=k)
        cnt = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), assign,
                                  num_segments=k)
        new = sums / jnp.maximum(cnt[:, None], 1.0)
        # re-seed empty clusters at the farthest points
        empty = cnt == 0
        if bool(jnp.any(empty)):
            _, dist = ops.kmeans_assign(xj, new, use_pallas=use_pallas)
            far = np.argsort(-np.asarray(dist))
            new_np = np.asarray(new)
            eidx = np.where(np.asarray(empty))[0]
            new_np[eidx] = x[far[: len(eidx)]]
            new = jnp.asarray(new_np)
        cent = np.asarray(new)
    assign, _ = ops.kmeans_assign(xj, jnp.asarray(cent),
                                  use_pallas=use_pallas)
    return cent.astype(np.float32), np.asarray(assign)
