"""EcoVector (paper §3): the mobile-tailored two-tier ANN index.

Faithful reproduction:
  * k-means cluster partitioning (§3.1.1),
  * HNSW over centroids, held in RAM (§3.1.2),
  * an independent small HNSW graph per cluster, *spilled to real disk
    files* and loaded/released per query (§3.1.3-3.1.4),
  * search = centroid k-ANNS -> load n_probe cluster graphs -> per-cluster
    graph search -> merge (§3.2),
  * incremental insert/delete via Algorithms 1 & 2 (§3.3), updating only
    the owning cluster's graph.

TPU-native path: `search_device` scans probed clusters densely with the
`ecoscan` Pallas kernel (DESIGN.md §2 explains why dense-MXU-scan replaces
intra-cluster graph traversal on TPU); cluster payloads stay in a padded
[NC, CAP, d] HBM tensor and only probed blocks move into VMEM.
"""
from __future__ import annotations

import io
import os
import pickle
import tempfile
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.hnsw import HNSW
from repro.core.kmeans import kmeans
from repro.kernels import ops


@dataclass
class EcoVectorStats:
    disk_loads: int = 0
    disk_bytes: int = 0
    disk_time_s: float = 0.0
    distance_ops: int = 0


class EcoVector:
    def __init__(self, dim: int, n_clusters: int = 64, M: int = 16,
                 ef_construction: int = 100, storage_dir: Optional[str] = None,
                 cache_clusters: int = 0, seed: int = 0):
        self.dim = dim
        self.n_clusters = n_clusters
        self.M = M
        self.efc = ef_construction
        self.seed = seed
        self.storage_dir = storage_dir or tempfile.mkdtemp(prefix="ecovector_")
        os.makedirs(self.storage_dir, exist_ok=True)
        self.centroids: Optional[np.ndarray] = None
        self.centroid_graph: Optional[HNSW] = None
        self.assign: Dict[int, int] = {}          # vid -> cluster
        self.cluster_members: List[List[int]] = []
        self.stats = EcoVectorStats()
        # tiny LRU of loaded cluster graphs (EdgeRAG-style caching, off by
        # default: the paper's EcoVector releases after each query)
        self.cache_clusters = cache_clusters
        self._cache: Dict[int, HNSW] = {}
        self._device_pack = None

    # ----------------------------------------------------------- build

    def build(self, vectors: np.ndarray, ids: Optional[np.ndarray] = None):
        vectors = np.asarray(vectors, np.float32)
        n = vectors.shape[0]
        ids = np.arange(n, dtype=np.int64) if ids is None else ids
        k = min(self.n_clusters, max(1, n))
        self.centroids, assign = kmeans(vectors, k, seed=self.seed)
        self.n_clusters = self.centroids.shape[0]
        # centroid HNSW in RAM
        self.centroid_graph = HNSW(self.dim, M=self.M, ef_construction=self.efc,
                                   seed=self.seed,
                                   max_elements=self.n_clusters)
        for c in range(self.n_clusters):
            self.centroid_graph.insert(c, self.centroids[c])
        # per-cluster graphs, spilled to disk
        self.cluster_members = [[] for _ in range(self.n_clusters)]
        for c in range(self.n_clusters):
            mask = assign == c
            cvids = ids[mask]
            self.cluster_members[c] = list(map(int, cvids))
            g = HNSW(self.dim, M=self.M, ef_construction=self.efc,
                     seed=self.seed + c, max_elements=max(len(cvids), 4))
            for vid, vec in zip(cvids, vectors[mask]):
                g.insert(int(vid), vec)
                self.assign[int(vid)] = c
            self._store_cluster(c, g)
        self._device_pack = None
        return self

    # ------------------------------------------------------ disk tier

    def _path(self, c: int) -> str:
        return os.path.join(self.storage_dir, f"cluster_{c:05d}.bin")

    def _store_cluster(self, c: int, g: HNSW):
        buf = io.BytesIO()
        pickle.dump(g, buf, protocol=pickle.HIGHEST_PROTOCOL)
        with open(self._path(c), "wb") as f:
            f.write(buf.getvalue())
        self._cache.pop(c, None)

    def _load_cluster(self, c: int) -> HNSW:
        if c in self._cache:
            return self._cache[c]
        t0 = time.perf_counter()
        with open(self._path(c), "rb") as f:
            data = f.read()
        g = pickle.loads(data)
        self.stats.disk_loads += 1
        self.stats.disk_bytes += len(data)
        self.stats.disk_time_s += time.perf_counter() - t0
        if self.cache_clusters:
            if len(self._cache) >= self.cache_clusters:
                self._cache.pop(next(iter(self._cache)))
            self._cache[c] = g
        return g

    def _release_cluster(self, c: int, g: HNSW, dirty: bool = False):
        if dirty:
            self._store_cluster(c, g)
        # not cached -> dropped; that's the partial-loading contract

    # ----------------------------------------------------------- search

    def search(self, q: np.ndarray, k: int = 10, n_probe: int = 4,
               ef_search: int = 32) -> Tuple[np.ndarray, np.ndarray]:
        """Faithful host search: centroid graph -> load clusters -> graph
        search per cluster -> merge -> release."""
        q = np.asarray(q, np.float32)
        n0 = self.centroid_graph.n_dist
        cids, _ = self.centroid_graph.search(q, n_probe,
                                             ef_search=max(n_probe * 2, 16))
        self.stats.distance_ops += self.centroid_graph.n_dist - n0
        best_ids: List[int] = []
        best_d: List[float] = []
        for c in map(int, cids):
            g = self._load_cluster(c)
            ids, dists = g.search(q, k, ef_search=ef_search)
            self.stats.distance_ops += g.n_dist
            best_ids.extend(map(int, ids))
            best_d.extend(map(float, dists))
            self._release_cluster(c, g)
        order = np.argsort(best_d)[:k]
        return (np.asarray([best_ids[i] for i in order], np.int64),
                np.asarray([best_d[i] for i in order], np.float32))

    # ----------------------------------------------------- device path

    def device_pack(self, cap: Optional[int] = None):
        """Pack clusters into the padded [NC, CAP, d] HBM layout consumed by
        the ecoscan kernel. Rebuilt lazily after updates."""
        if self._device_pack is not None:
            return self._device_pack
        sizes = [len(m) for m in self.cluster_members]
        cap = cap or max(8, int(np.max(sizes)) if sizes else 8)
        nc = self.n_clusters
        data = np.zeros((nc, cap, self.dim), np.float32)
        slot_ids = -np.ones((nc, cap), np.int64)
        lens = np.zeros((nc,), np.int32)
        for c in range(nc):
            g = self._load_cluster(c)
            ids, vecs = g.graph_arrays()
            m = min(len(ids), cap)
            data[c, :m] = vecs[:m]
            slot_ids[c, :m] = ids[:m]
            lens[c] = m
        self._device_pack = (data, lens, slot_ids, cap)
        return self._device_pack

    def search_device(self, q: np.ndarray, k: int = 10, n_probe: int = 4,
                      use_pallas: bool = True):
        """TPU-native batched search: centroid routing by dense matmul
        top-k, probed clusters scanned by the ecoscan kernel."""
        import jax.numpy as jnp
        q = np.atleast_2d(np.asarray(q, np.float32))
        data, lens, slot_ids, cap = self.device_pack()
        d2 = (np.sum(q ** 2, 1)[:, None] - 2 * q @ self.centroids.T
              + np.sum(self.centroids ** 2, 1)[None, :])
        probes = np.argsort(d2, axis=1)[:, :n_probe].astype(np.int32)
        dists, slots = ops.ecoscan(jnp.asarray(q), jnp.asarray(data),
                                   jnp.asarray(lens), jnp.asarray(probes),
                                   k=k, use_pallas=use_pallas)
        slots = np.asarray(slots)
        ids = np.where(slots >= 0,
                       slot_ids.reshape(-1)[np.clip(slots, 0, None)], -1)
        return ids, np.asarray(dists)

    # ----------------------------------------------------------- update

    def insert(self, vid: int, vec: np.ndarray):
        """§3.3.1: route to nearest centroid, Algorithm-1 insert into that
        cluster's graph only."""
        vec = np.asarray(vec, np.float32)
        cids, _ = self.centroid_graph.search(vec, 1, ef_search=16)
        c = int(cids[0])
        g = self._load_cluster(c)
        g.insert(int(vid), vec)
        self.assign[int(vid)] = c
        self.cluster_members[c].append(int(vid))
        self._release_cluster(c, g, dirty=True)
        self._device_pack = None

    def delete(self, vid: int):
        """§3.3.2: Algorithm-2 delete inside the owning cluster's graph."""
        c = self.assign.pop(int(vid), None)
        if c is None:
            return
        g = self._load_cluster(c)
        g.delete(int(vid))
        if int(vid) in self.cluster_members[c]:
            self.cluster_members[c].remove(int(vid))
        self._release_cluster(c, g, dirty=True)
        self._device_pack = None

    # ------------------------------------------------------- accounting

    def ram_bytes(self) -> int:
        """Resident memory: centroid graph + ids (Table 1 EcoVector row:
        4*Nc*(d + M'/(1-p0)) + 8N + one loaded inverted list)."""
        base = self.centroid_graph.memory_bytes() if self.centroid_graph else 0
        ids = 8 * len(self.assign)
        one_list = self.avg_cluster_bytes()
        return base + ids + one_list

    def disk_bytes(self) -> int:
        return sum(os.path.getsize(self._path(c))
                   for c in range(self.n_clusters)
                   if os.path.exists(self._path(c)))

    def avg_cluster_bytes(self) -> int:
        sizes = [os.path.getsize(self._path(c))
                 for c in range(self.n_clusters)
                 if os.path.exists(self._path(c))]
        return int(np.mean(sizes)) if sizes else 0

    def cluster_sizes(self) -> np.ndarray:
        return np.asarray([len(m) for m in self.cluster_members])
