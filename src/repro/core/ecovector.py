"""EcoVector (paper §3): the mobile-tailored two-tier ANN index.

Faithful reproduction:
  * k-means cluster partitioning (§3.1.1),
  * HNSW over centroids, held in RAM (§3.1.2),
  * an independent small HNSW graph per cluster, *spilled to real disk
    files* and loaded/released per query (§3.1.3-3.1.4),
  * search = centroid k-ANNS -> load n_probe cluster graphs -> per-cluster
    graph search -> merge (§3.2),
  * incremental insert/delete via Algorithms 1 & 2 (§3.3), updating only
    the owning cluster's graph.

TPU-native path: `search_device_batched` routes and scans fully on device
(one fused jitted route->scan call, DESIGN.md §4); cluster payloads stay in
a padded [NC, CAP, d] HBM tensor (DESIGN.md §2) and only probed blocks move
into VMEM. The pack is maintained *incrementally*: insert/delete mark only
the owning cluster dirty and `device_pack` rewrites just that cluster's
block in place, growing CAP geometrically on overflow (DESIGN.md §3) —
steady-state update cost is O(cluster), not O(N) disk reads.

Durability (DESIGN.md §12): cluster spill files are checksummed segments
written atomically (`core/store.py`); `save()` commits the whole index
(centroids, centroid graph, id maps, spill files) as a generation-
numbered snapshot, journaled `insert`/`delete` mutations hit a fsync'd
write-ahead log before they apply, and `load()` = latest generation +
WAL replay. A spill file that fails its checksum at query time is
quarantined and counted; search skips it and widens the probe set, and
`rebuild_cluster` restores it from salvage or caller-supplied vectors.
"""
from __future__ import annotations

import os
import pickle
import tempfile
import time
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import store
from repro.core.hnsw import HNSW
from repro.core.kmeans import kmeans
from repro.kernels import ops

_CLUSTER_KIND = "ecovector.cluster"
_STATE_KIND = "ecovector.state"


@dataclass
class EcoVectorStats:
    disk_loads: int = 0
    disk_bytes: int = 0
    disk_time_s: float = 0.0
    distance_ops: int = 0
    # device-pack maintenance accounting (DESIGN.md §3)
    pack_full_builds: int = 0       # whole [NC, CAP, d] rebuilds from disk
    pack_cluster_repacks: int = 0   # single-cluster block rewrites in place
    pack_grows: int = 0             # geometric CAP growths on overflow
    truncated_vectors: int = 0      # rows CURRENTLY dropped by a forced cap
    # durability accounting (DESIGN.md §12)
    corrupt_reads: int = 0          # spill-file loads that failed checksums
    quarantined: int = 0            # clusters CURRENTLY quarantined
    rebuilt: int = 0                # clusters restored (rebuild/auto-heal)
    wal_replayed: int = 0           # mutations replayed by load()
    # tiering accounting (DESIGN.md §14; stays zero on untirered indexes)
    tier_hot_hits: int = 0          # probes served from the device pack
    tier_cold_hits: int = 0         # probes served from the cold host pack
    promotions: int = 0             # clusters moved cold -> hot
    demotions: int = 0              # clusters moved hot -> cold


class EcoVector:
    def __init__(self, dim: int, n_clusters: int = 64, M: int = 16,
                 ef_construction: int = 100, storage_dir: Optional[str] = None,
                 cache_clusters: int = 0, seed: int = 0):
        self.dim = dim
        self.n_clusters = n_clusters
        self.M = M
        self.efc = ef_construction
        self.seed = seed
        self.storage_dir = storage_dir or tempfile.mkdtemp(prefix="ecovector_")
        os.makedirs(self.storage_dir, exist_ok=True)
        self.centroids: Optional[np.ndarray] = None
        self.centroid_graph: Optional[HNSW] = None
        self.assign: Dict[int, int] = {}          # vid -> cluster
        self.cluster_members: List[List[int]] = []
        self.stats = EcoVectorStats()
        # tiny LRU of loaded cluster graphs (EdgeRAG-style caching, off by
        # default: the paper's EcoVector releases after each query)
        self.cache_clusters = cache_clusters
        self._cache: Dict[int, HNSW] = {}         # insertion order == LRU
        # durability state (DESIGN.md §12)
        self._quarantined: Set[int] = set()       # clusters failing checksums
        self._salvage: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._journal: Optional[store.Journal] = None
        self._persist_root: Optional[str] = None
        self._replaying = False                   # WAL replay: don't re-log
        self._reset_pack_state()

    def _reset_pack_state(self):
        self._device_pack: Optional[Tuple] = None  # (data, lens, slots, cap)
        self._dirty: Set[int] = set()              # clusters needing repack
        self._mirror = None                        # jnp (data, lens) copies
        self._mirror_dirty: Set[int] = set()       # blocks stale on device
        self._centroids_dev = None
        self._pack_forced_cap: Optional[int] = None  # explicit cap budget
        self._trunc_by_cluster: Dict[int, int] = {}  # rows currently dropped
        self._pending_graphs: Dict[int, HNSW] = {}   # dirty graphs in hand

    # ----------------------------------------------------------- build

    def build(self, vectors: np.ndarray, ids: Optional[np.ndarray] = None):
        vectors = np.asarray(vectors, np.float32)
        n = vectors.shape[0]
        ids = np.arange(n, dtype=np.int64) if ids is None else ids
        k = min(self.n_clusters, max(1, n))
        self.centroids, assign = kmeans(vectors, k, seed=self.seed)
        self.n_clusters = self.centroids.shape[0]
        # centroid HNSW in RAM
        self.centroid_graph = HNSW(self.dim, M=self.M, ef_construction=self.efc,
                                   seed=self.seed,
                                   max_elements=self.n_clusters)
        for c in range(self.n_clusters):
            self.centroid_graph.insert(c, self.centroids[c])
        # per-cluster graphs, spilled to disk
        self.cluster_members = [[] for _ in range(self.n_clusters)]
        for c in range(self.n_clusters):
            mask = assign == c
            cvids = ids[mask]
            self.cluster_members[c] = list(map(int, cvids))
            g = HNSW(self.dim, M=self.M, ef_construction=self.efc,
                     seed=self.seed + c, max_elements=max(len(cvids), 4))
            for vid, vec in zip(cvids, vectors[mask]):
                g.insert(int(vid), vec)
                self.assign[int(vid)] = c
            self._store_cluster(c, g)
        self._reset_pack_state()
        return self

    # ------------------------------------------------------ disk tier

    def _path(self, c: int) -> str:
        return os.path.join(self.storage_dir, f"cluster_{c:05d}.bin")

    def _store_cluster(self, c: int, g: HNSW):
        # atomic + checksummed (tmp -> fsync -> rename): a crash mid-write
        # leaves the previous spill file intact, never a torn pickle
        store.dump_obj(self._path(c), g, kind=_CLUSTER_KIND)
        self._cache.pop(c, None)

    def _load_cluster(self, c: int) -> HNSW:
        """Load one spill file, validating magic + length + per-record
        CRC32 before any byte reaches pickle. Raises
        `store.CorruptSegmentError` on truncation/bit-rot and for
        already-quarantined clusters."""
        if c in self._quarantined:
            raise store.CorruptSegmentError(
                f"cluster {c} is quarantined (failed checksums earlier); "
                f"rebuild_cluster() restores it")
        if c in self._cache:
            # LRU promotion: move to the end (most recently used)
            g = self._cache.pop(c)
            self._cache[c] = g
            return g
        t0 = time.perf_counter()
        g = store.load_obj(self._path(c), kind=_CLUSTER_KIND)
        if not isinstance(g, HNSW):
            raise store.CorruptSegmentError(
                f"{self._path(c)}: decoded {type(g).__name__}, not HNSW")
        self.stats.disk_loads += 1
        self.stats.disk_bytes += os.path.getsize(self._path(c))
        self.stats.disk_time_s += time.perf_counter() - t0
        if self.cache_clusters:
            while len(self._cache) >= self.cache_clusters:
                self._cache.pop(next(iter(self._cache)))  # evict LRU head
            self._cache[c] = g
        return g

    def _load_cluster_checked(self, c: int) -> Optional[HNSW]:
        """Corruption-tolerant load: a cluster failing its checksum is
        auto-healed from an in-hand graph when possible, else quarantined
        and reported as None so the caller can degrade (skip + widen)."""
        if c in self._quarantined:
            return None
        try:
            return self._load_cluster(c)
        except (store.StoreError, OSError, pickle.UnpicklingError,
                EOFError) as e:
            self.stats.corrupt_reads += 1
            pending = self._pending_graphs.get(c)
            if pending is not None:
                # the freshest graph is still in hand from the update
                # path: rewrite the spill file instead of losing data
                self._store_cluster(c, pending)
                self.stats.rebuilt += 1
                return pending
            warnings.warn(f"cluster {c} failed validation ({e}); "
                          f"quarantined — search degrades around it",
                          stacklevel=3)
            self._quarantine(c)
            return None

    def _quarantine(self, c: int):
        """Take a corrupt cluster out of service: salvage what the device
        pack still holds, drop its members from the bookkeeping (their
        vectors are unreachable until rebuild), zero its pack block so
        host and device search agree, and move the bad file aside."""
        if c in self._quarantined:
            return
        self._quarantined.add(c)
        self._cache.pop(c, None)
        self._pending_graphs.pop(c, None)
        if self._device_pack is not None:
            data, lens, slot_ids, _ = self._device_pack
            m = int(lens[c])
            if m > 0 and c not in self._salvage:
                # pack rows predate the corruption: keep them as the
                # rebuild source (possibly stale if c was dirty)
                self._salvage[c] = (slot_ids[c, :m].copy(),
                                    data[c, :m].copy())
            data[c] = 0.0
            slot_ids[c, :] = -1
            lens[c] = 0
            self._mirror_dirty.add(c)
            self._dirty.discard(c)
        for vid in self.cluster_members[c]:
            self.assign.pop(int(vid), None)
        self.cluster_members[c] = []
        self._trunc_by_cluster.pop(c, None)
        if os.path.exists(self._path(c)):
            store.quarantine_file(self._path(c))
        self.stats.quarantined = len(self._quarantined)

    def rebuild_cluster(self, c: int, ids: Optional[np.ndarray] = None,
                        vectors: Optional[np.ndarray] = None) -> int:
        """Restore a quarantined cluster from source vectors: either the
        rows salvaged from the device pack at quarantine time, or
        caller-supplied (ids, vectors) re-embedded upstream. Returns the
        number of vectors restored."""
        if ids is None or vectors is None:
            if c not in self._salvage:
                raise store.StoreError(
                    f"cluster {c}: no salvage copy available — pass "
                    f"(ids, vectors) re-derived from the source corpus")
            ids, vectors = self._salvage[c]
        ids = np.asarray(ids, np.int64)
        vectors = np.asarray(vectors, np.float32)
        g = HNSW(self.dim, M=self.M, ef_construction=self.efc,
                 seed=self.seed + c, max_elements=max(len(ids), 4))
        for vid, vec in zip(ids, vectors):
            g.insert(int(vid), vec)
        self._quarantined.discard(c)
        self.stats.quarantined = len(self._quarantined)
        self._store_cluster(c, g)
        qfile = self._path(c) + ".quarantined"
        if os.path.exists(qfile):
            try:
                os.remove(qfile)
            except OSError:
                pass
        self.cluster_members[c] = list(map(int, ids))
        for vid in ids:
            self.assign[int(vid)] = c
        self._salvage.pop(c, None)
        self._mark_dirty(c, g)
        self.stats.rebuilt += 1
        return len(ids)

    def _release_cluster(self, c: int, g: HNSW, dirty: bool = False):
        if dirty:
            self._store_cluster(c, g)
        # not cached -> dropped; that's the partial-loading contract

    # ----------------------------------------------------------- search

    def _route(self, q: np.ndarray, n: int) -> List[int]:
        """Ranked centroid ids from the in-RAM graph (distance-op delta
        accounted), quarantined clusters filtered out."""
        n0 = self.centroid_graph.n_dist
        cids, _ = self.centroid_graph.search(q, n, ef_search=max(2 * n, 16))
        self.stats.distance_ops += self.centroid_graph.n_dist - n0
        return [c for c in map(int, cids) if c not in self._quarantined]

    def search(self, q: np.ndarray, k: int = 10, n_probe: int = 4,
               ef_search: int = 32) -> Tuple[np.ndarray, np.ndarray]:
        """Faithful host search: centroid graph -> load clusters -> graph
        search per cluster -> merge -> release.

        Corruption-tolerant: a cluster failing its checksum mid-query is
        quarantined and SKIPPED, and the probe set widens to the next-
        nearest healthy centroids so the query still scans `n_probe`
        clusters whenever enough survive (DESIGN.md §12)."""
        q = np.asarray(q, np.float32)
        want = min(n_probe, self.n_clusters)
        # over-ask just enough to cover already-quarantined clusters; the
        # healthy-index common case stays byte-identical to the old route
        ask = min(self.n_clusters, want + len(self._quarantined))
        ranked = self._route(q, ask)
        best_ids: List[int] = []
        best_d: List[float] = []
        scanned, i = 0, 0
        while i < len(ranked) and scanned < want:
            c = ranked[i]
            i += 1
            g = self._load_cluster_checked(c)
            if g is None:
                # a fresh quarantine: widen once to the full healthy
                # ranking so the probe budget is still met
                if len(ranked) < self.n_clusters - len(self._quarantined):
                    seen = set(ranked[:i]) | self._quarantined
                    ranked = ranked[:i] + [
                        c2 for c2 in self._route(q, self.n_clusters)
                        if c2 not in seen]
                continue
            n0 = g.n_dist
            ids, dists = g.search(q, k, ef_search=ef_search)
            # per-query delta only: the pickled graph's lifetime counter
            # includes construction-time distances
            self.stats.distance_ops += g.n_dist - n0
            best_ids.extend(map(int, ids))
            best_d.extend(map(float, dists))
            self._release_cluster(c, g)
            scanned += 1
        order = np.argsort(best_d)[:k]
        return (np.asarray([best_ids[i] for i in order], np.int64),
                np.asarray([best_d[i] for i in order], np.float32))

    # ----------------------------------------------------- device path

    def device_pack(self, cap: Optional[int] = None,
                    force_full: bool = False):
        """Return the padded [NC, CAP, d] HBM layout consumed by the
        ecoscan kernel as (data, lens, slot_ids, cap).

        Maintained incrementally: after insert/delete only the dirty
        clusters' blocks are rewritten in place (DESIGN.md §3). A full
        rebuild happens only on the first call, on an explicit `cap`
        change, or with `force_full=True` (the benchmark baseline; with
        `cap=None` it also lifts a previously forced cap).

        An explicit `cap` must be positive and is a hard per-cluster row
        budget: clusters
        beyond it are truncated loudly (warning + stats) and the pack
        never grows past it — incremental repacks keep honoring the
        budget."""
        if cap is not None and cap <= 0:
            raise ValueError(f"device_pack cap must be positive, got {cap} "
                             f"(omit cap for automatic sizing)")
        if (self._device_pack is None or force_full
                or (cap is not None and cap != self._device_pack[3])):
            self._build_pack(cap)
        else:
            if cap is not None:
                # same size as the current pack, but now an explicit budget
                self._pack_forced_cap = cap
            if self._dirty:
                self._repack_dirty()
        return self._device_pack

    def _build_pack(self, cap: Optional[int] = None):
        sizes = [len(m) for m in self.cluster_members]
        need = int(np.max(sizes)) if sizes else 0
        auto_cap = cap is None
        cap = cap or max(8, need)
        self._pack_forced_cap = None if auto_cap else cap
        nc = self.n_clusters
        data = np.zeros((nc, cap, self.dim), np.float32)
        slot_ids = -np.ones((nc, cap), np.int64)
        lens = np.zeros((nc,), np.int32)
        self._trunc_by_cluster = {}
        self._pending_graphs.clear()
        for c in range(nc):
            g = self._load_cluster_checked(c)
            if g is None:
                # quarantined (pre-existing or just detected): its block
                # stays empty and search degrades around it
                lens[c] = 0
                continue
            ids, vecs = g.graph_arrays()
            m = len(ids)
            if m > cap:
                if auto_cap:
                    # auto cap is sized from cluster_members; the graph
                    # holding more rows means the two diverged
                    raise RuntimeError(
                        f"cluster {c} graph has {m} rows but "
                        f"cluster_members implies cap {cap}: "
                        f"members/graph bookkeeping diverged")
                self._trunc_by_cluster[c] = m - cap
                warnings.warn(
                    f"device_pack cap={cap} truncates cluster {c} "
                    f"({m - cap} of {m} vectors dropped; recall will "
                    f"suffer — omit cap to size the pack automatically)",
                    stacklevel=3)
                m = cap
            data[c, :m] = vecs[:m]
            slot_ids[c, :m] = ids[:m]
            lens[c] = m
        self.stats.truncated_vectors = sum(self._trunc_by_cluster.values())
        self.stats.pack_full_builds += 1
        self._dirty.clear()
        self._mirror = None
        self._mirror_dirty.clear()
        self._device_pack = (data, lens, slot_ids, cap)

    def _repack_dirty(self):
        """Rewrite only the dirty clusters' blocks in place. An auto-cap
        pack grows CAP geometrically first if any dirty cluster overflows;
        a forced-cap pack keeps its budget and truncates loudly instead."""
        data, lens, slot_ids, cap = self._device_pack
        need = max(len(self.cluster_members[c]) for c in self._dirty)
        if need > cap and self._pack_forced_cap is None:
            new_cap = cap
            while new_cap < need:
                new_cap *= 2
            ndata = np.zeros((data.shape[0], new_cap, self.dim), np.float32)
            ndata[:, :cap] = data
            nslots = -np.ones((data.shape[0], new_cap), np.int64)
            nslots[:, :cap] = slot_ids
            data, slot_ids, cap = ndata, nslots, new_cap
            self.stats.pack_grows += 1
            self._mirror = None          # slot ids changed base: full refresh
            self._mirror_dirty.clear()
        for c in sorted(self._dirty):
            # insert/delete left the freshly-stored graph in hand — no
            # need to re-read the pickle we just wrote (an emptied graph
            # is falsy via HNSW.__len__, so test against None)
            g = self._pending_graphs.pop(c, None)
            if g is None:
                g = self._load_cluster_checked(c)
            if g is None:
                # corrupt mid-repack: _quarantine already zeroed the
                # block in place and pruned the bookkeeping
                continue
            ids, vecs = g.graph_arrays()
            m = len(ids)
            self._trunc_by_cluster.pop(c, None)
            if m > cap:
                if self._pack_forced_cap is None:
                    # growth is sized from cluster_members; a bigger graph
                    # means the two diverged (same invariant _build_pack
                    # enforces) — don't mask it as a cap problem
                    raise RuntimeError(
                        f"cluster {c} graph has {m} rows but "
                        f"cluster_members implies cap {cap}: "
                        f"members/graph bookkeeping diverged")
                # forced-cap budget: same loud contract as _build_pack
                self._trunc_by_cluster[c] = m - cap
                warnings.warn(
                    f"device_pack cap={cap} truncates cluster {c} on "
                    f"repack ({m - cap} of {m} vectors dropped; use "
                    f"device_pack(force_full=True) without cap to lift "
                    f"the budget)", stacklevel=4)
                m = cap
            data[c, :m] = vecs[:m]
            data[c, m:] = 0.0
            slot_ids[c, :m] = ids[:m]
            slot_ids[c, m:] = -1
            lens[c] = m
            self.stats.pack_cluster_repacks += 1
            self._mirror_dirty.add(c)
        self._dirty.clear()
        self.stats.truncated_vectors = sum(self._trunc_by_cluster.values())
        self._device_pack = (data, lens, slot_ids, cap)

    def _device_arrays(self):
        """jnp mirrors of the pack (+ centroids), refreshed per dirty block
        rather than re-uploading the whole [NC, CAP, d] tensor."""
        import jax.numpy as jnp
        data, lens, _, _ = self.device_pack()
        # jnp.array (copy) rather than jnp.asarray: the CPU backend may
        # zero-copy-alias an aligned numpy buffer, and repacks mutate the
        # host pack in place — an aliased mirror would change (and dirty-
        # block refreshes become no-ops) under callers' feet
        if self._mirror is None or self._mirror[0].shape != data.shape:
            self._mirror = (jnp.array(data), jnp.array(lens))
            self._mirror_dirty.clear()
        elif self._mirror_dirty:
            touched = sorted(self._mirror_dirty)
            mdata, _ = self._mirror
            mdata = mdata.at[jnp.asarray(touched)].set(
                jnp.asarray(data[touched]))
            self._mirror = (mdata, jnp.array(lens))
            self._mirror_dirty.clear()
        if self._centroids_dev is None:
            self._centroids_dev = jnp.array(
                np.asarray(self.centroids, np.float32))
        return self._mirror[0], self._mirror[1], self._centroids_dev

    def search_device_batched(self, q: np.ndarray, k: int = 10,
                              n_probe: int = 4, use_pallas: bool = True,
                              fused: bool = True):
        """TPU-native batched search over q [B, d]: centroid routing and
        the ecoscan cluster scan run as ONE jitted device call (matmul +
        lax.top_k feeding the scalar-prefetched kernel grid) — no host
        round-trip between route and scan. `fused=False` keeps the legacy
        two-step path (host numpy routing, then the scan) for before/after
        benchmarking. Returns (ids [B, k] int64, dists [B, k] f32)."""
        import jax.numpy as jnp
        q = np.atleast_2d(np.asarray(q, np.float32))
        if q.shape[0] == 0:
            return (np.zeros((0, k), np.int64), np.zeros((0, k), np.float32))
        n_probe = min(n_probe, self.n_clusters)
        data_j, lens_j, cent_j = self._device_arrays()
        _, lens, slot_ids, cap = self._device_pack
        if fused:
            dists, slots, probes = ops.route_and_scan(
                jnp.asarray(q), cent_j, data_j, lens_j,
                n_probe=n_probe, k=k, use_pallas=use_pallas)
            probes = np.asarray(probes)
        else:
            d2 = (np.sum(q ** 2, 1)[:, None] - 2 * q @ self.centroids.T
                  + np.sum(self.centroids ** 2, 1)[None, :])
            probes = np.argsort(d2, axis=1)[:, :n_probe].astype(np.int32)
            dists, slots = ops.ecoscan(jnp.asarray(q), data_j, lens_j,
                                       jnp.asarray(probes), k=k,
                                       use_pallas=use_pallas)
        # power-model accounting: dense routing + scanned candidates
        self.stats.distance_ops += q.shape[0] * self.n_clusters
        self.stats.distance_ops += int(lens[probes].sum())
        slots = np.asarray(slots)
        ids = np.where(slots >= 0,
                       slot_ids.reshape(-1)[np.clip(slots, 0, None)], -1)
        return ids, np.asarray(dists)

    def search_device(self, q: np.ndarray, k: int = 10, n_probe: int = 4,
                      use_pallas: bool = True):
        """Back-compat alias for `search_device_batched` (accepts [d] or
        [B, d] queries)."""
        return self.search_device_batched(q, k=k, n_probe=n_probe,
                                          use_pallas=use_pallas)

    # ----------------------------------------------------------- update

    # bound on update-path graphs kept resident between an update and the
    # next device query — preserves the partial-loading memory contract
    # (beyond this, repack falls back to a disk read for the eldest)
    PENDING_GRAPHS_MAX = 8

    def _pack_live(self) -> bool:
        """Is there a device-side layout that insert/delete must keep in
        sync (via dirty marks)? Subclasses with their own layout (the
        tiered index) override this instead of `_mark_dirty`."""
        return self._device_pack is not None

    def _mark_dirty(self, c: int, g: Optional[HNSW] = None):
        if self._pack_live():
            self._dirty.add(c)
            if g is not None:
                self._pending_graphs.pop(c, None)
                self._pending_graphs[c] = g
                while len(self._pending_graphs) > self.PENDING_GRAPHS_MAX:
                    self._pending_graphs.pop(next(iter(self._pending_graphs)))

    def _wal_append(self, op: tuple):
        """Journal a mutation BEFORE applying it: when this returns the
        op is fsync'd and will survive kill -9 (load replays it). No-op
        until the index has a persistence root (first `save()`)."""
        if self._journal is not None and not self._replaying:
            self._journal.append(
                pickle.dumps(op, protocol=pickle.HIGHEST_PROTOCOL))

    def insert(self, vid: int, vec: np.ndarray):
        """§3.3.1: route to nearest centroid, Algorithm-1 insert into that
        cluster's graph only. The device pack is NOT invalidated: the
        owning cluster is marked dirty and repacked in place on the next
        device query (DESIGN.md §3). With a persistence root attached the
        op hits the WAL (fsync) before it applies."""
        vec = np.asarray(vec, np.float32)
        self._wal_append(("insert", int(vid), vec.tobytes()))
        cids, _ = self.centroid_graph.search(vec, 1, ef_search=16)
        c = int(cids[0])
        if c in self._quarantined:
            # the owner's graph is lost: restart it from the salvage copy
            # (when one exists) plus this vector, so updates keep working
            # under quarantine instead of waiting on an operator rebuild
            if c in self._salvage:
                sids, svecs = self._salvage[c]
                keep = sids != int(vid)
                ids = np.concatenate([sids[keep],
                                      np.asarray([int(vid)], np.int64)])
                vecs = np.concatenate([svecs[keep], vec[None]])
            else:
                ids = np.asarray([int(vid)], np.int64)
                vecs = vec[None]
            self.rebuild_cluster(c, ids, vecs)
            return
        g = self._load_cluster(c)
        g.insert(int(vid), vec)
        self.assign[int(vid)] = c
        self.cluster_members[c].append(int(vid))
        self._release_cluster(c, g, dirty=True)
        self._mark_dirty(c, g)

    def delete(self, vid: int):
        """§3.3.2: Algorithm-2 delete inside the owning cluster's graph
        (WAL'd first, like insert)."""
        self._wal_append(("delete", int(vid)))
        c = self.assign.pop(int(vid), None)
        if c is None:
            return
        if c in self._quarantined:
            return  # bookkeeping already pruned; data already lost
        g = self._load_cluster(c)
        g.delete(int(vid))
        if int(vid) in self.cluster_members[c]:
            self.cluster_members[c].remove(int(vid))
        self._release_cluster(c, g, dirty=True)
        self._mark_dirty(c, g)

    # ------------------------------------------------------ persistence

    def save(self, root: Optional[str] = None) -> int:
        """Commit the full index (centroids, centroid graph, id maps,
        every healthy spill file) as the next generation under `root`,
        then rotate the WAL — this IS the compaction step: journaled
        mutations are folded into the snapshot and their log dropped.
        Subsequent `insert`/`delete` are journaled against the new
        generation. Returns the generation number."""
        root = root or self._persist_root
        if root is None:
            raise ValueError("save() needs a root directory (none given "
                             "and no previous save to reuse)")
        if self.centroids is None or self.centroid_graph is None:
            raise store.StoreError("save() before build(): nothing to "
                                   "persist yet")
        if self._journal is None or self._journal.root != root:
            self._journal = store.Journal(root)
        tmp = self._journal.begin()
        self._write_state(tmp)
        g = self._journal.commit()
        self._persist_root = root
        return g

    def _write_state(self, d: str):
        # Spill files go first: verify-on-copy may quarantine a rotten
        # cluster, and state.seg must record the post-verification
        # quarantine set (else the snapshot would claim a cluster is
        # healthy while omitting its file).
        for c in range(self.n_clusters):
            if c in self._quarantined:
                continue
            try:
                # verify-on-copy: bit-rot in a spill file must not be
                # laundered into a freshly-committed generation
                blob = store.verify_segment(self._path(c),
                                            kind=_CLUSTER_KIND)
            except (store.StoreError, OSError) as e:
                self.stats.corrupt_reads += 1
                warnings.warn(f"cluster {c} failed validation during "
                              f"save ({e}); quarantined and left out of "
                              f"the snapshot", stacklevel=3)
                self._quarantine(c)
                continue
            with open(os.path.join(d, f"cluster_{c:05d}.bin"), "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
        cent_bytes, cent_spec = store.array_record(
            np.asarray(self.centroids, np.float32))
        state = {
            "dim": self.dim, "n_clusters": self.n_clusters, "M": self.M,
            "ef_construction": self.efc, "seed": self.seed,
            "cache_clusters": self.cache_clusters,
            "assign": {int(k): int(v) for k, v in self.assign.items()},
            "cluster_members": [list(map(int, m))
                                for m in self.cluster_members],
            "quarantined": sorted(self._quarantined),
        }
        store.write_segment(
            os.path.join(d, "state.seg"),
            [pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL),
             cent_bytes,
             pickle.dumps(self.centroid_graph,
                          protocol=pickle.HIGHEST_PROTOCOL)],
            {"centroids": cent_spec}, kind=_STATE_KIND)

    @classmethod
    def load(cls, root: str, storage_dir: Optional[str] = None,
             replay_wal: bool = True) -> "EcoVector":
        """Restore the latest committed generation + WAL replay. Spill
        files are copied into a fresh working `storage_dir` (the
        committed generation stays immutable); every acknowledged
        mutation since the snapshot is re-applied from the journal."""
        j = store.Journal(root)
        g = j.latest()
        if g is None:
            raise FileNotFoundError(f"no committed generation under "
                                    f"{root}")
        meta, recs = store.decode_segment(
            j.read_file(g, "state.seg"), os.path.join(j.gen_dir(g),
                                                      "state.seg"))
        if meta.get("kind") != _STATE_KIND or len(recs) != 3:
            raise store.CorruptSegmentError(
                f"{root}: generation {g} state segment malformed")
        state = pickle.loads(recs[0])
        self = cls(state["dim"], n_clusters=state["n_clusters"],
                   M=state["M"], ef_construction=state["ef_construction"],
                   storage_dir=storage_dir, seed=state["seed"],
                   cache_clusters=state["cache_clusters"])
        self.centroids = store.record_array(recs[1], meta["centroids"])
        self.centroid_graph = pickle.loads(recs[2])
        self.assign = {int(k): int(v) for k, v in state["assign"].items()}
        self.cluster_members = [list(m) for m in state["cluster_members"]]
        self._quarantined = set(state["quarantined"])
        self.stats.quarantined = len(self._quarantined)
        for name in j.manifest(g)["files"]:
            if name.startswith("cluster_"):
                with open(os.path.join(self.storage_dir, name), "wb") as f:
                    f.write(j.read_file(g, name))
        self._journal = j
        self._persist_root = root
        self._restore_extra(j, g)
        if replay_wal:
            self._replay_journal()
        return self

    def _restore_extra(self, j: "store.Journal", g: int) -> None:
        """Subclass hook: restore additional generation files (the tiered
        index's tier assignment + cold pack) after the core state is back
        but BEFORE the WAL replays, so replayed mutations land on the
        restored tier layout."""

    def _replay_journal(self) -> None:
        """Re-apply every acknowledged mutation journaled since the
        loaded generation (torn tail == never acknowledged)."""
        ops_raw, _torn = self._journal.replay()
        self._replaying = True
        try:
            for raw in ops_raw:
                self._apply_wal(pickle.loads(raw))
        finally:
            self._replaying = False
        self.stats.wal_replayed = len(ops_raw)

    def _apply_wal(self, op: tuple):
        kind = op[0]
        if kind == "insert":
            _, vid, vec_bytes = op
            self.insert(int(vid), np.frombuffer(vec_bytes, np.float32))
        elif kind == "delete":
            self.delete(int(op[1]))
        else:
            raise store.CorruptSegmentError(
                f"unknown WAL op {kind!r} (journal from a newer version?)")

    # ------------------------------------------------------- accounting

    def ram_bytes(self) -> int:
        """Resident memory: centroid graph + ids (Table 1 EcoVector row:
        4*Nc*(d + M'/(1-p0)) + 8N + one loaded inverted list), PLUS
        everything the runtime actually keeps resident on top of the
        paper's model — the LRU cluster-graph cache, update-path pending
        graphs, and the jnp device mirrors. A freshly built index reports
        exactly the paper number; a warmed-up one reports the truth."""
        base = self.centroid_graph.memory_bytes() if self.centroid_graph else 0
        ids = 8 * len(self.assign)
        one_list = self.avg_cluster_bytes()
        cached = sum(g.memory_bytes() for g in self._cache.values())
        pending = sum(g.memory_bytes()
                      for c, g in self._pending_graphs.items()
                      if c not in self._cache)
        return (base + ids + one_list + cached + pending
                + self.device_resident_bytes())

    def device_resident_bytes(self) -> int:
        """Bytes currently held on-device (jnp mirrors of the cluster
        pack + centroids) — the quantity a `device_budget_bytes` knob
        constrains. Zero until the first device search materialises the
        mirrors."""
        total = 0
        if self._mirror is not None:
            total += sum(int(m.size) * m.dtype.itemsize
                         for m in self._mirror)
        if self._centroids_dev is not None:
            total += (int(self._centroids_dev.size)
                      * self._centroids_dev.dtype.itemsize)
        return total

    def disk_bytes(self) -> int:
        return sum(os.path.getsize(self._path(c))
                   for c in range(self.n_clusters)
                   if os.path.exists(self._path(c)))

    def avg_cluster_bytes(self) -> int:
        sizes = [os.path.getsize(self._path(c))
                 for c in range(self.n_clusters)
                 if os.path.exists(self._path(c))]
        return int(np.mean(sizes)) if sizes else 0

    def cluster_sizes(self) -> np.ndarray:
        return np.asarray([len(m) for m in self.cluster_members])
