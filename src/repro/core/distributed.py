"""Pod-scale EcoVector: cluster shards across the whole mesh.

The paper's asymmetry — tiny routing structure in the fast tier, bulk index
in the slow tier, only probed clusters move — promoted to a TPU pod:

  * centroids + query batch: replicated (they are the small tier),
  * packed cluster payload [NC, CAP, d]: sharded on NC across every mesh
    axis (each device owns NC/ndev clusters in its HBM),
  * each device scans only its *resident* probed clusters (non-resident
    probes are masked, never fetched — no cross-device cluster movement),
  * per-device top-k all-gathered (k * ndev candidates, a few KB) and
    merged: the only collective in the search path.

`shard_map` + jnp here (not the Pallas kernel) so the same step lowers for
the 512-chip dry-run; on-device the inner scan is the ecoscan kernel's math
verbatim.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _flat_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def make_sharded_retrieval(mesh: Mesh, *, k: int = 10, n_probe: int = 8):
    """Returns retrieve(q, centroids, data, lens, slot_ids) -> (dists, ids).

    q: [B, d]; centroids: [NC, d]; data: [NC, CAP, d]; lens: [NC];
    slot_ids: [NC, CAP] global ids. NC must divide the device count.
    """
    axes = _flat_axes(mesh)
    ndev = mesh.devices.size

    def retrieve(q, centroids, data, lens, slot_ids):
        B = q.shape[0]

        def local(qr, cent, data_l, lens_l, sid_l):
            nc_loc, cap, d = data_l.shape
            didx = jax.lax.axis_index(axes)  # flattened device index
            lo = didx * nc_loc
            # routing on replicated centroids (cheap: NC x d matmul)
            d2 = (jnp.sum(qr * qr, 1)[:, None]
                  - 2.0 * qr @ cent.T
                  + jnp.sum(cent * cent, 1)[None, :])
            _, probes = jax.lax.top_k(-d2, n_probe)            # [B, P]
            # which probes live here?
            local_p = probes - lo
            resident = (local_p >= 0) & (local_p < nc_loc)
            local_p = jnp.clip(local_p, 0, nc_loc - 1)
            blk = data_l[local_p]                              # [B,P,CAP,d]
            xq = jnp.einsum("bpcd,bd->bpc", blk, qr)
            xx = jnp.sum(blk * blk, axis=-1)
            dist = xx - 2.0 * xq + jnp.sum(qr * qr, 1)[:, None, None]
            slot = jnp.arange(cap)[None, None, :]
            valid = resident[..., None] & (slot < lens_l[local_p][..., None])
            dist = jnp.where(valid, dist, jnp.inf)
            ids = jnp.where(valid, sid_l[local_p], -1)
            nd, ni = jax.lax.top_k(-dist.reshape(B, -1), k)
            gid = jnp.take_along_axis(ids.reshape(B, -1), ni, axis=1)
            # merge across devices: k*ndev candidates, tiny
            all_d = jax.lax.all_gather(-nd, axes, axis=1, tiled=True)
            all_i = jax.lax.all_gather(gid, axes, axis=1, tiled=True)
            fd, fi = jax.lax.top_k(-all_d, k)
            out_ids = jnp.take_along_axis(all_i, fi, axis=1)
            return -fd, out_ids

        shard_axes = axes if len(axes) > 1 else axes[0]
        from repro.dist.sharding import shard_map
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P(shard_axes), P(shard_axes), P(shard_axes)),
            out_specs=(P(), P()))
        return fn(q, centroids, data, lens, slot_ids)

    return retrieve


def retrieval_input_structs(*, B: int, NC: int, CAP: int, d: int):
    f32, i32 = jnp.float32, jnp.int32
    return (jax.ShapeDtypeStruct((B, d), f32),
            jax.ShapeDtypeStruct((NC, d), f32),
            jax.ShapeDtypeStruct((NC, CAP, d), f32),
            jax.ShapeDtypeStruct((NC,), i32),
            jax.ShapeDtypeStruct((NC, CAP), i32))


def retrieval_shardings(mesh: Mesh):
    axes = _flat_axes(mesh)
    sh = lambda spec: NamedSharding(mesh, spec)  # noqa: E731
    shard_axes = axes if len(axes) > 1 else axes[0]
    return (sh(P()), sh(P()), sh(P(shard_axes)), sh(P(shard_axes)),
            sh(P(shard_axes)))


def reference_retrieval(q, centroids, data, lens, slot_ids, *, k, n_probe):
    """Single-host oracle for the sharded step."""
    q = np.asarray(q)
    d2 = (np.sum(q ** 2, 1)[:, None] - 2 * q @ np.asarray(centroids).T
          + np.sum(np.asarray(centroids) ** 2, 1)[None, :])
    probes = np.argsort(d2, 1)[:, :n_probe]
    B = q.shape[0]
    data = np.asarray(data)
    lens = np.asarray(lens)
    slot_ids = np.asarray(slot_ids)
    out_d = np.zeros((B, k), np.float32)
    out_i = np.zeros((B, k), np.int64)
    for b in range(B):
        ds, ids = [], []
        for c in probes[b]:
            m = lens[c]
            diff = data[c, :m] - q[b]
            ds.append(np.einsum("nd,nd->n", diff, diff))
            ids.append(slot_ids[c, :m])
        ds = np.concatenate(ds)
        ids = np.concatenate(ids)
        o = np.argsort(ds)[:k]
        out_d[b] = ds[o]
        out_i[b] = ids[o]
    return out_d, out_i
