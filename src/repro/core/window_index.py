"""Corpus-resident SCR window index (DESIGN.md §6).

`apply_scr` re-splits, re-windows, and re-embeds every window of every
retrieved document on every query — on a fixed corpus that work is pure
waste (EdgeRAG's observation: precompute embeddings once, reuse per
query). This index moves all of it to build time: every document is
split into sentences, windowed (`SCRConfig` geometry), and embedded
ONCE, and the window embeddings are packed into the same padded
block-per-owner device layout EcoVector uses for cluster payloads
([ND, CAPW, d] in HBM, `lens[ND]` valid counts), so the fused
`scr_select` kernel can DMA exactly the retrieved documents' blocks per
query batch.

Updates mirror EcoVector's dirty-cluster repack protocol: `add`/
`update`/`remove` touch host metadata and mark only the owning block
dirty; the next `pack()` re-embeds just the dirty documents (one batched
embed call for all of them) and rewrites their blocks in place, growing
CAPW (and the block table) geometrically on overflow. The jnp device
mirror refreshes per dirty block, not wholesale.

Durability (DESIGN.md §12): `save()` commits texts + the embedded block
pack as a checksummed generation snapshot (so a restart re-embeds
NOTHING), journaled `add`/`update`/`remove` ops hit a fsync'd WAL before
they apply, and `load()` replays the journal — replayed docs simply mark
their blocks dirty, so the next `pack()` re-embeds only them.
"""
from __future__ import annotations

import os
import pickle
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import store
from repro.core.scr import SCRConfig, sliding_windows, split_sentences

_STATE_KIND = "window_index.state"


@dataclass
class WindowIndexStats:
    full_builds: int = 0         # whole [ND, CAPW, d] pack builds
    block_repacks: int = 0       # single-doc block rewrites in place
    grows: int = 0               # geometric CAPW / row-table growths
    embed_calls: int = 0         # batched embed invocations
    windows_embedded: int = 0    # total window texts embedded
    wal_replayed: int = 0        # mutations replayed by load()
    # residency / traffic accounting ahead of this pack's own tiering
    # pass (DESIGN.md §14 measures EcoVector; this makes the SCR window
    # pack — the other RAM-resident block pack — equally measurable)
    resident_bytes: int = 0      # host pack + device mirror, last pack()
    select_calls: int = 0        # scr_select batch invocations
    select_queries: int = 0      # query rows across those batches
    blocks_dma: int = 0          # doc blocks DMA'd by scr_select, total
    last_query_dma_blocks: float = 0.0   # blocks per query, last batch


class WindowIndex:
    """Precomputed sentence/window/embedding state for a document corpus,
    packed for the `scr_select` kernel."""

    MIN_CAPW = 8                 # same floor as EcoVector's device pack

    def __init__(self, embed: Callable, cfg: SCRConfig = SCRConfig(),
                 dim: Optional[int] = None):
        self.embed = embed
        self.cfg = cfg
        self.texts: List[str] = []
        self.sents: List[List[str]] = []
        self.spans: List[List[Tuple[int, int]]] = []
        self.ntok: List[int] = []            # whitespace tokens per doc
        self.stats = WindowIndexStats()
        self._dim = dim if dim is not None else getattr(embed, "dim", None)
        self._data: Optional[np.ndarray] = None    # [ND, CAPW, d]
        self._lens: Optional[np.ndarray] = None    # [ND] i32
        self._dirty: Set[int] = set()
        self._mirror = None                        # jnp (data, lens)
        self._mirror_dirty: Set[int] = set()
        # durability state (DESIGN.md §12)
        self._journal: Optional[store.Journal] = None
        self._persist_root: Optional[str] = None
        self._replaying = False

    # ------------------------------------------------------------- build

    def __len__(self) -> int:
        return len(self.texts)

    def _window_texts(self, di: int) -> List[str]:
        sents, spans = self.sents[di], self.spans[di]
        return [" ".join(sents[a:b]) for a, b in spans]

    def _set_doc(self, di: int, text: str):
        self.texts[di] = text
        self.sents[di] = split_sentences(text)
        self.spans[di] = sliding_windows(self.sents[di],
                                         self.cfg.sliding_window_size,
                                         self.cfg.overlap_size)
        self.ntok[di] = len(text.split())

    def _embed_batch(self, win_texts: List[str]) -> np.ndarray:
        vecs = np.asarray(self.embed(win_texts), np.float32)
        self.stats.embed_calls += 1
        self.stats.windows_embedded += len(win_texts)
        if self._dim is None:
            self._dim = vecs.shape[1]
        return vecs

    def build(self, docs: Sequence[str]) -> "WindowIndex":
        """Split/window/embed the whole corpus in one batched embed call
        and build the block pack."""
        n = len(docs)
        self.texts = [""] * n
        self.sents = [[] for _ in range(n)]
        self.spans = [[] for _ in range(n)]
        self.ntok = [0] * n
        for di, text in enumerate(docs):
            self._set_doc(di, text)
        self._build_pack(range(n))
        return self

    def _build_pack(self, doc_ids):
        win_texts, owners = [], []
        for di in doc_ids:
            wt = self._window_texts(di)
            win_texts.extend(wt)
            owners.extend([di] * len(wt))
        vecs = (self._embed_batch(win_texts) if win_texts
                else np.zeros((0, self._dim or 1), np.float32))
        d = self._dim or (vecs.shape[1] if vecs.size else 1)
        nd = len(self.texts)
        capw = max(self.MIN_CAPW,
                   max((len(s) for s in self.spans), default=0))
        self._data = np.zeros((nd, capw, d), np.float32)
        self._lens = np.zeros((nd,), np.int32)
        at = np.zeros(nd, np.int64)
        for v, di in zip(vecs, owners):
            self._data[di, at[di]] = v
            at[di] += 1
        for di in range(nd):
            self._lens[di] = len(self.spans[di])
        self.stats.full_builds += 1
        self._dirty.clear()
        self._mirror = None
        self._mirror_dirty.clear()

    # ----------------------------------------------------------- updates

    def _wal_append(self, op: tuple):
        """Journal a mutation before applying it (fsync'd — survives
        kill -9). No-op until the index has been `save()`d once."""
        if self._journal is not None and not self._replaying:
            self._journal.append(
                pickle.dumps(op, protocol=pickle.HIGHEST_PROTOCOL))

    def add(self, text: str) -> int:
        """Append a document; only its block is (lazily) embedded and
        packed. Returns the new doc id."""
        self._wal_append(("add", text))
        di = len(self.texts)
        self.texts.append("")
        self.sents.append([])
        self.spans.append([])
        self.ntok.append(0)
        self._set_doc(di, text)
        self._mark_dirty(di)
        return di

    def update(self, di: int, text: str):
        """Replace a document's text; marks only its block dirty."""
        self._wal_append(("update", di, text))
        self._set_doc(di, text)
        self._mark_dirty(di)

    def remove(self, di: int):
        """Drop a document's windows (its block empties; the slot stays,
        mirroring how retrieval indexes tombstone ids)."""
        self._wal_append(("remove", di))
        self._set_doc(di, "")
        self._mark_dirty(di)

    def _mark_dirty(self, di: int):
        if self._data is not None:
            self._dirty.add(di)

    # -------------------------------------------------------------- pack

    def pack(self) -> Tuple[np.ndarray, np.ndarray]:
        """Return the host (data [ND, CAPW, d], lens [ND]) pack, repacking
        only dirty blocks (one batched embed call across all of them)."""
        if self._data is None:
            self._build_pack(range(len(self.texts)))
        elif self._dirty:
            self._repack_dirty()
        return self._data, self._lens

    def _repack_dirty(self):
        nd, capw, d = self._data.shape
        need_rows = len(self.texts)
        need_capw = max((len(self.spans[di]) for di in self._dirty),
                        default=0)
        if need_rows > nd or need_capw > capw:
            new_nd, new_capw = max(nd, 1), capw
            while new_nd < need_rows:
                new_nd *= 2
            while new_capw < need_capw:
                new_capw *= 2
            ndata = np.zeros((new_nd, new_capw, d), np.float32)
            ndata[:nd, :capw] = self._data
            nlens = np.zeros((new_nd,), np.int32)
            nlens[:nd] = self._lens
            self._data, self._lens = ndata, nlens
            self.stats.grows += 1
            self._mirror = None          # shape changed: full refresh
            self._mirror_dirty.clear()
        dirty = sorted(self._dirty)
        win_texts, owners = [], []
        for di in dirty:
            wt = self._window_texts(di)
            win_texts.extend(wt)
            owners.extend([di] * len(wt))
        vecs = (self._embed_batch(win_texts) if win_texts
                else np.zeros((0, d), np.float32))
        if len(win_texts) and vecs.shape[1] != d:
            # the pack was built before any window existed (placeholder
            # dim); rebuild it now that the true dim is known
            self._build_pack(range(len(self.texts)))
            return
        at = {di: 0 for di in dirty}
        for di in dirty:
            self._data[di] = 0.0
            self._lens[di] = len(self.spans[di])
        for v, di in zip(vecs, owners):
            self._data[di, at[di]] = v
            at[di] += 1
        self.stats.block_repacks += len(dirty)
        self._mirror_dirty.update(dirty)
        self._dirty.clear()

    def device_arrays(self):
        """jnp mirrors of the pack, refreshed per dirty block rather than
        re-uploading the whole [ND, CAPW, d] tensor."""
        import jax.numpy as jnp
        data, lens = self.pack()
        # jnp.array (copy) rather than jnp.asarray: the CPU backend may
        # zero-copy-alias an aligned numpy buffer, and the host pack is
        # mutated in place by later repacks — an aliased mirror would
        # change under every reference already handed out
        if self._mirror is None or self._mirror[0].shape != data.shape:
            self._mirror = (jnp.array(data), jnp.array(lens))
            self._mirror_dirty.clear()
        elif self._mirror_dirty:
            touched = sorted(self._mirror_dirty)
            mdata = self._mirror[0].at[jnp.asarray(touched)].set(
                jnp.asarray(data[touched]))
            self._mirror = (mdata, jnp.array(lens))
            self._mirror_dirty.clear()
        return self._mirror

    # ------------------------------------------------------- persistence

    def save(self, root: Optional[str] = None) -> int:
        """Commit texts + the embedded block pack as the next generation
        under `root` (flushing dirty blocks first, so the snapshot never
        needs re-embedding at load), then rotate the WAL — the compaction
        step. Returns the generation number."""
        root = root or self._persist_root
        if root is None:
            raise ValueError("save() needs a root directory (none given "
                             "and no previous save to reuse)")
        data, lens = self.pack()   # fold dirty blocks into the snapshot
        if self._journal is None or self._journal.root != root:
            self._journal = store.Journal(root)
        tmp = self._journal.begin()
        data_bytes, data_spec = store.array_record(data)
        lens_bytes, lens_spec = store.array_record(lens)
        state = {"texts": list(self.texts), "cfg": self.cfg,
                 "dim": self._dim}
        store.write_segment(
            os.path.join(tmp, "windows.seg"),
            [pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL),
             data_bytes, lens_bytes],
            {"data": data_spec, "lens": lens_spec}, kind=_STATE_KIND)
        g = self._journal.commit()
        self._persist_root = root
        return g

    @classmethod
    def load(cls, embed: Callable, root: str,
             replay_wal: bool = True) -> "WindowIndex":
        """Restore the latest generation + WAL replay. Sentences/spans
        are recomputed from the saved texts (deterministic given the
        config); the embedded pack is restored bit-identically, so no
        embed call happens unless the WAL replays mutations — those only
        mark blocks dirty for the next `pack()`."""
        j = store.Journal(root)
        g = j.latest()
        if g is None:
            raise FileNotFoundError(f"no committed generation under "
                                    f"{root}")
        path = os.path.join(j.gen_dir(g), "windows.seg")
        meta, recs = store.decode_segment(j.read_file(g, "windows.seg"),
                                          path)
        if meta.get("kind") != _STATE_KIND or len(recs) != 3:
            raise store.CorruptSegmentError(
                f"{path}: window-index state segment malformed")
        state = pickle.loads(recs[0])
        self = cls(embed, cfg=state["cfg"], dim=state["dim"])
        texts = state["texts"]
        n = len(texts)
        self.texts = [""] * n
        self.sents = [[] for _ in range(n)]
        self.spans = [[] for _ in range(n)]
        self.ntok = [0] * n
        for di, text in enumerate(texts):
            self._set_doc(di, text)
        self._data = store.record_array(recs[1], meta["data"])
        self._lens = store.record_array(recs[2], meta["lens"])
        for di in range(n):
            # defensive: a span count disagreeing with the saved pack
            # (config drift) re-embeds just that block on the next pack()
            if int(self._lens[di]) != len(self.spans[di]):
                self._dirty.add(di)
        self._journal = j
        self._persist_root = root
        if replay_wal:
            ops_raw, _torn = j.replay()
            self._replaying = True
            try:
                for raw in ops_raw:
                    self._apply_wal(pickle.loads(raw))
            finally:
                self._replaying = False
            self.stats.wal_replayed = len(ops_raw)
        return self

    def _apply_wal(self, op: tuple):
        kind = op[0]
        if kind == "add":
            self.add(op[1])
        elif kind == "update":
            self.update(int(op[1]), op[2])
        elif kind == "remove":
            self.remove(int(op[1]))
        else:
            raise store.CorruptSegmentError(
                f"unknown WAL op {kind!r} (journal from a newer version?)")

    # -------------------------------------------------------- accounting

    def ram_bytes(self) -> int:
        data, lens = self.pack()
        return int(data.nbytes + lens.nbytes)

    def resident_bytes(self) -> int:
        """Total resident footprint of the window pack: the host arrays
        plus the jnp device mirror when one has been materialised. The
        number a future tiering pass on this pack will budget against."""
        total = self.ram_bytes()
        if self._mirror is not None:
            total += sum(int(m.size) * m.dtype.itemsize
                         for m in self._mirror)
        self.stats.resident_bytes = total
        return total

    def record_select(self, doc_ids: np.ndarray) -> None:
        """Account one `scr_select` batch: every valid (query, doc) pair
        is one doc block DMA'd from the pack into the kernel grid."""
        doc_ids = np.asarray(doc_ids)
        blocks = int((doc_ids >= 0).sum())
        nq = int(doc_ids.shape[0])
        self.stats.select_calls += 1
        self.stats.select_queries += nq
        self.stats.blocks_dma += blocks
        self.stats.last_query_dma_blocks = blocks / max(nq, 1)
