"""Fault tolerance on top of dist.checkpoint.

  RestartManager   periodic checkpoints + restart-from-latest; survives
                   kill -9 because every committed save is atomic and the
                   manager never trusts uncommitted state.
  StepWatchdog     flags straggler steps against a running mean.
  reshard_restore  elastic recovery: a checkpoint written under one mesh
                   restores bit-identically onto a different mesh (hosts
                   lost or added) by re-placing host leaves with the
                   target mesh's shardings.
"""
from __future__ import annotations

import time
from typing import Any, NamedTuple, Optional, Tuple

import jax

from repro.dist import checkpoint
from repro.dist.sharding import spec_tree_to_shardings


class RestartManager:
    """Checkpoint every `interval` steps; resume from the latest commit.

    `async_save=False` (default) blocks on the disk write inside
    `on_step`, so a kill -9 at ANY point between steps loses at most
    `interval` steps — the durability contract the kill-at tests assert.
    `async_save=True` overlaps the write with the next steps (snapshot is
    still synchronous, so donated buffers are safe); a crash may lose the
    in-flight save on top of the interval.
    """

    def __init__(self, ckpt_dir: str, interval: int = 50,
                 async_save: bool = False):
        self.ckpt_dir = ckpt_dir
        self.interval = interval
        self.async_save = async_save
        self._pending = None

    def maybe_restore(self, state: Any) -> Tuple[Any, int]:
        """(state, first_step_to_run): restored latest checkpoint and
        step+1, or the passed-in state and 0 when none committed."""
        latest = checkpoint.latest_step(self.ckpt_dir)
        if latest is None:
            return state, 0
        return checkpoint.restore(self.ckpt_dir, latest, state), latest + 1

    def on_step(self, step: int, state: Any) -> None:
        if self.interval <= 0 or step <= 0 or step % self.interval:
            return
        self._save(step, state)

    def finalize(self, step: int, state: Any) -> None:
        """Unconditional blocking save of the final state."""
        self.flush()
        checkpoint.save(self.ckpt_dir, step, state)

    def flush(self) -> None:
        """Wait for any in-flight async save to commit."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _save(self, step: int, state: Any) -> None:
        self.flush()
        if self.async_save:
            self._pending = checkpoint.save(self.ckpt_dir, step, state,
                                            blocking=False)
        else:
            checkpoint.save(self.ckpt_dir, step, state)


class StragglerReport(NamedTuple):
    is_straggler: bool
    step_time_s: float
    mean_s: float
    step: int


class StepWatchdog:
    """start()/stop(step) around each training step; a step slower than
    `factor` x the running mean of healthy steps is flagged. The first
    `warmup` steps only feed the mean (compile steps must not trip it),
    and flagged steps are excluded from it so one hung host cannot drag
    the baseline up and mask the next stall."""

    def __init__(self, factor: float = 3.0, warmup: int = 2,
                 history: int = 64):
        self.factor = factor
        self.warmup = warmup
        self.history = history
        self._times: list = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> StragglerReport:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        mean = (sum(self._times) / len(self._times)) if self._times else dt
        flag = len(self._times) >= self.warmup and dt > self.factor * mean
        if not flag:
            self._times.append(dt)
            if len(self._times) > self.history:
                self._times.pop(0)
        return StragglerReport(flag, dt, mean, step)


def reshard_restore(ckpt_dir: str, like: Any, mesh, specs,
                    step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore the latest (or given) checkpoint onto `mesh`.

    `like` supplies the pytree structure (arrays or ShapeDtypeStructs),
    `specs` the matching logical-axis spec tree. Leaves are read on host
    and `device_put` with the target mesh's (shape-pruned) shardings, so
    the values are bit-identical regardless of how the writing mesh was
    laid out — the checkpoint format is mesh-oblivious by construction.
    Returns (state, first_step_to_run).
    """
    if step is None:
        step = checkpoint.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    host = checkpoint.restore_host(ckpt_dir, step, like)
    shardings = spec_tree_to_shardings(mesh, specs, like)
    state = jax.tree.map(jax.device_put, host, shardings)
    return state, step + 1
