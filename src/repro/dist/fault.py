"""Fault tolerance on top of dist.checkpoint.

  RestartManager   periodic checkpoints + restart-from-latest; survives
                   kill -9 because every committed save is atomic and the
                   manager never trusts uncommitted state.
  StepWatchdog     flags straggler steps against a running mean.
  reshard_restore  elastic recovery: a checkpoint written under one mesh
                   restores bit-identically onto a different mesh (hosts
                   lost or added) by re-placing host leaves with the
                   target mesh's shardings.
  HealthTracker    strike/drain/probation/recovery state machine for one
                   replica-like unit — the shared health primitive behind
                   the serve-side SlotScheduler failover (the serving
                   counterpart of RestartManager's training-side role).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax

from repro.dist import checkpoint
from repro.dist.sharding import spec_tree_to_shardings


@dataclass
class HealthConfig:
    """Knobs for one HealthTracker.

    `max_strikes` failures drain the unit; each success forgives
    `strike_decay` strikes, so transient errors don't accumulate forever.
    A drained unit becomes probe-eligible after `cooldown_s`; every failed
    probe multiplies the cooldown by `cooldown_backoff` (capped at
    `cooldown_max_s`), and after `max_probes` failed probes the unit is
    `exhausted` — permanently out of service (None = keep probing)."""
    max_strikes: int = 2
    strike_decay: int = 1
    cooldown_s: float = 0.25
    cooldown_backoff: float = 2.0
    cooldown_max_s: float = 30.0
    max_probes: Optional[int] = 8


class HealthTracker:
    """HEALTHY -> (strikes) DRAINED -> (cooldown) PROBING -> HEALTHY.

    One tracker per replica-like unit. `record_failure()` adds a strike
    and reports whether the unit just drained; `record_success()` decays
    strikes and, while probing, recovers the unit. `probe_due()` /
    `begin_probe()` gate the single canary a drained unit must pass to
    re-enter service — a unit is never lost forever unless its probe
    budget is exhausted. The clock is injectable so tests can drive the
    state machine deterministically."""

    HEALTHY, DRAINED, PROBING = "healthy", "drained", "probing"

    def __init__(self, cfg: Optional[HealthConfig] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.cfg = cfg or HealthConfig()
        self.clock = clock
        self.state = self.HEALTHY
        self.strikes = 0
        self.probes = 0              # probes attempted
        self.drains = 0
        self.recoveries = 0
        self._cooldown_s = self.cfg.cooldown_s
        self._next_probe_s = 0.0

    @property
    def healthy(self) -> bool:
        """Fully in service (probing units carry only their canary)."""
        return self.state == self.HEALTHY

    @property
    def exhausted(self) -> bool:
        """Drained with no probe budget left: permanently out."""
        return (self.state == self.DRAINED
                and self.cfg.max_probes is not None
                and self.probes >= self.cfg.max_probes)

    def record_success(self) -> bool:
        """One unit of successful work; returns True when this success
        recovered a probing unit back to HEALTHY."""
        if self.state == self.PROBING:
            self.state = self.HEALTHY
            self.strikes = 0
            self.probes = 0          # fresh probe budget after recovery
            self._cooldown_s = self.cfg.cooldown_s
            self.recoveries += 1
            return True
        self.strikes = max(0, self.strikes - self.cfg.strike_decay)
        return False

    def record_failure(self) -> bool:
        """One failure; returns True when the unit just drained (the
        caller should re-queue its in-flight work). A failure while
        probing always drains and backs off the next probe."""
        if self.state == self.PROBING:
            self._drain(backoff=True)
            return True
        self.strikes += 1
        if self.state == self.HEALTHY and self.strikes >= self.cfg.max_strikes:
            self._drain(backoff=False)
            return True
        return False

    def _drain(self, *, backoff: bool) -> None:
        self.state = self.DRAINED
        self.drains += 1
        if backoff:
            self._cooldown_s = min(self._cooldown_s * self.cfg.cooldown_backoff,
                                   self.cfg.cooldown_max_s)
        self._next_probe_s = self.clock() + self._cooldown_s

    def probe_due(self) -> bool:
        """Drained, cooled down, and probe budget remaining."""
        return (self.state == self.DRAINED and not self.exhausted
                and self.clock() >= self._next_probe_s)

    def begin_probe(self) -> None:
        """Enter PROBING: the unit accepts exactly one canary; the next
        record_success / record_failure resolves it."""
        assert self.state == self.DRAINED, f"probe from {self.state}"
        self.state = self.PROBING
        self.probes += 1


class RestartManager:
    """Checkpoint every `interval` steps; resume from the latest commit.

    `async_save=False` (default) blocks on the disk write inside
    `on_step`, so a kill -9 at ANY point between steps loses at most
    `interval` steps — the durability contract the kill-at tests assert.
    `async_save=True` overlaps the write with the next steps (snapshot is
    still synchronous, so donated buffers are safe); a crash may lose the
    in-flight save on top of the interval.
    """

    def __init__(self, ckpt_dir: str, interval: int = 50,
                 async_save: bool = False):
        self.ckpt_dir = ckpt_dir
        self.interval = interval
        self.async_save = async_save
        self._pending = None

    def maybe_restore(self, state: Any) -> Tuple[Any, int]:
        """(state, first_step_to_run): restored latest checkpoint and
        step+1, or the passed-in state and 0 when none committed."""
        latest = checkpoint.latest_step(self.ckpt_dir)
        if latest is None:
            return state, 0
        return checkpoint.restore(self.ckpt_dir, latest, state), latest + 1

    def on_step(self, step: int, state: Any) -> None:
        if self.interval <= 0 or step <= 0 or step % self.interval:
            return
        self._save(step, state)

    def finalize(self, step: int, state: Any) -> None:
        """Unconditional blocking save of the final state."""
        self.flush()
        checkpoint.save(self.ckpt_dir, step, state)

    def flush(self) -> None:
        """Wait for any in-flight async save to commit."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _save(self, step: int, state: Any) -> None:
        self.flush()
        if self.async_save:
            self._pending = checkpoint.save(self.ckpt_dir, step, state,
                                            blocking=False)
        else:
            checkpoint.save(self.ckpt_dir, step, state)


class StragglerReport(NamedTuple):
    is_straggler: bool
    step_time_s: float
    mean_s: float
    step: int


class StepWatchdog:
    """start()/stop(step) around each training step; a step slower than
    `factor` x the running mean of healthy steps is flagged. The first
    `warmup` steps only feed the mean (compile steps must not trip it),
    and flagged steps are excluded from it so one hung host cannot drag
    the baseline up and mask the next stall."""

    def __init__(self, factor: float = 3.0, warmup: int = 2,
                 history: int = 64):
        self.factor = factor
        self.warmup = warmup
        self.history = history
        self._times: list = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> StragglerReport:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        mean = (sum(self._times) / len(self._times)) if self._times else dt
        flag = len(self._times) >= self.warmup and dt > self.factor * mean
        if not flag:
            self._times.append(dt)
            if len(self._times) > self.history:
                self._times.pop(0)
        return StragglerReport(flag, dt, mean, step)


def reshard_restore(ckpt_dir: str, like: Any, mesh, specs,
                    step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore the latest (or given) checkpoint onto `mesh`.

    `like` supplies the pytree structure (arrays or ShapeDtypeStructs),
    `specs` the matching logical-axis spec tree. Leaves are read on host
    and `device_put` with the target mesh's (shape-pruned) shardings, so
    the values are bit-identical regardless of how the writing mesh was
    laid out — the checkpoint format is mesh-oblivious by construction.
    Returns (state, first_step_to_run).
    """
    if step is None:
        step = checkpoint.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    host = checkpoint.restore_host(ckpt_dir, step, like)
    shardings = spec_tree_to_shardings(mesh, specs, like)
    state = jax.tree.map(jax.device_put, host, shardings)
    return state, step + 1
