"""Host-side checkpointing with atomic directory commits.

Layout (one directory per step):

  <dir>/step_00000042/manifest.json      # shapes/dtypes + leaf file list
  <dir>/step_00000042/arrays/00000.bin   # raw little-endian leaf bytes

Writers stage everything under ``step_XXXXXXXX.tmp`` and commit with one
``os.replace`` — readers (`latest_step`) only trust directories whose
manifest exists at the final path, so a crash mid-write leaves at worst a
stale ``.tmp`` that the next save of the same step overwrites. The
stage→rename commit and gated numbered-dir listing are the shared
primitives in `core/store.py` (`atomic_replace_dir` / `numbered_dirs`),
which the retrieval indexes' generation snapshots use too. Leaf bytes
are stored raw (not .npy) because bfloat16/int8 moment leaves use
ml_dtypes dtypes that predate numpy's format support; the manifest carries
the dtype names and `restore` rebuilds arrays with `np.frombuffer`.

`save(..., blocking=False)` snapshots the tree to host memory
synchronously (so donated/overwritten device buffers are safe) and does
the disk write on a background thread, returning it for `join()`.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core.store import atomic_replace_dir, numbered_dirs

_MANIFEST = "manifest.json"


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


class _SaveThread(threading.Thread):
    """Writer thread that re-raises its failure from join(): an async
    save that died (disk full, permissions) must surface to the caller —
    a silently-lost checkpoint voids the durability contract."""

    def __init__(self, fn, name: str):
        super().__init__(name=name)
        self._fn = fn
        self._exc: Optional[BaseException] = None

    def run(self):
        try:
            self._fn()
        except BaseException as e:  # noqa: BLE001 - transported to join()
            self._exc = e

    def join(self, timeout: Optional[float] = None) -> None:
        super().join(timeout)
        if self._exc is not None and not self.is_alive():
            exc, self._exc = self._exc, None
            raise exc


def save(ckpt_dir: str, step: int, tree: Any, *,
         blocking: bool = True) -> Optional[threading.Thread]:
    """Write `tree` as checkpoint `step`. Returns the writer thread when
    ``blocking=False`` (already-started; join() to wait), else None."""
    leaves = jax.tree.leaves(tree)
    # Device->host snapshot happens on the caller's thread: once save()
    # returns, the training loop may donate or overwrite every buffer.
    host = [np.asarray(x) for x in leaves]

    def write():
        final = _step_dir(ckpt_dir, step)
        tmp = final + ".tmp"
        arrays = os.path.join(tmp, "arrays")
        os.makedirs(arrays, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, a in enumerate(host):
            fname = f"{i:05d}.bin"
            with open(os.path.join(arrays, fname), "wb") as f:
                f.write(np.ascontiguousarray(a).tobytes())
            manifest["leaves"].append({"file": fname,
                                       "shape": list(a.shape),
                                       "dtype": str(a.dtype)})
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        atomic_replace_dir(tmp, final)  # re-save of the same step is ok

    if blocking:
        write()
        return None
    th = _SaveThread(write, name=f"ckpt-save-{step}")
    th.start()
    return th


def restore_host(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore checkpoint `step` as host numpy arrays in `like`'s
    structure (dtypes come from the manifest, bit-identical to save)."""
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    treedef = jax.tree.structure(like)
    entries = manifest["leaves"]
    if treedef.num_leaves != len(entries):
        raise ValueError(
            f"checkpoint {d} has {len(entries)} leaves, expected "
            f"{treedef.num_leaves} (model/optimizer structure changed?)")
    out = []
    for e in entries:
        with open(os.path.join(d, "arrays", e["file"]), "rb") as f:
            raw = f.read()
        a = np.frombuffer(raw, dtype=_np_dtype(e["dtype"]))
        out.append(a.reshape(e["shape"]))
    return jax.tree.unflatten(treedef, out)


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore checkpoint `step` as device arrays (single-device/no-mesh
    placement; see fault.reshard_restore for mesh-aware restore)."""
    import jax.numpy as jnp
    return jax.tree.map(jnp.asarray, restore_host(ckpt_dir, step, like))


def available_steps(ckpt_dir: str) -> list:
    """Committed checkpoint steps, ascending (partial writes — dirs
    without a manifest — are ignored, exactly like uncommitted index
    generations)."""
    return numbered_dirs(ckpt_dir, "step_", _MANIFEST)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None
