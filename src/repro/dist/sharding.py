"""Logical-axis sharding layer.

Models and the trainer annotate every tensor dimension with a *logical*
axis name; this module owns the single mapping from logical axes to the
physical mesh axes of whatever mesh is currently installed:

  logical      mesh axes                        carried by
  "batch"      ("pod", "data")                  data parallelism
  "fsdp"       ("data",) or ("pod", "data")     ZeRO-3 parameter shards
  "tp"         ("model",)                       tensor parallelism
  "expert"     ("model",)                       MoE expert parallelism
  "seq_sp"     ("model",)                       sequence parallelism
  "pod"        ("pod",)                         cross-pod placement

"fsdp" spans the pod axis only when `set_fsdp_spans_pods(True)` is active
(400B+ configs whose optimizer state cannot fit a single pod).

Every mapping is pruned against reality: mesh axes that do not exist on
the current mesh, are already consumed by an earlier dimension, or do not
evenly divide the dimension being sharded are dropped (that dimension is
replicated). With no mesh installed — the 1-device CPU test environment —
`shard` is the identity and `axis_size` is 1, so model code never branches
on the execution environment.

The mesh itself is ambient state installed with `use_mesh(mesh)`; only the
launchers touch it. `shard_map` wraps the moving jax API (`check_vma` vs
`check_rep`) so model code is pinned to one spelling.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --------------------------------------------------------------- mesh state

_MESH_STACK: list = []
_FSDP_SPANS_PODS = [False]


def get_mesh() -> Optional[Mesh]:
    """The innermost mesh installed by `use_mesh`, or None off-mesh."""
    return _MESH_STACK[-1] if _MESH_STACK else None


@contextmanager
def use_mesh(mesh: Mesh):
    """Install `mesh` as the ambient mesh for the dynamic extent."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def set_fsdp_spans_pods(flag: bool) -> None:
    """ZeRO-3 state spans the "pod" axis too (400B+ multi-pod configs)."""
    _FSDP_SPANS_PODS[0] = bool(flag)


def fsdp_spans_pods() -> bool:
    return _FSDP_SPANS_PODS[0]


# ------------------------------------------------------- logical -> physical

_RULES = {
    "batch": ("pod", "data"),
    "tp": ("model",),
    "expert": ("model",),
    "seq_sp": ("model",),
    "pod": ("pod",),
    # raw mesh-axis names pass through (launch code occasionally uses them)
    "data": ("data",),
    "model": ("model",),
}


def _mesh_axes_for(logical: Optional[str]) -> Tuple[str, ...]:
    if logical is None:
        return ()
    if logical == "fsdp":
        return ("pod", "data") if fsdp_spans_pods() else ("data",)
    try:
        return _RULES[logical]
    except KeyError:
        raise ValueError(f"unknown logical axis {logical!r}; "
                         f"expected one of {sorted(_RULES) + ['fsdp']}")


def axis_size(mesh: Optional[Mesh], logical: Optional[str]) -> int:
    """Total device count behind a logical axis (1 off-mesh / unmapped)."""
    if mesh is None:
        return 1
    n = 1
    for a in _mesh_axes_for(logical):
        n *= int(mesh.shape.get(a, 1))
    return n


def logical_to_spec(mesh: Mesh, axes: Sequence[Optional[str]],
                    shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axes to a PartitionSpec on `mesh`.

    Pruning rules (per dimension, in order): a mesh axis is kept only if it
    exists on `mesh`, was not already used by an earlier dimension, and —
    when `shape` is given — the accumulated shard count still divides the
    dimension. Dropped axes leave the dimension replicated.
    """
    used: set = set()
    entries = []
    for i, lg in enumerate(axes):
        keep = []
        size = 1
        for a in _mesh_axes_for(lg):
            asz = int(mesh.shape.get(a, 0))
            if asz <= 0 or a in used:
                continue
            if shape is not None and (i >= len(shape) or
                                      shape[i] % (size * asz) != 0):
                continue
            keep.append(a)
            size *= asz
        used.update(keep)
        if not keep:
            entries.append(None)
        elif len(keep) == 1:
            entries.append(keep[0])
        else:
            entries.append(tuple(keep))
    return P(*entries)


def _fit(axes: Sequence[Optional[str]], ndim: int) -> Tuple[Optional[str], ...]:
    ax = tuple(axes)[:ndim]
    return ax + (None,) * (ndim - len(ax))


def sharding_for(mesh: Mesh, *axes: Optional[str],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
    """NamedSharding for one array from its logical axes (shape-pruned)."""
    ax = _fit(axes, len(shape)) if shape is not None else axes
    return NamedSharding(mesh, logical_to_spec(mesh, ax, shape=shape))


def spec_tree_to_shardings(mesh: Mesh, spec_tree, struct_tree):
    """Tree of NamedShardings from a logical-spec tree + matching
    shape-bearing tree (arrays or ShapeDtypeStructs), pruned per-leaf.

    Spec leaves are tuples of logical axis names / None; specs shorter
    (or longer) than a leaf's rank are padded (or truncated) with
    replication, so scalar leaves may use `()`.
    """
    def one(spec, leaf):
        return sharding_for(mesh, *spec, shape=tuple(leaf.shape))

    def is_spec(x):
        return isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)

    return jax.tree.map(one, spec_tree, struct_tree, is_leaf=is_spec)


def shard(x, *axes: Optional[str]):
    """Constrain `x` to its logical sharding; identity off-mesh.

    The workhorse annotation inside model code: a no-op without a mesh or
    on a 1-device mesh, `with_sharding_constraint` otherwise. Extra axes
    beyond `x.ndim` are ignored and missing ones replicate, so call sites
    never need rank plumbing.
    """
    mesh = get_mesh()
    if mesh is None or mesh.size == 1:
        return x
    spec = logical_to_spec(mesh, _fit(axes, x.ndim), shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map(f, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """Version-stable `shard_map` (jax renamed check_rep -> check_vma and
    moved it out of jax.experimental; pin one spelling here)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)
