"""Distributed substrate: logical-axis sharding, checkpointing, fault
tolerance.

The rest of the codebase is written against this layer, never against raw
jax.sharding: models annotate activations/params with *logical* axes
("batch", "fsdp", "tp", "expert", "seq_sp"), and this package maps them to
whatever physical mesh — if any — the launcher installed. Off-mesh (the
1-device CPU test environment) every entry point degrades to a no-op, so
the exact same model code runs on a laptop and on a multi-pod slice.

  sharding.py   logical axes -> PartitionSpec / NamedSharding, mesh context
  checkpoint.py atomic directory-commit save/restore (optional async)
  fault.py      RestartManager (kill -9 survival), StepWatchdog,
                reshard_restore (elastic mesh-to-mesh recovery)
"""
from repro.dist import checkpoint, fault, sharding  # noqa: F401
