"""Training driver: real training on CPU (reduced configs) or any mesh.

  PYTHONPATH=src python -m repro.launch.train --arch gte_small --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features exercised: data pipeline w/ prefetch, microbatch accumulation,
AdamW (+int8 moments on large configs), remat, checkpoint/restart
(RestartManager survives kill -9 between steps), step watchdog.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig, ShapeConfig, TrainConfig
from repro.configs import get_config, get_reduced
from repro.data.pipeline import LMBatcher, Prefetcher
from repro.data.synthetic import lm_token_stream
from repro.data.tokenizer import HashTokenizer
from repro.dist.fault import RestartManager, StepWatchdog
from repro.models import model
from repro.train import trainer


def run(arch: str, *, reduced: bool = True, steps: int = 100, batch: int = 8,
        seq: int = 128, ckpt_dir: str = "", ckpt_interval: int = 50,
        lr: float = 3e-4, microbatches: int = 1, log_every: int = 10,
        seed: int = 0, kill_at: int = -1):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    if cfg.family == "encdec":
        raise SystemExit("use examples/train_embedder.py families for LM "
                         "training; encdec has its own batch layout")
    shape = ShapeConfig("custom", seq, batch, "train")
    run_cfg = RunConfig(model=cfg, shape=shape,
                        train=TrainConfig(learning_rate=lr,
                                          warmup_steps=min(20, steps // 5)))
    tok = HashTokenizer(cfg.vocab_size)
    stream = lm_token_stream(tok, n_tokens=max(200_000, batch * seq * 4),
                             seed=seed)
    batcher = LMBatcher(stream, batch, seq, seed=seed)
    prefetch = Prefetcher(batcher.batch_at)

    train_step, nmb, mdtype = trainer.make_train_step(
        run_cfg, max_steps=steps, microbatches=microbatches, seq_sp=False)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))
    params, opt_state = trainer.make_states(run_cfg,
                                            key=jax.random.PRNGKey(seed))
    n_params = model.count_params(params)
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"microbatches={nmb}, moments={mdtype}")

    start = 0
    rm = None
    if ckpt_dir:
        rm = RestartManager(ckpt_dir, interval=ckpt_interval)
        (params, opt_state), start = rm.maybe_restore((params, opt_state))
        if start:
            print(f"[train] restored checkpoint, resuming at step {start}")
    wd = StepWatchdog()
    losses = []
    for step in range(start, steps):
        b = prefetch.next()
        wd.start()
        params, opt_state, metrics = train_step(
            params, opt_state, {k: jnp.asarray(v) for k, v in b.items()})
        loss = float(metrics["loss"])
        rep = wd.stop(step)
        losses.append(loss)
        if rep.is_straggler:
            print(f"[watchdog] step {step} straggler: {rep.step_time_s:.2f}s"
                  f" vs mean {rep.mean_s:.2f}s")
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step} loss={loss:.4f} "
                  f"gnorm={float(metrics.get('grad_norm', 0)):.2f} "
                  f"({rep.step_time_s:.2f}s)")
        if rm:
            rm.on_step(step, (params, opt_state))
        if kill_at == step:  # fault-injection hook for tests
            print(f"[train] simulated crash at step {step}", flush=True)
            import os
            os._exit(42)
    prefetch.stop()
    if rm:
        rm.finalize(steps - 1, (params, opt_state))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25_0_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--kill-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    losses = run(args.arch, reduced=args.reduced, steps=args.steps,
                 batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                 ckpt_interval=args.ckpt_interval, lr=args.lr,
                 microbatches=args.microbatches, kill_at=args.kill_at,
                 seed=args.seed)
    print(f"[train] final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
