"""Production mesh builders.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, tp: int = 2, pod: int = 1):
    """Small mesh for subprocess integration tests (8 host devices)."""
    if pod > 1:
        return jax.make_mesh((pod, data, tp), ("pod", "data", "model"))
    return jax.make_mesh((data, tp), ("data", "model"))
