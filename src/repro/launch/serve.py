"""Serving driver: the full MobileRAG pipeline on the request-centric API.

  # batched: answer_batch(generate=True) through the RagSession
  PYTHONPATH=src python -m repro.launch.serve --pipeline mobile --questions 16

  # streaming: Poisson arrivals into a live session, per-request latency
  PYTHONPATH=src python -m repro.launch.serve --stream --arrival-qps 4

  # multi-replica: SlotScheduler over N continuous engines
  PYTHONPATH=src python -m repro.launch.serve --replicas 2

  # serving under fire: per-request deadlines + deterministic chaos
  PYTHONPATH=src python -m repro.launch.serve --replicas 3 --chaos \
      --deadline-s 30

Wires: synthetic corpus -> embedder -> EcoVector -> SCR -> RagSession
(continuous-batching decode on the slot-paged engine; retrieval/SCR of the
next queries overlaps decode of the previous ones). `--deadline-s` bounds
per-request latency (expired requests are shed, their slots freed);
`--max-pending` bounds session admission (overload degrades, then sheds);
`--chaos` wraps each replica in a seeded FaultPlan (serving/faults.py)
and reports goodput = completed-within-deadline / submitted.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data.synthetic import make_qa_corpus
from repro.serving.embedder import HashEmbedder
from repro.serving.rag import PIPELINES, accuracy


def _percentiles(xs):
    if not xs:
        return 0.0, 0.0
    return (float(np.percentile(xs, 50)), float(np.percentile(xs, 95)))


def run_batch(pipe, corpus, args) -> None:
    questions = [e.question for e in corpus.examples[: args.questions]]
    t0 = time.perf_counter()
    answers = pipe.answer_batch(questions, generate=True,
                                max_new=args.max_new)
    wall = time.perf_counter() - t0
    acc = accuracy(pipe, corpus.examples, max_q=args.questions)
    toks = [a.prompt_tokens for a in answers]
    print(f"[serve] {len(answers)} answers in {wall:.2f}s | "
          f"answer-in-context acc={acc:.2f} | "
          f"prompt tokens mean={np.mean(toks):.0f} | "
          f"measured TTFT={np.mean([a.ttft_measured_s for a in answers]):.3f}s | "
          f"model TTFT={np.mean([a.ttft_model_s for a in answers]):.2f}s | "
          f"model energy={np.mean([a.energy_model_j for a in answers]):.2f}J")
    for a in answers[:3]:
        print(f"  docs={a.doc_ids} gen={a.gen_tokens[:8]}")


def run_stream(pipe, corpus, args) -> None:
    """Poisson arrival process into a live RagSession: queries become
    visible to the session at their arrival times while it keeps stepping,
    so retrieval/SCR of late arrivals overlaps decode of early ones."""
    rng = np.random.default_rng(args.seed)
    n = args.questions
    gaps = rng.exponential(1.0 / args.arrival_qps, size=n)
    arrivals = np.cumsum(gaps)
    sink = None
    if args.trace_export:
        from repro.serving.trace import TraceSink
        sink = TraceSink()
    sess = pipe.session(max_new=args.max_new, slots=args.slots,
                        greedy=not args.sample, seed=args.seed,
                        max_pending=args.max_pending,
                        deadline_s=args.deadline_s,
                        trace=sink, slo_s=args.slo_s)
    t0 = time.perf_counter()
    submitted = 0
    latencies = []
    trace = []
    while submitted < n or sess.pending:
        now = time.perf_counter() - t0
        while submitted < n and arrivals[submitted] <= now:
            rid = sess.submit(corpus.examples[submitted].question)
            trace.append((now, rid, "submitted"))
            submitted += 1
        if not sess.pending:
            time.sleep(min(arrivals[submitted] - now, 0.05))
            continue
        for ev in sess.step():
            if ev.kind in ("retrieved", "done", "shed", "failed"):
                trace.append((time.perf_counter() - t0, ev.req_id, ev.kind))
            if ev.kind == "done":
                req = sess.requests[ev.req_id]
                latencies.append(req.latency_s)
    wall = time.perf_counter() - t0
    p50, p95 = _percentiles(latencies)
    eng = sess.engine
    c = sess.counters
    print(f"[serve --stream] {n} requests at ~{args.arrival_qps:.1f} qps "
          f"in {wall:.2f}s | latency p50={p50:.3f}s p95={p95:.3f}s | "
          f"slot util={eng.utilisation():.2f} "
          f"({eng.steps} decode steps x {eng.slots} slots) | "
          f"prefix hits={eng.prefix_hits} "
          f"tokens reused={eng.prefix_tokens_reused} | "
          f"done={c.completed} "
          f"shed={c.shed_deadline + c.shed_overload + c.shed_oversize} "
          f"degraded={c.degraded} failed={c.failed}")
    if args.slo_s is not None:
        print(f"[serve --slo-s {args.slo_s}] "
              f"slo_shed={c.shed_slo} slo_degraded={c.degraded_slo}")
    if sess.trace is not None and args.trace_export:
        m = sess.trace.export_jsonl(args.trace_export)
        print(f"[serve --trace-export] {m} records -> "
              f"{args.trace_export} (check: python tools/trace_check.py "
              f"{args.trace_export})")
    for t, rid, kind in trace[: 3 * 3]:
        print(f"  t={t:6.3f}s req={rid} {kind}")


def run_replicas(pipe, corpus, args) -> None:
    """SlotScheduler over N continuous-engine replicas (slot admission,
    per-slot stall hedging, failover). With `--chaos` each replica is
    wrapped in its seeded FaultPlan sub-schedule and the line reports
    goodput (completed within deadline / submitted)."""
    from repro.serving.scheduler import SlotScheduler
    slm = pipe._ensure_slm()
    engines = [slm.continuous(args.slots)]
    for _ in range(1, args.replicas):
        engines.append(engines[0].clone())
    sink = None
    if args.trace_export:
        from repro.serving.trace import TraceSink
        sink = TraceSink()
        for e in engines:
            e.trace = sink
    if args.chaos:
        from repro.serving.faults import FaultPlan, wrap_replicas
        engines = wrap_replicas(engines, FaultPlan.quick(args.seed))
    sched = SlotScheduler(engines, max_queue=args.max_queue,
                          deadline_s=args.deadline_s,
                          stall_s=2.0 if args.chaos else 30.0,
                          probe_cooldown_s=0.25, trace=sink)
    questions = [e.question for e in corpus.examples[: args.questions]]
    answers = pipe.answer_batch(questions)          # retrieval + SCR
    t0 = time.perf_counter()
    for a in answers:
        sched.submit(slm.encode_prompt(a.prompt, bucket=False),
                     args.max_new)
    completions = sched.run()
    wall = time.perf_counter() - t0
    lat = [c.latency_s for c in completions]
    p50, p95 = _percentiles(lat)
    cnt = sched.counters
    deadline = args.deadline_s or float("inf")
    good = sum(1 for c in completions if c.latency_s <= deadline)
    print(f"[serve --replicas {args.replicas}] {len(completions)} "
          f"completions in {wall:.2f}s | p50={p50:.3f}s p95={p95:.3f}s | "
          f"goodput={good}/{cnt.submitted} | shed={len(sched.shed)} "
          f"degraded={cnt.degraded} hedges={cnt.hedges} "
          f"drains={cnt.drains} recoveries={cnt.recoveries} | "
          f"served per replica={[s.served for s in sched.state]}")
    for c in completions[:3]:
        print(f"  rid={c.rid} replica={c.replica} hedged={c.hedged} "
              f"tokens={c.tokens[:8]}")
    if sink is not None:
        m = sink.export_jsonl(args.trace_export)
        print(f"[serve --trace-export] {m} records -> "
              f"{args.trace_export} (check: python tools/trace_check.py "
              f"{args.trace_export})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", default="mobile",
                    choices=list(PIPELINES.keys()))
    ap.add_argument("--questions", type=int, default=8)
    ap.add_argument("--docs", type=int, default=150)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--stream", action="store_true",
                    help="Poisson arrival process into a live RagSession")
    ap.add_argument("--sample", action="store_true",
                    help="sampled decode (per-request PRNG streams; "
                         "draws are independent of co-residents) instead "
                         "of greedy — --stream path")
    ap.add_argument("--arrival-qps", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline; expired requests are "
                         "shed with their engine slot freed")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="session admission bound (--stream): overload "
                         "degrades past half, sheds at the bound")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="scheduler queue bound (--replicas): "
                         "degrade-then-shed overflow policy")
    ap.add_argument("--chaos", action="store_true",
                    help="wrap each replica in a seeded FaultPlan "
                         "(crashes/stalls/slow steps) — --replicas path")
    ap.add_argument("--slo-s", type=float, default=None,
                    help="per-request latency SLO (--stream): the "
                         "session degrades retrieve_chunk/n_probe/"
                         "max_new from observed p95 stage costs before "
                         "it sheds (docs/OBSERVABILITY.md)")
    ap.add_argument("--trace-export", default=None, metavar="PATH",
                    help="record the run into a TraceSink and export "
                         "JSONL for tools/trace_check.py "
                         "(--stream / --replicas paths)")
    ap.add_argument("--page-size", type=int, default=32,
                    help="KV pool page granularity (positions per page); "
                         "smaller pages share longer prompt prefixes, "
                         "larger ones cut table/gather overhead")
    ap.add_argument("--device-budget", type=float, default=None,
                    help="device-memory budget for the retrieval index "
                         "(DESIGN.md §14): bytes, or a fraction in (0, 1] "
                         "of the all-resident pack. Builds a tiered "
                         "hot/cold EcoVector and forces device retrieval "
                         "so the tiers are exercised")
    args = ap.parse_args()

    corpus = make_qa_corpus("squad", n_docs=args.docs,
                            n_questions=args.questions, seed=args.seed)
    emb = HashEmbedder(dim=128)
    pipe_kw = {}
    if args.device_budget is not None:
        pipe_kw = {"device_budget_bytes": args.device_budget,
                   "device_retrieval": True}
    pipe = PIPELINES[args.pipeline](corpus.docs, emb, top_k=3, **pipe_kw)
    if hasattr(pipe, "_ensure_slm"):
        # the Engine is built lazily on first use, so the pool page
        # granularity can still be set here
        pipe._ensure_slm().page_size = args.page_size
    print(f"[serve] pipeline={pipe.name} docs={len(corpus.docs)} "
          f"index_build={pipe.build_s:.2f}s")

    if args.stream:
        run_stream(pipe, corpus, args)
    elif args.replicas > 1:
        run_replicas(pipe, corpus, args)
    else:
        run_batch(pipe, corpus, args)

    if args.device_budget is not None:
        idx, s = pipe.index, pipe.index.stats
        hits = s.tier_hot_hits + s.tier_cold_hits
        print(f"[serve --device-budget] hot={len(idx.hot_clusters())} "
              f"cold={len(idx.cold_clusters())} clusters | "
              f"resident={idx.device_resident_bytes()}B "
              f"budget={idx.device_budget_bytes}B "
              f"(all-resident {idx.all_resident_bytes()}B) | "
              f"hot-hit-rate={s.tier_hot_hits / max(hits, 1):.2f} | "
              f"promotions={s.promotions} demotions={s.demotions}")


if __name__ == "__main__":
    main()
