"""Serving driver: full MobileRAG pipeline with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --pipeline mobile \
      --questions 16 --replicas 2

Wires: synthetic corpus -> embedder -> EcoVector (or baseline index) ->
SCR -> sLM generation (reduced model, real decode loop) through the
Scheduler (dynamic batching + hedged re-dispatch).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data.synthetic import make_qa_corpus
from repro.data.tokenizer import HashTokenizer
from repro.models import model
from repro.serving.embedder import HashEmbedder
from repro.serving.engine import Engine
from repro.serving.rag import PIPELINES, accuracy
from repro.serving.scheduler import Scheduler


def make_generator(seed: int = 0, max_len: int = 192):
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    eng = Engine(cfg, params, max_len=max_len)
    tok = HashTokenizer(cfg.vocab_size)

    def generate(prompts, max_new=16):
        arrs = [np.asarray(tok.encode(p)[-128:], np.int32) for p in prompts] \
            if isinstance(prompts[0], str) else prompts
        res = eng.generate(arrs, max_new=max_new)
        return [r.tokens for r in res]

    return generate, tok, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", default="mobile",
                    choices=list(PIPELINES.keys()))
    ap.add_argument("--questions", type=int, default=8)
    ap.add_argument("--docs", type=int, default=150)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    corpus = make_qa_corpus("squad", n_docs=args.docs,
                            n_questions=args.questions, seed=0)
    emb = HashEmbedder(dim=128)
    pipe = PIPELINES[args.pipeline](corpus.docs, emb, top_k=3)
    print(f"[serve] pipeline={pipe.name} docs={len(corpus.docs)} "
          f"index_build={pipe.build_s:.2f}s")

    gen, tok, eng = make_generator()
    replicas = [lambda prompts, mx: gen(prompts, mx)
                for _ in range(args.replicas)]
    sched = Scheduler(replicas, max_wave=4)

    t0 = time.perf_counter()
    answers = []
    for ex in corpus.examples[: args.questions]:
        a = pipe.answer(ex.question)
        answers.append(a)
        sched.submit(np.asarray(tok.encode(a.prompt)[-96:], np.int32),
                     args.max_new)
    completions = sched.run()
    wall = time.perf_counter() - t0
    acc = accuracy(pipe, corpus.examples, max_q=args.questions)
    toks = [a.prompt_tokens for a in answers]
    print(f"[serve] {len(completions)} completions in {wall:.2f}s | "
          f"answer-in-context acc={acc:.2f} | "
          f"prompt tokens mean={np.mean(toks):.0f} | "
          f"model TTFT={np.mean([a.ttft_model_s for a in answers]):.2f}s | "
          f"model energy={np.mean([a.energy_model_j for a in answers]):.2f}J")
    for c in completions[:3]:
        print(f"  rid={c.rid} replica={c.replica} hedged={c.hedged} "
              f"tokens={c.tokens[:8]}")


if __name__ == "__main__":
    main()
