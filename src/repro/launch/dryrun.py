import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x input shape)
cell on the production meshes, print memory/cost analysis, and dump the
roofline terms to JSON.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch h2o_danube_1_8b \
      --shape train_4k [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full campaign
"""
import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.config import MeshConfig, RunConfig, SHAPES
from repro.configs import ARCH_IDS, cells, get_config
from repro.dist.sharding import (set_fsdp_spans_pods, sharding_for,
                                 spec_tree_to_shardings, use_mesh)
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.roofline.hlo import structural_cost
from repro.train import optimizer as opt
from repro.train import trainer

# TPU v5e hardware model (targets; this host is CPU so terms are derived,
# not measured)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HBM_CAP = 16e9               # bytes per chip


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return model.batch_struct(cfg, shape)
    if shape.kind == "prefill":
        b = model.batch_struct(cfg, shape)
        b.pop("labels", None)
        return b
    # decode
    return model.decode_inputs_struct(cfg, shape)


def _prefill_batch_specs(cfg):
    b = model.batch_specs(cfg)
    b.pop("labels", None)
    return b


def lower_cell(arch: str, shape_name: str, mesh, *, donate: bool = True):
    """Build and lower the step function for one cell. Returns `lowered`."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    run = RunConfig(model=cfg, shape=shape)
    pspecs = model.param_specs(cfg)

    if shape.kind == "train":
        import dataclasses
        if cfg.family == "moe" and cfg.param_count() > 60e9:
            # ZeRO++-style int8 weight gathers (EXPERIMENTS §Perf hc-3)
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, int8_gather=True))
            run = RunConfig(model=cfg, shape=shape)
        train_step, nmb, mdtype = trainer.make_train_step(run)
        p_sh, o_sh, b_sh = trainer.state_shardings(run, mesh)
        params_s, opt_s = trainer.make_states(run, abstract=True)
        batch_s = model.batch_struct(cfg, shape)
        jitted = jax.jit(train_step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1) if donate else ())
        return jitted.lower(params_s, opt_s, batch_s), {"microbatches": nmb,
                                                        "moments": mdtype}

    # serving cells use bf16 weights
    params_s = model.param_shapes(cfg, jnp.bfloat16)
    p_sh = spec_tree_to_shardings(mesh, pspecs, params_s)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(cfg, params, batch)
        batch_s = input_specs(arch, shape_name)
        b_sh = spec_tree_to_shardings(mesh, _prefill_batch_specs(cfg),
                                      batch_s)
        jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        return jitted.lower(params_s, batch_s), {}

    # decode: int8 KV for the large dense models (EXPERIMENTS §Perf hc-2)
    import dataclasses
    if cfg.family == "dense" and cfg.param_count() > 10e9:
        cfg = dataclasses.replace(cfg, kv_quant=True)

    def serve_step(params, cache, token, pos):
        return model.decode_step(cfg, params, cache, token, pos)

    cache_s = jax.eval_shape(partial(model.init_cache, cfg,
                                     shape.global_batch, shape.seq_len))
    c_sh = spec_tree_to_shardings(mesh, model.cache_specs(cfg), cache_s)
    io0 = input_specs(arch, shape_name)
    t_sh = sharding_for(mesh, "batch", None, shape=io0["token"].shape)
    io = input_specs(arch, shape_name)
    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, t_sh, None),
                     out_shardings=(None, c_sh),
                     donate_argnums=(1,) if donate else ())
    return jitted.lower(params_s, cache_s, io["token"], io["pos"]), {}


def analyze(compiled, mesh, cfg, shape) -> dict:
    """Three-term roofline from the compiled artifact (per-device module)."""
    nchips = mesh.devices.size
    # raw XLA cost analysis (kept for reference; undercounts while bodies)
    try:
        xla_cost = compiled.cost_analysis()
        if isinstance(xla_cost, list):
            xla_cost = xla_cost[0]
        xla_flops = float(xla_cost.get("flops", 0.0))
    except Exception:
        xla_flops = None
    # structural analysis with loop trip counts applied
    sc = structural_cost(compiled.as_text())
    flops_dev = sc["flops"]
    bytes_dev = sc["bytes"]
    coll = {"total": sc["collective_total"], "ops": sc["collective_ops"]}
    coll.update({k: v for k, v in sc.items()
                 if k.startswith(("coll_", "n_"))})
    mem = compiled.memory_analysis()
    memd = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        memd[attr] = getattr(mem, attr, None)
    peak_dev = (memd.get("argument_size_in_bytes") or 0) + \
        (memd.get("temp_size_in_bytes") or 0) + \
        (memd.get("output_size_in_bytes") or 0) - \
        (memd.get("alias_size_in_bytes") or 0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll.get("total", 0) / ICI_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))[1]

    # MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE); decode D = batch tokens
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
    flops_total = flops_dev * nchips
    return {
        "chips": int(nchips),
        "xla_cost_analysis_flops": xla_flops,
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll.get("total", 0),
        "collectives": {k: v for k, v in coll.items()},
        "memory_analysis": memd,
        "peak_bytes_per_device": peak_dev,
        "fits_hbm_16g": bool(peak_dev <= HBM_CAP) if peak_dev else None,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": model_flops,
        "hlo_flops_total": flops_total,
        "useful_flops_ratio": model_flops / flops_total if flops_total else None,
        "roofline_fraction": (
            model_flops / PEAK_FLOPS / nchips /
            max(t_compute, t_memory, t_coll)
            if max(t_compute, t_memory, t_coll) > 0 else None),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: Path,
             verbose: bool = True) -> dict:
    mesh_tag = "pod2" if multi_pod else "pod1"
    tag = f"{arch}.{shape_name}.{mesh_tag}"
    outfile = outdir / f"{tag}.json"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # 400B+ on multi-pod: ZeRO state must span pods to fit 16 GB chips
        set_fsdp_spans_pods(multi_pod and
                            get_config(arch).param_count() > 3e11)
        with use_mesh(mesh):
            lowered, extra = lower_cell(arch, shape_name, mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            res = analyze(compiled, mesh, cfg, shape)
            res.update(extra)
            res.update({"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                        "status": "ok", "lower_s": t_lower,
                        "compile_s": t_compile})
            if verbose:
                print(f"[{tag}] memory_analysis:", res["memory_analysis"])
                print(f"[{tag}] cost: flops/dev={res['flops_per_device']:.3e} "
                      f"bytes/dev={res['hbm_bytes_per_device']:.3e} "
                      f"coll/dev={res['collective_bytes_per_device']:.3e}")
                print(f"[{tag}] roofline: compute={res['t_compute_s']:.4f}s "
                      f"memory={res['t_memory_s']:.4f}s "
                      f"collective={res['t_collective_s']:.4f}s "
                      f"dominant={res['dominant']} "
                      f"frac={res['roofline_fraction']}")
    except Exception as e:  # record failures: they are bugs to fix
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        if verbose:
            print(f"[{tag}] FAILED: {res['error']}")
    res["wall_s"] = time.time() - t0
    outdir.mkdir(parents=True, exist_ok=True)
    outfile.write_text(json.dumps(res, indent=2, default=str))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()
    outdir = Path(args.out)

    if args.all:
        jobs = []
        for arch in ARCH_IDS:
            for sh in cells(arch):
                for mp in ((False, True) if args.both_meshes else
                           (args.multi_pod,)):
                    jobs.append((arch, sh.name, mp))
    else:
        assert args.arch and args.shape
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        jobs = [(args.arch, args.shape, mp) for mp in meshes]

    ok = bad = 0
    for arch, sh, mp in jobs:
        tag = f"{arch}.{sh}.{'pod2' if mp else 'pod1'}"
        if args.skip_done and (outdir / f"{tag}.json").exists():
            prev = json.loads((outdir / f"{tag}.json").read_text())
            if prev.get("status") == "ok":
                ok += 1
                continue
        res = run_cell(arch, sh, mp, outdir)
        if res["status"] == "ok":
            ok += 1
        else:
            bad += 1
    write_summary(outdir)
    print(f"dryrun: {ok} ok, {bad} failed")
    raise SystemExit(1 if bad else 0)


def write_summary(outdir: Path) -> Path:
    """Fold every per-cell JSON in `outdir` into one summary.json keyed
    by cell tag — the artifact tools/roofline_diff.py compares across
    nightly runs to flag roofline regressions."""
    cells_d = {}
    for p in sorted(outdir.glob("*.json")):
        if p.name == "summary.json":
            continue
        r = json.loads(p.read_text())
        if "arch" not in r:
            continue
        tag = f"{r['arch']}.{r['shape']}.{r['mesh']}"
        cells_d[tag] = {k: r.get(k) for k in (
            "status", "dominant", "t_compute_s", "t_memory_s",
            "t_collective_s", "roofline_fraction",
            "flops_per_device", "hbm_bytes_per_device",
            "collective_bytes_per_device", "peak_bytes_per_device",
            "fits_hbm_16g", "useful_flops_ratio")}
    summary = {"cells": cells_d,
               "n_ok": sum(1 for c in cells_d.values()
                           if c["status"] == "ok"),
               "n_error": sum(1 for c in cells_d.values()
                              if c["status"] != "ok")}
    out = outdir / "summary.json"
    out.write_text(json.dumps(summary, indent=2, default=str))
    print(f"summary: {len(cells_d)} cells -> {out}")
    return out


if __name__ == "__main__":
    main()
