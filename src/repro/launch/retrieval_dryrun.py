import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the paper's technique at pod scale: the sharded EcoVector
retrieval step (core/distributed.py) lowered + compiled on the production
meshes with a billion-scale synthetic index.

  PYTHONPATH=src python -m repro.launch.retrieval_dryrun [--multi-pod]

Default config: 1.07B vectors (2^20 clusters x 1024 cap x 128d would be
512 TB — we target the *per-pod* HBM budget instead: clusters are sized so
the packed index fills ~60% of pod HBM, the realistic serving ceiling).
"""
import argparse
import json
import time
from pathlib import Path

import jax

from repro.core.distributed import (make_sharded_retrieval,
                                    retrieval_input_structs,
                                    retrieval_shardings)
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo import structural_cost

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def run(multi_pod: bool, B: int = 1024, d: int = 128, cap: int = 1024,
        n_probe: int = 16, k: int = 10, hbm_frac: float = 0.6,
        out: str = "results/dryrun"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = mesh.devices.size
    # size the index to ~hbm_frac of aggregate HBM
    bytes_per_cluster = cap * d * 4 + cap * 4 + 4
    nc_per_dev = int(16e9 * hbm_frac / bytes_per_cluster)
    NC = nc_per_dev * ndev
    n_vectors = NC * cap
    structs = retrieval_input_structs(B=B, NC=NC, CAP=cap, d=d)
    shardings = retrieval_shardings(mesh)
    fn = make_sharded_retrieval(mesh, k=k, n_probe=n_probe)
    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=shardings).lower(*structs)
    compiled = lowered.compile()
    sc = structural_cost(compiled.as_text())
    mem = compiled.memory_analysis()
    res = {
        "cell": "ecovector_retrieval",
        "mesh": "pod2" if multi_pod else "pod1",
        "chips": ndev,
        "n_vectors": n_vectors,
        "n_clusters": NC,
        "batch_queries": B,
        "n_probe": n_probe,
        "flops_per_device": sc["flops"],
        "hbm_bytes_per_device": sc["bytes"],
        "collective_bytes_per_device": sc["collective_total"],
        "t_compute_s": sc["flops"] / PEAK_FLOPS,
        "t_memory_s": sc["bytes"] / HBM_BW,
        "t_collective_s": sc["collective_total"] / ICI_BW,
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "wall_s": time.time() - t0,
        "status": "ok",
    }
    res["dominant"] = max(
        ("compute", res["t_compute_s"]), ("memory", res["t_memory_s"]),
        ("collective", res["t_collective_s"]), key=lambda kv: kv[1])[0]
    tag = f"ecovector_retrieval.{res['mesh']}"
    Path(out).mkdir(parents=True, exist_ok=True)
    (Path(out) / f"{tag}.json").write_text(json.dumps(res, indent=2))
    qps_bound = B / max(res["t_compute_s"], res["t_memory_s"],
                        res["t_collective_s"])
    print(f"[{tag}] {n_vectors/1e9:.2f}B vectors in {NC/1e6:.2f}M clusters "
          f"across {ndev} chips")
    print(f"[{tag}] terms: compute={res['t_compute_s']*1e3:.3f}ms "
          f"memory={res['t_memory_s']*1e3:.3f}ms "
          f"collective={res['t_collective_s']*1e3:.3f}ms "
          f"dominant={res['dominant']} -> bound ~{qps_bound:,.0f} qps/pod "
          f"at batch {B}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--n-probe", type=int, default=16)
    args = ap.parse_args()
    modes = (False, True) if args.both else (args.multi_pod,)
    for mp in modes:
        run(mp, B=args.batch, n_probe=args.n_probe)


if __name__ == "__main__":
    main()
