"""AdamW with ZeRO-sharded state and optional quantised moments.

`moments_dtype="int8"` stores the first moment as {"q": int8 param-shaped,
"s": f32 per-row scales} (~1.03 B/param) and the second moment in bfloat16
(2 B/param): v must keep its dynamic range — linear int8 flushes small
second moments to zero and 1/sqrt(v) explodes. Net 3.06 B/param vs 8 —
the memory trick that lets the 100B+ architectures keep full optimizer
state on a single 256-chip pod. The q tensor shares the parameter's
sharding spec exactly (scales drop the last axis), so ZeRO-3 is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


# ------------------------------------------------------- int8 moment codec


def quantize_rows(x):
    """Symmetric int8 quantisation with per-row (last-axis) f32 scales."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    q = jnp.round(x / jnp.maximum(s, 1e-20)).astype(jnp.int8)
    return {"q": q, "s": s}


def dequantize_rows(qt):
    return qt["q"].astype(jnp.float32) * qt["s"]


def _is_q(x):
    return isinstance(x, dict) and set(x.keys()) == {"q", "s"}


# ------------------------------------------------------------- adamw


def lr_schedule(cfg: TrainConfig, max_steps: int = 10000) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = cfg.learning_rate * step / jnp.maximum(cfg.warmup_steps, 1)
        frac = jnp.clip((step - cfg.warmup_steps) /
                        jnp.maximum(max_steps - cfg.warmup_steps, 1), 0, 1)
        cos = cfg.learning_rate * (0.1 + 0.9 * 0.5 *
                                   (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


def init_opt_state(params, moments_dtype: str = "float32"):
    def mk_m(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if moments_dtype == "int8":
            return quantize_rows(z)
        return z.astype(moments_dtype)

    def mk_v(p):
        if moments_dtype == "int8":
            return jnp.zeros(p.shape, jnp.bfloat16)
        return jnp.zeros(p.shape, jnp.float32).astype(moments_dtype)

    return {
        "m": jax.tree.map(mk_m, params),
        "v": jax.tree.map(mk_v, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs_tree, moments_dtype: str = "float32"):
    """Logical-axis specs for the optimizer state, derived from params."""
    def mk_m(spec):
        if moments_dtype == "int8":
            return {"q": spec, "s": tuple(spec[:-1]) + (None,)}
        return spec
    is_spec = lambda x: isinstance(x, tuple)  # noqa: E731
    return {
        "m": jax.tree.map(mk_m, param_specs_tree, is_leaf=is_spec),
        "v": jax.tree.map(lambda s: s, param_specs_tree, is_leaf=is_spec),
        "step": (),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: TrainConfig, params, grads, opt_state, lr_fn,
                 moments_dtype: str = "float32"):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    lr = lr_fn(step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = dequantize_rows(m) if _is_q(m) else m.astype(jnp.float32)
        vf = dequantize_rows(v) if _is_q(v) else v.astype(jnp.float32)
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * jnp.square(g)
        u = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        new_p = (p.astype(jnp.float32) -
                 lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
        if moments_dtype == "int8":
            return (new_p.astype(p.dtype), quantize_rows(mf),
                    vf.astype(jnp.bfloat16))
        return (new_p.astype(p.dtype), mf.astype(moments_dtype),
                vf.astype(moments_dtype))

    is_q = _is_q
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    mdef = jax.tree.structure(opt_state["m"], is_leaf=is_q)
    new_m = jax.tree.unflatten(mdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(mdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
