"""Int8 error-feedback gradient compression for the cross-pod hop.

`compress_decompress` simulates the wire format in-graph: quantise each
gradient leaf to int8 (per-row scales), dequantise, and keep the residual
in an error-feedback accumulator folded into the next step's gradient.
For the stateless in-step variant used by the trainer the residual is
simply re-added (unbiased within the step); the stateful EF accumulator is
exposed for the training loop that owns persistent state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.optimizer import dequantize_rows, quantize_rows


def compress_decompress(grads):
    """Round-trip grads through the int8 wire format (per-leaf)."""
    def f(g):
        if g.ndim == 0:
            return g
        qt = quantize_rows(g.astype(jnp.float32))
        return dequantize_rows(qt).astype(g.dtype)
    return jax.tree.map(f, grads)


def compress_with_feedback(grads, ef_state):
    """Stateful error feedback: g' = Q(g + e); e' = (g + e) - g'."""
    def f(g, e):
        if g.ndim == 0:
            return g, e
        tot = g.astype(jnp.float32) + e
        qt = quantize_rows(tot)
        deq = dequantize_rows(qt)
        return deq.astype(g.dtype), tot - deq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [f(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
