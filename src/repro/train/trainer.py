"""Training step builder: microbatch gradient accumulation (scan), mixed
precision, remat (inside the models), ZeRO-3 sharding, optional gradient
compression on the cross-pod hop, AdamW.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig, TrainConfig
from repro.dist.sharding import get_mesh, shard, sharding_for, spec_tree_to_shardings
from repro.models import model
from repro.train import optimizer as opt
from repro.train.grad_compress import compress_decompress


def moments_dtype_for(cfg: ModelConfig) -> str:
    """int8 moments for 100B+ models (see optimizer.py docstring)."""
    return "int8" if cfg.param_count() > 60e9 else "float32"


def microbatches_for(cfg: ModelConfig, global_batch: int, mesh=None,
                     seq_len: int = 4096) -> int:
    dp = 1
    if mesh is not None:
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    n = cfg.param_count()
    want = 8 if n > 60e9 else (4 if n > 5e9 else 1)
    # cap activation footprint: <= 256k tokens per microbatch
    want = max(want, (global_batch * seq_len) // (256 * 1024))
    while want > 1 and (global_batch % want or (global_batch // want) % dp):
        want //= 2
    return max(want, 1)


def _split_microbatches(batch, nmb: int):
    def f(x):
        return x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])
    return jax.tree.map(f, batch)


def make_train_step(run: RunConfig, *, max_steps: int = 10000,
                    microbatches: Optional[int] = None,
                    seq_sp: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradient flow: per-microbatch grads accumulate in f32 (sharded like
    params: XLA reduce-scatters each microbatch's grads straight into the
    ZeRO-3 layout, so cross-pod traffic is one reduced gradient per step,
    overlappable with the next microbatch's compute by the latency-hiding
    scheduler). Optional int8 error-feedback compression is applied on the
    accumulated gradient before the optimizer.
    """
    cfg = run.model
    tcfg = run.train
    mesh = get_mesh()
    nmb = microbatches if microbatches is not None else \
        microbatches_for(cfg, run.shape.global_batch, mesh,
                         run.shape.seq_len)
    mdtype = moments_dtype_for(cfg)
    lr_fn = opt.lr_schedule(tcfg, max_steps)
    use_seq_sp = seq_sp and run.shape.seq_len % 16 == 0 and \
        run.shape.kind == "train"

    pspecs = model.param_specs(cfg)

    def shard_like_params(tree):
        if get_mesh() is None:
            return tree
        return jax.tree.map(lambda x, s: shard(x, *s), tree, pspecs)

    def loss_for(params, mb):
        loss, metrics = model.loss_fn(cfg, params, mb, seq_sp=use_seq_sp,
                                      z_coef=tcfg.z_loss)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if nmb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mbs = _split_microbatches(batch, nmb)
            # 400B+ regime: accumulate in bf16 to halve the gradient
            # buffer (the optimizer upcasts to f32 per update anyway)
            acc_dtype = jnp.bfloat16 if cfg.param_count() > 3e11 \
                else jnp.float32
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            g0 = shard_like_params(g0)

            def acc(carry, mb):
                gacc, lacc = carry
                (l, met), g = jax.value_and_grad(
                    loss_for, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), gacc, g)
                gacc = shard_like_params(gacc)
                return (gacc, lacc + l), None

            (grads, lsum), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / nmb,
                                 grads)
            loss = lsum / nmb
            metrics = {"loss": loss}
        if tcfg.grad_compression == "int8_ef":
            grads = compress_decompress(grads)
        grads = shard_like_params(grads)
        params2, opt_state2, om = opt.adamw_update(
            tcfg, params, grads, opt_state, lr_fn, mdtype)
        params2 = shard_like_params(params2)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return params2, opt_state2, metrics

    return train_step, nmb, mdtype


def make_states(run: RunConfig, key=None, abstract: bool = False):
    """(params, opt_state) concrete or as ShapeDtypeStructs."""
    cfg = run.model
    mdtype = moments_dtype_for(cfg)
    if abstract:
        def mk():
            p = model.init_params(cfg, jax.random.PRNGKey(0))
            return p, opt.init_opt_state(p, mdtype)
        return jax.eval_shape(mk)
    p = model.init_params(cfg, key if key is not None else jax.random.PRNGKey(0))
    return p, opt.init_opt_state(p, mdtype)


def state_shardings(run: RunConfig, mesh):
    """NamedShardings for (params, opt_state, batch) under `mesh`,
    pruned per-leaf against the actual shapes."""
    cfg = run.model
    pspecs = model.param_specs(cfg)
    ospecs = opt.opt_state_specs(pspecs, moments_dtype_for(cfg))
    bspecs = model.batch_specs(cfg)
    params_s, opt_s = make_states(run, abstract=True)
    batch_s = model.batch_struct(cfg, run.shape)
    return (spec_tree_to_shardings(mesh, pspecs, params_s),
            spec_tree_to_shardings(mesh, ospecs, opt_s),
            spec_tree_to_shardings(mesh, bspecs, batch_s))
