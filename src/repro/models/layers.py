"""Shared model building blocks: norms, RoPE/M-RoPE, attention (full /
chunked-flash / sliding-window / decode), MLP variants.

All attention paths support GQA with *activation-level* head padding:
params stay at the architecture's true head counts; at trace time q-heads
are zero-padded up to a multiple of the tensor-parallel degree and KV heads
are broadcast-expanded to the TP degree, so every head dimension shards
evenly on the mesh. Off-mesh (CPU tests) no padding happens.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import axis_size, get_mesh, shard

# ---------------------------------------------------------------- norms


def rmsnorm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------- rope


def rope_freqs(dh: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x, positions, theta=10000.0):
    """x: [B, S, H, dh]; positions: [B, S] (int)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # [dh/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta=1e6):
    """M-RoPE (Qwen2-VL): positions3 [B, S, 3] = (t, h, w) ids; `sections`
    partitions the dh/2 frequency slots among the three streams."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # [dh/2]
    if sum(sections) != dh // 2:  # rescale for reduced head dims
        f = (dh // 2) / sum(sections)
        sections = [max(1, int(s * f)) for s in sections]
        sections[-1] = dh // 2 - sum(sections[:-1])
    sec = jnp.concatenate([jnp.full((s,), i) for i, s in enumerate(sections)])
    # pick per-frequency position stream
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                # [B, S, 3]
        jnp.broadcast_to(sec.astype(jnp.int32),
                         positions3.shape[:2] + (dh // 2,)),
        axis=-1)                                       # [B, S, dh/2]
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int, offset=0):
    pos = (jnp.arange(seq_len, dtype=jnp.float32) + offset)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ------------------------------------------------------- head padding


def tp_degree() -> int:
    return axis_size(get_mesh(), "tp")


def pad_heads(q, tp: int):
    """Zero-pad head axis of q [B,S,H,dh] to a multiple of tp."""
    h = q.shape[2]
    hp = ((h + tp - 1) // tp) * tp
    if hp == h:
        return q, h
    pad = jnp.zeros(q.shape[:2] + (hp - h, q.shape[3]), q.dtype)
    return jnp.concatenate([q, pad], axis=2), h


def expand_kv(k, tp: int):
    """Broadcast-expand kv head axis of [B,S,Hkv,dh] to max(Hkv, tp)."""
    hkv = k.shape[2]
    if hkv >= tp:
        return k
    rep = tp // hkv if tp % hkv == 0 else tp  # uneven -> expand to tp fully
    if tp % hkv == 0:
        return jnp.repeat(k, rep, axis=2)
    # expand to tp by tiling each kv head ceil then slicing (rare path)
    reps = -(-tp // hkv)
    return jnp.repeat(k, reps, axis=2)[:, :, :tp, :]


# ------------------------------------------------------- attention


def _grouped_scores(q, k):
    """q: [B,Sq,Hp,dh], k: [B,Sk,G,dh] with Hp % G == 0 -> [B,G,Hp/G,Sq,Sk]"""
    b, sq, hp, dh = q.shape
    g = k.shape[2]
    qg = q.reshape(b, sq, g, hp // g, dh)
    return jnp.einsum("bqgnd,bkgd->bgnqk", qg, k)


def _grouped_context(p, v):
    b, g, n, sq, sk = p.shape
    ctx = jnp.einsum("bgnqk,bkgd->bqgnd", p, v)
    return ctx.reshape(b, sq, g * n, v.shape[-1])


def attention(q, k, v, *, causal: bool, window: Optional[int] = None,
              q_offset=0, kv_len=None, chunk: int = 1024,
              banded: bool = True):
    """Memory-bounded chunked (flash-style, online-softmax) attention.

    q [B,Sq,H,dh]; k,v [B,Sk,G,dh] (G = expanded kv heads, H % G == 0).
    `window`: sliding-window width (None = full). `kv_len`: valid kv prefix
    (for padded caches). Scans kv in chunks; when `window` is set and
    `banded`, statically skips chunks fully outside the band.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    g = k.shape[2]
    scale = 1.0 / math.sqrt(dh)
    nchunks = -(-sk // chunk)
    skp = nchunks * chunk
    if skp != sk:
        padk = jnp.zeros((b, skp - sk, g, dh), k.dtype)
        k = jnp.concatenate([k, padk], axis=1)
        v = jnp.concatenate([v, padk], axis=1)
    qpos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, nchunks, chunk, g, dh)
    vc = v.reshape(b, nchunks, chunk, g, dh)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        ci, kb, vb = inp
        kpos = ci * chunk + jnp.arange(chunk)
        s = _grouped_scores(q, kb) * scale              # [B,G,N,Sq,C] f32-ish
        s = s.astype(jnp.float32)
        mask = kpos[None, :] <= qpos[:, None] if causal else jnp.ones(
            (sq, chunk), bool)
        if window is not None:
            mask = mask & (qpos[:, None] - kpos[None, :] < window)
        if kv_len is not None:
            mask = mask & (kpos[None, :] < kv_len)
        mask = mask & (kpos[None, :] < sk)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        ctxb = jnp.einsum("bgnqk,bkgd->bgnqd", p.astype(kb.dtype), vb)
        acc = acc * corr[..., None].astype(acc.dtype) + ctxb.astype(acc.dtype)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, g, h // g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, g, h // g, sq), jnp.float32)
    a0 = jnp.zeros((b, g, h // g, sq, dh), jnp.float32)

    idx = jnp.arange(nchunks)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (idx, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out.reshape(b, h, sq, dh), 1, 2)  # [B,Sq,H,dh]
    return out.astype(q.dtype)


def quantize_kv(x):
    """[.., S, G, dh] -> (int8 values, f32 scales [.., S, G]).

    Scales are per token per kv-head — in a slot-paged cache that means
    per SLOT per position per head (`k_s`/`v_s` are `[L, slots, S, G]`),
    so each slot's quantisation is independent of its co-residents."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-10)
    q = jnp.round(x.astype(jnp.float32) / s[..., None]).astype(jnp.int8)
    return q, s


def dequantize_kv(q, s, dtype=jnp.bfloat16):
    """Inverse of `quantize_kv`: int8 values [.., S, G, dh] x scales
    [.., S, G] -> dtype. `dequantize_kv(*quantize_kv(x))` is the exact
    value every int8-KV attention path sees for x — prefill fake-quant
    (dense.block), chunked paged prefill, and the score/probability-side
    scaling in `decode_attention_q8` all agree on it bit for bit."""
    return (q.astype(jnp.float32) * s[..., None].astype(jnp.float32)
            ).astype(dtype)


def _decode_valid_mask(kv_len, b: int, s: int, *, window=None,
                       ring: bool = False):
    """[B, S] valid-slot mask for a padded decode cache. `kv_len` is the
    shared scalar length OR a per-row [B] vector (slot-paged batches where
    every request sits at its own position)."""
    kv = jnp.asarray(kv_len, jnp.int32)
    if kv.ndim == 0:
        kv = jnp.broadcast_to(kv[None], (b,))
    kv = kv[:, None]                                     # [B, 1]
    slots = jnp.arange(s)[None, :]                       # [1, S]
    if ring:
        return slots < jnp.minimum(kv, s)
    valid = slots < kv
    if window is not None:
        valid = valid & (slots >= kv - window)
    return valid


def decode_attention_q8(q, kq, ks, vq, vs, kv_len, *, window=None,
                        ring: bool = False):
    """int8-KV decode attention. kq/vq: [B,S,G,dh] int8; ks/vs: [B,S,G].

    Per-token scales commute through the dot products:
      scores_t = (q . kq_t) * ks_t      and      ctx = sum_t (p_t*vs_t) vq_t
    so the cache tensors enter the matmuls via (free) int8->bf16 converts
    and no dequantised cache copy is ever materialized.
    """
    b, _, h, dh = q.shape
    s_len, g = kq.shape[1], kq.shape[2]
    scale = 1.0 / math.sqrt(dh)
    scores = _grouped_scores(q, kq.astype(q.dtype)) * scale  # [B,G,N,1,S]
    scores = scores.astype(jnp.float32) * \
        ks.transpose(0, 2, 1)[:, :, None, None, :]
    valid = _decode_valid_mask(kv_len, b, s_len, window=window, ring=ring)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = p * vs.transpose(0, 2, 1)[:, :, None, None, :]
    ctx = _grouped_context(p.astype(q.dtype), vq.astype(q.dtype))
    return ctx


def decode_attention(q, k_cache, v_cache, kv_len, *, window=None,
                     ring: bool = False):
    """Single-position attention. q [B,1,H,dh]; caches [B,S,G,dh].

    `kv_len`: scalar shared length or per-row [B] vector (paged slots).
    `ring`: cache is a ring buffer (SWA) — all filled slots are valid.
    """
    b, _, h, dh = q.shape
    s, g = k_cache.shape[1], k_cache.shape[2]
    scale = 1.0 / math.sqrt(dh)
    s_scores = _grouped_scores(q, k_cache) * scale       # [B,G,N,1,S]
    s_scores = s_scores.astype(jnp.float32)
    valid = _decode_valid_mask(kv_len, b, s, window=window, ring=ring)
    s_scores = jnp.where(valid[:, None, None, None, :], s_scores, -1e30)
    p = jax.nn.softmax(s_scores, axis=-1)
    ctx = _grouped_context(p.astype(q.dtype), v_cache)   # [B,1,H,dh]
    return ctx


# ------------------------------------------------------- mlp


def mlp(x, w1, w2, w3, act: str):
    """w1/w3: [d, ff]; w2: [ff, d]. swiglu uses w3 as gate; sq_relu/gelu
    ignore w3 (may be None)."""
    h = x @ w1
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ w3)
    elif act == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    h = shard(h, "batch", None, "tp")
    return h @ w2


def causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x [B,S,C], w [K,C]. Returns (y, new_state)
    where state is the last K-1 inputs [B,K-1,C] for streaming decode."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    ys = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return ys, new_state
