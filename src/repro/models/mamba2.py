"""Mamba2 (SSD — state-space duality) family. [arXiv:2405.21060]

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute *within* a chunk (MXU-friendly masked matmuls) + a short sequential
recurrence over chunk states. Decode is an O(1) state update: the reason
this arch serves long_500k with a constant-size cache.

Heads are sharded over "tp"; the SSM state tensor is [B, H, N, P] with H on
"tp".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models import dense
from repro.models.common import ParamDef, embed_defs


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    H = d_in // m.head_dim
    return d_in, H, m.head_dim, m.ssm_state


def defs(cfg: ModelConfig) -> dict:
    Ln, d = cfg.num_layers, cfg.d_model
    d_in, H, P, N = _dims(cfg)
    K = cfg.mamba.conv_width
    layer = {
        "norm": ParamDef((Ln, d), (None, "fsdp"), "zeros"),
        "w_xz": ParamDef((Ln, d, 2 * d_in), (None, "fsdp", "tp")),
        "w_bc": ParamDef((Ln, d, 2 * N), (None, "fsdp", None)),
        "w_dt": ParamDef((Ln, d, H), (None, "fsdp", "tp")),
        "dt_bias": ParamDef((Ln, H), (None, "tp"), "dt_bias"),
        "A_log": ParamDef((Ln, H), (None, "tp"), "a_log"),
        "D": ParamDef((Ln, H), (None, "tp"), "zeros"),
        "conv_w": ParamDef((Ln, K, d_in + 2 * N), (None, None, None)),
        "ssm_norm": ParamDef((Ln, d_in), (None, "tp"), "zeros"),
        "w_out": ParamDef((Ln, d_in, d), (None, "tp", "fsdp")),
    }
    out = {"layers": layer}
    out.update(embed_defs(cfg))
    return out


def dt_bias_init(key, shape):
    # softplus(dt_bias) spread across (1e-3, 1e-1)
    u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 1e-1)
    return jnp.log(jnp.expm1(u))


def a_log_init(key, shape):
    return jnp.log(jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0))


# ---------------------------------------------------------------- SSD core


def _proj(cfg, lp, y):
    d_in, H, P, N = _dims(cfg)
    zx = y @ lp["w_xz"]
    z, xs = jnp.split(zx, 2, axis=-1)                 # [B,S,d_in] each
    bc = y @ lp["w_bc"]                               # [B,S,2N]
    dt = jax.nn.softplus((y @ lp["w_dt"]).astype(jnp.float32) + lp["dt_bias"])
    return z, xs, bc, dt


def ssd_chunked(cfg: ModelConfig, lp, xs, Bm, Cm, dt):
    """xs [B,S,d_in]; Bm,Cm [B,S,N]; dt [B,S,H] -> (y [B,S,d_in],
    final_state [B,H,N,P])."""
    d_in, H, P, N = _dims(cfg)
    b, S, _ = xs.shape
    Q = min(cfg.mamba.chunk_size, S)
    pad = (-S) % Q
    if pad:  # zero dt => identity recurrence on padded tail
        xs = jnp.concatenate([xs, jnp.zeros((b, pad, d_in), xs.dtype)], 1)
        Bm = jnp.concatenate([Bm, jnp.zeros((b, pad, N), Bm.dtype)], 1)
        Cm = jnp.concatenate([Cm, jnp.zeros((b, pad, N), Cm.dtype)], 1)
        dt = jnp.concatenate([dt, jnp.zeros((b, pad, H), dt.dtype)], 1)
    S_orig, S = S, S + pad
    NC = S // Q
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))     # [H], negative
    x4 = xs.reshape(b, NC, Q, H, P)
    dtc = dt.reshape(b, NC, Q, H)
    Bc = Bm.reshape(b, NC, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(b, NC, Q, N).astype(jnp.float32)
    dA = dtc * A                                      # [B,NC,Q,H]
    seg = jnp.cumsum(dA, axis=2)
    xdt = (x4.astype(jnp.float32) * dtc[..., None])   # [B,NC,Q,H,P]

    # intra-chunk (quadratic within chunk, masked lower-triangular)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)    # [B,NC,Q,Q]
    ldiff = seg[:, :, :, None, :] - seg[:, :, None, :, :]   # [B,NC,Q,K,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(ldiff), 0.0)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", scores, Lmat, xdt)

    # per-chunk terminal states
    dte = jnp.exp(seg[:, :, -1:, :] - seg)            # decay to chunk end
    states = jnp.einsum("bckn,bckh,bckhp->bchnp", Bc, dte, xdt)

    # inter-chunk recurrence over NC chunk states
    chunk_decay = jnp.exp(seg[:, :, -1])              # [B,NC,H]

    def step(h, inp):
        dec, st = inp                                  # [B,H], [B,H,N,P]
        h_out = h                                      # state entering chunk
        h = dec[..., None, None] * h + st
        return h, h_out

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    h_final, h_in = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                   # [B,NC,H,N,P]

    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", Cc, h_in, jnp.exp(seg))
    y = (y_intra + y_inter).reshape(b, S, H, P)
    y = y + x4.reshape(b, S, H, P).astype(jnp.float32) * lp["D"][None, None, :, None]
    return (y.reshape(b, S, d_in)[:, :S_orig].astype(xs.dtype), h_final)


def mixer(cfg: ModelConfig, lp, x, *, state=None, decode=False):
    """Full Mamba2 block mixer. state: (h [B,H,N,P] f32, conv [B,K-1,C])."""
    d_in, H, P, N = _dims(cfg)
    res = x
    y = L.rmsnorm(x, lp["norm"], cfg.norm_eps)
    z, xs, bc, dt = _proj(cfg, lp, y)
    conv_in = jnp.concatenate([xs, bc.astype(xs.dtype)], axis=-1)
    if decode:
        h_prev, conv_state = state
        conv_out, conv_state = L.causal_conv1d(conv_in, lp["conv_w"], conv_state)
        conv_out = jax.nn.silu(conv_out)
        xs2, bc2 = conv_out[..., :d_in], conv_out[..., d_in:]
        Bm, Cm = jnp.split(bc2, 2, axis=-1)
        A = -jnp.exp(lp["A_log"].astype(jnp.float32))
        dA = jnp.exp(dt[:, 0] * A)                    # [B,H]
        x1 = xs2[:, 0].reshape(-1, H, P).astype(jnp.float32)
        xdt = x1 * dt[:, 0][..., None]
        h = dA[..., None, None] * h_prev + \
            jnp.einsum("bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32), xdt)
        yv = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h)
        yv = yv + x1 * lp["D"][None, :, None]
        y_ssm = yv.reshape(-1, 1, d_in).astype(xs.dtype)
        new_state = (h, conv_state)
    else:
        conv_out, _ = L.causal_conv1d(conv_in, lp["conv_w"])
        conv_out = jax.nn.silu(conv_out)
        xs2, bc2 = conv_out[..., :d_in], conv_out[..., d_in:]
        xs2 = shard(xs2, "batch", None, "tp")
        Bm, Cm = jnp.split(bc2, 2, axis=-1)
        y_ssm, h_final = ssd_chunked(cfg, lp, xs2, Bm, Cm, dt)
        new_state = (h_final, conv_in[:, -(cfg.mamba.conv_width - 1):])
    y_ssm = y_ssm * jax.nn.silu(z)
    y_ssm = L.rmsnorm(y_ssm, lp["ssm_norm"], cfg.norm_eps)
    return res + y_ssm @ lp["w_out"], new_state


# ---------------------------------------------------------------- forward


def hidden_states(cfg: ModelConfig, params, batch, *, seq_sp: bool = False,
                  collect_state: bool = False):
    x, _ = dense.embed_inputs(cfg, params, batch)
    x = shard(x, "batch", "seq_sp" if seq_sp else None, None)

    def body(xc, lp):
        xc, st = mixer(cfg, lp, xc)
        if collect_state:
            return xc, st
        return xc, None

    body_fn = jax.checkpoint(body) if cfg.remat and not collect_state else body
    x, states = jax.lax.scan(body_fn, x, params["layers"])
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps), states


def forward_logits(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    x, _ = hidden_states(cfg, params, batch, seq_sp=seq_sp)
    return dense.logits_from_hidden(cfg, params, x)


# ---------------------------------------------------------------- serving


def init_cache(cfg: ModelConfig, b: int, seq_len: int, dtype=jnp.bfloat16):
    d_in, H, P, N = _dims(cfg)
    K = cfg.mamba.conv_width
    Ln = cfg.num_layers
    return {
        "h": jnp.zeros((Ln, b, H, N, P), jnp.float32),
        "conv": jnp.zeros((Ln, b, K - 1, d_in + 2 * N), dtype),
    }


def cache_specs(cfg: ModelConfig):
    return {"h": (None, "batch", "tp", None, None),
            "conv": (None, "batch", None, None)}


def prefill(cfg: ModelConfig, params, batch):
    x, states = hidden_states(cfg, params, batch, collect_state=True)
    logits = dense.logits_from_hidden(cfg, params, x[:, -1:, :])[:, 0]
    h, conv = states
    return logits, {"h": h, "conv": conv.astype(x.dtype)}


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], token, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)

    def body(carry, inp):
        xc, h_all, conv_all = carry
        lp, idx = inp
        h = jax.lax.dynamic_index_in_dim(h_all, idx, 0, keepdims=False)
        conv = jax.lax.dynamic_index_in_dim(conv_all, idx, 0, keepdims=False)
        xc, (h, conv) = mixer(cfg, lp, xc, state=(h, conv), decode=True)
        h_all = jax.lax.dynamic_update_index_in_dim(h_all, h, idx, 0)
        conv_all = jax.lax.dynamic_update_index_in_dim(
            conv_all, conv.astype(conv_all.dtype), idx, 0)
        return (xc, h_all, conv_all), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, h, conv), _ = jax.lax.scan(
        body, (x, cache["h"], cache["conv"]), (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x)[:, 0]
    return logits, {"h": h, "conv": conv}
