"""Unified model API over all families.

  init_params / param_shapes / param_specs
  loss_fn                         (training objective, all families)
  prefill / decode_step           (serving)
  make_batch_specs / make_cache   (ShapeDtypeStruct builders for dry-run)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import dense, encdec, mamba2, moe, rglru
from repro.models.common import cast_params, init_tree, shape_tree, spec_tree
from repro.models.encdec import DEC_RATIO

FAMILIES = {
    "dense": dense,
    "moe": moe,
    "encdec": encdec,
    "rglru": rglru,
    "mamba2": mamba2,
}

IGNORE_LABEL = -100


def family(cfg: ModelConfig):
    return FAMILIES[cfg.family]


def param_defs(cfg: ModelConfig) -> dict:
    return family(cfg).defs(cfg)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    custom = {
        "lam": rglru.lam_init,
        "dt_bias": mamba2.dt_bias_init,
        "a_log": mamba2.a_log_init,
    }
    return init_tree(param_defs(cfg), key, dtype, custom)


def param_shapes(cfg: ModelConfig, dtype=jnp.float32):
    return shape_tree(param_defs(cfg), dtype)


def param_specs(cfg: ModelConfig):
    return spec_tree(param_defs(cfg))


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


# ------------------------------------------------------------------ loss


def compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def loss_fn(cfg: ModelConfig, params, batch, *, seq_sp: bool = False,
            aux_coef: float = 0.01, z_coef: float = 0.0):
    """Causal-LM cross entropy (+ MoE aux loss). Returns (loss, metrics)."""
    params = cast_params(params, compute_dtype(cfg))
    aux = None
    if cfg.family == "moe":
        logits, aux = moe.forward_logits(cfg, params, batch, seq_sp=seq_sp)
    else:
        logits = family(cfg).forward_logits(cfg, params, batch, seq_sp=seq_sp)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    valid = (labels != IGNORE_LABEL)
    safe = jnp.where(valid, labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - true_logit) * valid
    ntok = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll) / ntok
    metrics = {"nll": loss, "ntokens": ntok}
    if z_coef:
        zl = z_coef * jnp.sum(jnp.square(lse) * valid) / ntok
        loss = loss + zl
        metrics["z_loss"] = zl
    if aux is not None:
        # aux was summed over layers inside the scan
        metrics["moe_aux"] = aux
        loss = loss + aux_coef * aux
    metrics["loss"] = loss
    return loss, metrics


# ------------------------------------------------------------------ serving


def prefill(cfg: ModelConfig, params, batch):
    return family(cfg).prefill(cfg, cast_params(params, compute_dtype(cfg)),
                               batch)


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    return family(cfg).decode_step(
        cfg, cast_params(params, compute_dtype(cfg)), cache, token, pos)


def supports_paged(cfg: ModelConfig) -> bool:
    """Whether the slot-paged decode path (continuous batching) covers
    this config: the dense and moe text decoder families — for dense
    including sliding-window (per-slot ring pages) and int8-KV
    (per-slot scales) variants. Still excluded: M-RoPE decode (bakes in
    a scalar position offset per image grid), non-causal encoders, the
    encdec / recurrent-state families (mamba2 / rglru keep fixed-size
    state, not paged KV), and moe+swa / moe+int8 combos — the paged
    helpers would handle them, but the legacy wave path (the parity
    baseline and `continuous=False` fallback) implements neither ring
    rolls nor KV quantization for moe, so claiming support would let
    `continuous=False` silently produce divergent tokens."""
    if cfg.modality != "text" or not cfg.causal or cfg.rope_type == "mrope":
        return False
    if cfg.family == "dense":
        return True
    return (cfg.family == "moe" and not cfg.kv_quant
            and cfg.sliding_window is None)


def decode_step_paged(cfg: ModelConfig, params, cache, token, pos, active,
                      table, *, page_size: int, ring_len: int = 0):
    """Per-slot-position decode step over a block-table page pool. token
    [B,1]; pos [B] (each slot's write position / current kv_len — the
    ring cursor `pos % ring_len` is derived inside for sliding-window
    configs); active [B] bool (inactive slots' cache writes are dropped);
    table [B, W] int32 per-slot page ids (`page_size` positions per
    page)."""
    assert supports_paged(cfg), cfg.name
    return family(cfg).decode_step_paged(
        cfg, cast_params(params, compute_dtype(cfg)), cache, token, pos,
        active, table, page_size=page_size, ring_len=ring_len)


def prefill_chunk_paged(cfg: ModelConfig, params, cache, tokens, row,
                        offset, limit=None, *, page_size: int,
                        ring_len: int = 0, abs_len: int = 0):
    """One [1, C] prefill chunk scattered through page-table row `row`
    ([W] int32) at logical `offset` of a block-table page pool; `limit` =
    offset + the chunk's real (pre-padding) length, `abs_len` the static
    absolute-order scratch length sliding-window ring reconstruction
    uses. Returns (chunk logits [1, C, V], cache)."""
    assert supports_paged(cfg), cfg.name
    return family(cfg).prefill_chunk_paged(
        cfg, cast_params(params, compute_dtype(cfg)), cache, tokens, row,
        offset, limit, page_size=page_size, ring_len=ring_len,
        abs_len=abs_len)


def init_page_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                   dtype=jnp.bfloat16):
    """Block-table KV page pool [L, num_pages, page_size, G, dh]
    (+ scale planes for `kv_quant`) — the allocation the slot-paged
    serving engine maps per-request page tables into."""
    assert supports_paged(cfg), cfg.name
    return family(cfg).init_page_pool(cfg, num_pages, page_size, dtype)


def init_cache(cfg: ModelConfig, b: int, seq_len: int, dtype=jnp.bfloat16):
    return family(cfg).init_cache(cfg, b, seq_len, dtype)


def cache_specs(cfg: ModelConfig):
    return family(cfg).cache_specs(cfg)


def encode(cfg: ModelConfig, params, batch):
    """Sentence-embedding path (bidirectional mean-pooled encoder)."""
    return dense.encode(cfg, cast_params(params, compute_dtype(cfg)), batch)


# -------------------------------------------------- dry-run input builders


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one train batch of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if cfg.family == "encdec":
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
            "dec_tokens": jax.ShapeDtypeStruct((B, S // DEC_RATIO), i32),
            "labels": jax.ShapeDtypeStruct((B, S // DEC_RATIO), i32),
        }
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }
    if cfg.modality == "vision":
        out["patches"] = jax.ShapeDtypeStruct((B, dense.N_IMG, cfg.d_model),
                                              bf16)
    return out


def batch_specs(cfg: ModelConfig) -> dict:
    """Logical sharding axes for each batch input."""
    if cfg.family == "encdec":
        return {"frames": ("batch", None, None), "dec_tokens": ("batch", None),
                "labels": ("batch", None)}
    out = {"tokens": ("batch", None), "labels": ("batch", None)}
    if cfg.modality == "vision":
        out["patches"] = ("batch", None, None)
    return out


def decode_inputs_struct(cfg: ModelConfig, shape: ShapeConfig):
    """(token, pos) structs for a decode cell; cache comes from init_cache
    via eval_shape."""
    B = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_sample_batch(cfg: ModelConfig, B: int, S: int, key=None):
    """Small concrete batch for smoke tests."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    if cfg.family == "encdec":
        sd = max(S // DEC_RATIO, 8)
        return {
            "frames": jax.random.normal(k1, (B, S, cfg.d_model), jnp.float32),
            "dec_tokens": jax.random.randint(k2, (B, sd), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (B, sd), 0, cfg.vocab_size),
        }
    out = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.modality == "vision":
        out["patches"] = jax.random.normal(
            k1, (B, dense.N_IMG, cfg.d_model), jnp.float32)
        # vision batches must be at least N_IMG + some text
        assert S > dense.N_IMG, "vision smoke batch needs S > N_IMG"
        out["labels"] = out["labels"].at[:, :dense.N_IMG].set(IGNORE_LABEL)
    return out
