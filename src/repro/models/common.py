"""Param-definition helpers shared by all model families."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical sharding axes
    init: str = "normal"              # normal | zeros | custom key
    scale: float = 0.02


def init_tree(defs, key, dtype, custom: dict[str, Callable] | None = None):
    """defs: nested dict of ParamDef -> nested dict of arrays."""
    flat, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(flat))
    custom = custom or {}
    out = []
    for d, k in zip(flat, keys):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "normal":
            out.append((jax.random.normal(k, d.shape, jnp.float32) * d.scale
                        ).astype(dtype))
        else:
            out.append(custom[d.init](k, d.shape).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def spec_tree(defs):
    return jax.tree.map(lambda d: d.axes, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def shape_tree(defs, dtype):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# Parameters kept in f32 regardless of compute dtype (recurrence-critical)
F32_KEEP = ("lam", "A_log", "dt_bias", "D")


def cast_params(tree, dtype):
    """Mixed-precision policy: cast weights to compute dtype at use-site
    (differentiable, so grads flow to the f32 masters)."""
    def f(path, a):
        last = path[-1]
        name = getattr(last, "key", None) or str(last)
        if name in F32_KEEP:
            return a
        return a.astype(dtype) if a.dtype == jnp.float32 else a
    return jax.tree_util.tree_map_with_path(f, tree)


def attn_defs(cfg: ModelConfig, L: int, prefix: str = "") -> dict:
    """Per-layer-stacked attention params."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, max(cfg.num_kv_heads, 1)
    defs = {
        f"{prefix}attn_norm": ParamDef((L, d), (None, "fsdp"), "zeros"),
        f"{prefix}wq": ParamDef((L, d, h * hd), (None, "fsdp", "tp")),
        f"{prefix}wk": ParamDef((L, d, kv * hd), (None, "fsdp", "tp")),
        f"{prefix}wv": ParamDef((L, d, kv * hd), (None, "fsdp", "tp")),
        f"{prefix}wo": ParamDef((L, h * hd, d), (None, "tp", "fsdp")),
    }
    if cfg.qkv_bias:
        defs[f"{prefix}bq"] = ParamDef((L, h * hd), (None, "tp"), "zeros")
        defs[f"{prefix}bk"] = ParamDef((L, kv * hd), (None, "tp"), "zeros")
        defs[f"{prefix}bv"] = ParamDef((L, kv * hd), (None, "tp"), "zeros")
    return defs


def mlp_defs(cfg: ModelConfig, L: int, d_ff: int, prefix: str = "") -> dict:
    d = cfg.d_model
    defs = {
        f"{prefix}mlp_norm": ParamDef((L, d), (None, "fsdp"), "zeros"),
        f"{prefix}w1": ParamDef((L, d, d_ff), (None, "fsdp", "tp")),
        f"{prefix}w2": ParamDef((L, d_ff, d), (None, "tp", "fsdp")),
    }
    if cfg.act == "swiglu":
        defs[f"{prefix}w3"] = ParamDef((L, d, d_ff), (None, "fsdp", "tp"))
    return defs


def embed_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    defs = {
        "tok_embed": ParamDef((cfg.vocab_padded, d), ("tp", "fsdp")),
        "final_norm": ParamDef((d,), ("fsdp",), "zeros"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.vocab_padded), ("fsdp", "tp"))
    if cfg.modality == "vision":
        defs["patch_proj"] = ParamDef((d, d), ("fsdp", "tp"))
    if cfg.modality == "audio":
        defs["frame_proj"] = ParamDef((d, d), ("fsdp", "tp"))
    return defs
