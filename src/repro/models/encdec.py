"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

The conv/mel frontend is a STUB per the assignment: inputs are precomputed
frame embeddings [B, S_enc, d] (projected by `frame_proj`). Positions are
sinusoidal (shape-independent, unlike whisper's learned tables, so the
synthetic 32k-frame shapes stay well-defined).

train_4k/prefill_32k: S_enc = shape.seq_len, S_dec = S_enc // DEC_RATIO.
decode_32k: decoder self-attn KV cache of shape.seq_len; cross-attn KV over
`encdec.cross_kv_len` frames.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models import dense
from repro.models.common import attn_defs, embed_defs, mlp_defs, ParamDef

DEC_RATIO = 8


def defs(cfg: ModelConfig) -> dict:
    e = cfg.encdec
    enc = {**attn_defs(cfg, e.encoder_layers),
           **mlp_defs(cfg, e.encoder_layers, cfg.d_ff)}
    dec = {**attn_defs(cfg, e.decoder_layers),
           **attn_defs(cfg, e.decoder_layers, prefix="cross_"),
           **mlp_defs(cfg, e.decoder_layers, cfg.d_ff)}
    out = {"enc_layers": enc, "dec_layers": dec}
    out.update(embed_defs(cfg))
    return out


def _cross_kv(cfg, lp, enc_out):
    hd = cfg.resolved_head_dim
    b, s, _ = enc_out.shape
    k = (enc_out @ lp["cross_wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (enc_out @ lp["cross_wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    tp = L.tp_degree()
    return L.expand_kv(k, tp), L.expand_kv(v, tp)


def _cross_attend(cfg, lp, x, ck, cv):
    h = cfg.num_heads
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = (x @ lp["cross_wq"]).reshape(b, s, h, hd)
    tp = L.tp_degree()
    q, _ = L.pad_heads(q, tp)
    q = shard(q, "batch", None, "tp", None)
    if s == 1:
        ctx = L.decode_attention(q, ck, cv, ck.shape[1])
    else:
        ctx = L.attention(q, ck, cv, causal=False)
    ctx = ctx[:, :, :h, :]
    return ctx.reshape(b, s, -1) @ lp["cross_wo"]


def enc_block(cfg, lp, x):
    h = cfg.num_heads
    res = x
    y = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = dense._qkv(cfg, lp, y, None)
    ctx = L.attention(q, k, v, causal=False)[:, :, :h, :]
    x = res + ctx.reshape(ctx.shape[0], ctx.shape[1], -1) @ lp["wo"]
    res = x
    y = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    return res + L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)


def dec_block(cfg, lp, x, enc_out):
    """Training/prefill decoder block (full sequence)."""
    h = cfg.num_heads
    res = x
    y = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = dense._qkv(cfg, lp, y, None)
    ctx = L.attention(q, k, v, causal=True)[:, :, :h, :]
    x = res + ctx.reshape(ctx.shape[0], ctx.shape[1], -1) @ lp["wo"]
    res = x
    y = L.rmsnorm(x, lp["cross_attn_norm"], cfg.norm_eps)
    ck, cv = _cross_kv(cfg, lp, enc_out)
    x = res + _cross_attend(cfg, lp, y, ck, cv)
    res = x
    y = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    return res + L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)


def encode(cfg: ModelConfig, params, frames):
    """frames: [B, S_enc, d] stub embeddings -> enc_out [B, S_enc, d]."""
    x = frames.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    x = x @ params["frame_proj"]
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    x = shard(x, "batch", None, None)

    def body(xc, lp):
        return enc_block(cfg, lp, xc), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return x


def decode_train(cfg: ModelConfig, params, tokens, enc_out):
    x = jnp.take(params["tok_embed"], tokens, axis=0)
    x = x.astype(enc_out.dtype)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(xc, lp):
        return dec_block(cfg, lp, xc, enc_out), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def forward_logits(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    enc_out = encode(cfg, params, batch["frames"])
    x = decode_train(cfg, params, batch["dec_tokens"], enc_out)
    return dense.logits_from_hidden(cfg, params, x)


# ---------------------------------------------------------------- serving


def init_cache(cfg: ModelConfig, b: int, seq_len: int, dtype=jnp.bfloat16):
    g = dense.kv_expanded_heads(cfg)
    hd = cfg.resolved_head_dim
    Ld = cfg.encdec.decoder_layers
    return {
        "k": jnp.zeros((Ld, b, seq_len, g, hd), dtype),
        "v": jnp.zeros((Ld, b, seq_len, g, hd), dtype),
        "cross_k": jnp.zeros((Ld, b, cfg.encdec.cross_kv_len, g, hd), dtype),
        "cross_v": jnp.zeros((Ld, b, cfg.encdec.cross_kv_len, g, hd), dtype),
    }


def cache_specs(cfg: ModelConfig):
    axes = (None, "batch", None, "tp", None)
    return {"k": axes, "v": axes, "cross_k": axes, "cross_v": axes}


def prefill(cfg: ModelConfig, params, batch):
    """Encode frames, prefill decoder over `dec_tokens`, build both caches."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["dec_tokens"]
    x = jnp.take(params["tok_embed"], tokens, axis=0).astype(enc_out.dtype)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(xc, lp):
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        _, k, v = dense._qkv(cfg, lp, y, None)
        ck, cv = _cross_kv(cfg, lp, enc_out)
        xc = dec_block(cfg, lp, xc, enc_out)
        return xc, (k, v, ck, cv)

    x, (k, v, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x[:, -1:, :])[:, 0]
    return logits, {"k": k, "v": v, "cross_k": ck, "cross_v": cv}


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    x = jnp.take(params["tok_embed"], token, axis=0)
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    pe = L.sinusoidal_positions(1, cfg.d_model, offset=pos).astype(x.dtype)
    x = x + pe[None]

    zero = jnp.int32(0)

    def body(carry, inp):
        xc, ck_all, cv_all = carry
        lp, xk, xv, idx = inp
        h = cfg.num_heads
        b = xc.shape[0]
        res = xc
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        q, k, v = dense._qkv(cfg, lp, y, None)
        ck_all = jax.lax.dynamic_update_slice(
            ck_all, k[None].astype(ck_all.dtype), (idx, zero, pos, zero, zero))
        cv_all = jax.lax.dynamic_update_slice(
            cv_all, v[None].astype(cv_all.dtype), (idx, zero, pos, zero, zero))
        ck = jax.lax.dynamic_index_in_dim(ck_all, idx, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, idx, 0, keepdims=False)
        ctx = L.decode_attention(q, ck.astype(k.dtype), cv.astype(v.dtype),
                                 pos + 1)[:, :, :h, :]
        xc = res + ctx.reshape(b, 1, -1) @ lp["wo"]
        res = xc
        y = L.rmsnorm(xc, lp["cross_attn_norm"], cfg.norm_eps)
        xc = res + _cross_attend(cfg, lp, y, xk, xv)
        res = xc
        y = L.rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        xc = res + L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
        return (xc, ck_all, cv_all), None

    idxs = jnp.arange(cfg.encdec.decoder_layers, dtype=jnp.int32)
    (x, k, v), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["dec_layers"], cache["cross_k"], cache["cross_v"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x)[:, 0]
    return logits, {"k": k, "v": v, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
