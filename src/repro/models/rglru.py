"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention,
repeating pattern "rra" (2 recurrent : 1 local-attn). [arXiv:2402.19427]

Layer stacking: the pattern repeats `NB = num_layers // len(pattern)` times
as a scanned *super-block* (heterogeneous sub-layers, homogeneous across
repeats); remainder layers run unrolled as a small "tail".

RG-LRU: a_t = exp(-c * softplus(Λ) * sigmoid(r_t)),
        h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
Training uses an associative scan (O(S log S), parallel); decode is a
single-step state update — the reason this arch serves long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models import dense
from repro.models.common import ParamDef, attn_defs, embed_defs, mlp_defs

RG_C = 8.0


def _rec_defs(cfg: ModelConfig, NB: int, prefix: str) -> dict:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    K = cfg.rglru.conv_width
    return {
        f"{prefix}norm": ParamDef((NB, d), (None, "fsdp"), "zeros"),
        f"{prefix}w_in": ParamDef((NB, d, w), (None, "fsdp", "tp")),
        f"{prefix}w_gate": ParamDef((NB, d, w), (None, "fsdp", "tp")),
        f"{prefix}conv_w": ParamDef((NB, K, w), (None, None, "tp")),
        f"{prefix}w_rx": ParamDef((NB, w, 2 * w), (None, "fsdp", "tp")),
        f"{prefix}b_rx": ParamDef((NB, 2 * w), (None, "tp"), "zeros"),
        f"{prefix}lam": ParamDef((NB, w), (None, "tp"), "lam"),
        f"{prefix}w_out": ParamDef((NB, w, d), (None, "tp", "fsdp")),
        **mlp_defs(cfg, NB, cfg.d_ff, prefix=prefix),
    }


def defs(cfg: ModelConfig) -> dict:
    pat = cfg.rglru.pattern
    NB, rem = divmod(cfg.num_layers, len(pat))
    layer: dict = {}
    for i, c in enumerate(pat):
        if c == "r":
            layer.update(_rec_defs(cfg, NB, f"s{i}_"))
        else:
            layer.update(attn_defs(cfg, NB, prefix=f"s{i}_"))
            layer.update(mlp_defs(cfg, NB, cfg.d_ff, prefix=f"s{i}_"))
    out = {"layers": layer}
    for j in range(rem):  # tail layers follow the pattern from the start
        c = pat[j]
        if c == "r":
            out[f"tail{j}"] = _rec_defs(cfg, 1, "")
        else:
            out[f"tail{j}"] = {**attn_defs(cfg, 1, ""),
                               **mlp_defs(cfg, 1, cfg.d_ff, "")}
    out.update(embed_defs(cfg))
    return out


def lam_init(key, shape):
    # a = sigmoid(lam)-driven decay in ~(0.9, 0.999)
    u = jax.random.uniform(key, shape, jnp.float32, 0.38, 0.8)
    return jnp.log(jnp.exp(-jnp.log(u) / RG_C) - 1.0)  # inverse softplus


# ------------------------------------------------------------- RG-LRU core


def _gates(lp, xc):
    g = xc @ lp["w_rx"] + lp["b_rx"]
    r, i = jnp.split(g, 2, axis=-1)
    log_a = -RG_C * jax.nn.softplus(lp["lam"].astype(jnp.float32)) * \
        jax.nn.sigmoid(r.astype(jnp.float32))
    gated_x = (xc.astype(jnp.float32) *
               jax.nn.sigmoid(i.astype(jnp.float32)))
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return log_a, beta * gated_x


def rglru_scan(lp, xc):
    """xc: [B, S, w] conv output -> recurrent output [B, S, w] (train)."""
    log_a, bx = _gates(lp, xc)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    la, h = jax.lax.associative_scan(combine, (log_a, bx), axis=1)
    return h.astype(xc.dtype)


def rglru_step(lp, xc, h_prev):
    """xc: [B, 1, w]; h_prev: [B, w] -> (y [B,1,w], h [B,w])."""
    log_a, bx = _gates(lp, xc)
    h = jnp.exp(log_a[:, 0]) * h_prev + bx[:, 0]
    return h.astype(xc.dtype)[:, None], h


def rec_block(cfg: ModelConfig, lp, x, *, state=None, decode=False):
    """Griffin recurrent block. state: (h [B,w] f32, conv [B,K-1,w])."""
    res = x
    y = L.rmsnorm(x, lp["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(y @ lp["w_gate"])
    xin = y @ lp["w_in"]
    xin = shard(xin, "batch", None, "tp")
    if decode:
        h_prev, conv_state = state
        xc, conv_state = L.causal_conv1d(xin, lp["conv_w"], conv_state)
        yr, h = rglru_step(lp, xc, h_prev)
        new_state = (h, conv_state)
    else:
        xc, _ = L.causal_conv1d(xin, lp["conv_w"])
        yr = rglru_scan(lp, xc)
        new_state = None
    x = res + (gate * yr) @ lp["w_out"]
    res = x
    y = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    x = res + L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
    return x, new_state


def attn_block(cfg: ModelConfig, lp, x, positions, *, cache=None, pos=None):
    """Local (sliding-window) attention block."""
    win = cfg.rglru.local_window
    h = cfg.num_heads
    res = x
    y = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    if cache is None:
        q, k, v = dense._qkv(cfg, lp, y, positions)
        ctx = L.attention(q, k, v, causal=True, window=win)
        new_cache = None
    else:
        ck, cv = cache
        positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
        q, k, v = dense._qkv(cfg, lp, y, positions)
        sc = ck.shape[1]
        slot = pos % sc
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        ctx = L.decode_attention(q, ck, cv, pos + 1, ring=True)
        new_cache = (ck, cv)
    ctx = ctx[:, :, :h, :]
    x = res + ctx.reshape(ctx.shape[0], ctx.shape[1], -1) @ lp["wo"]
    res = x
    y = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    x = res + L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
    return x, new_cache


def _sub(lp, i):
    pre = f"s{i}_"
    return {k[len(pre):]: v for k, v in lp.items() if k.startswith(pre)}


# ------------------------------------------------------------- forward


def hidden_states(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    x, positions = dense.embed_inputs(cfg, params, batch)
    pat = cfg.rglru.pattern

    def body(xc, lp):
        for i, c in enumerate(pat):
            sub = _sub(lp, i)
            if c == "r":
                xc, _ = rec_block(cfg, sub, xc)
            else:
                xc, _ = attn_block(cfg, sub, xc, positions)
        return xc, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    rem = cfg.num_layers % len(pat)
    for j in range(rem):
        tail = jax.tree.map(lambda a: a[0], params[f"tail{j}"])
        if pat[j] == "r":
            x, _ = rec_block(cfg, tail, x)
        else:
            x, _ = attn_block(cfg, tail, x, positions)
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def forward_logits(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    return dense.logits_from_hidden(
        cfg, params, hidden_states(cfg, params, batch, seq_sp=seq_sp))


# ------------------------------------------------------------- serving


def init_cache(cfg: ModelConfig, b: int, seq_len: int, dtype=jnp.bfloat16):
    pat = cfg.rglru.pattern
    NB, rem = divmod(cfg.num_layers, len(pat))
    w = cfg.rglru.lru_width or cfg.d_model
    K = cfg.rglru.conv_width
    g = dense.kv_expanded_heads(cfg)
    hd = cfg.resolved_head_dim
    win = min(cfg.rglru.local_window, seq_len)
    cache: dict = {}
    for i, c in enumerate(pat):
        if c == "r":
            cache[f"s{i}_h"] = jnp.zeros((NB, b, w), jnp.float32)
            cache[f"s{i}_conv"] = jnp.zeros((NB, b, K - 1, w), dtype)
        else:
            cache[f"s{i}_k"] = jnp.zeros((NB, b, win, g, hd), dtype)
            cache[f"s{i}_v"] = jnp.zeros((NB, b, win, g, hd), dtype)
    for j in range(rem):
        if pat[j] == "r":
            cache[f"tail{j}_h"] = jnp.zeros((b, w), jnp.float32)
            cache[f"tail{j}_conv"] = jnp.zeros((b, K - 1, w), dtype)
        else:
            cache[f"tail{j}_k"] = jnp.zeros((b, win, g, hd), dtype)
            cache[f"tail{j}_v"] = jnp.zeros((b, win, g, hd), dtype)
    return cache


def cache_specs(cfg: ModelConfig):
    pat = cfg.rglru.pattern
    rem = cfg.num_layers % len(pat)
    specs: dict = {}
    for i, c in enumerate(pat):
        if c == "r":
            specs[f"s{i}_h"] = (None, "batch", "tp")
            specs[f"s{i}_conv"] = (None, "batch", None, "tp")
        else:
            specs[f"s{i}_k"] = (None, "batch", None, "tp", None)
            specs[f"s{i}_v"] = (None, "batch", None, "tp", None)
    for j in range(rem):
        if pat[j] == "r":
            specs[f"tail{j}_h"] = ("batch", "tp")
            specs[f"tail{j}_conv"] = ("batch", None, "tp")
        else:
            specs[f"tail{j}_k"] = ("batch", None, "tp", None)
            specs[f"tail{j}_v"] = ("batch", None, "tp", None)
    return specs


def prefill(cfg: ModelConfig, params, batch):
    """Prefill = full forward while collecting terminal recurrent states and
    ring-layout local-attention caches."""
    x, positions = dense.embed_inputs(cfg, params, batch)
    pat = cfg.rglru.pattern
    S = x.shape[1]
    win = min(cfg.rglru.local_window, S)

    def body(xc, lp):
        outs = {}
        for i, c in enumerate(pat):
            sub = _sub(lp, i)
            if c == "r":
                y = L.rmsnorm(xc, sub["norm"], cfg.norm_eps)
                xin = y @ sub["w_in"]
                xconv, _ = L.causal_conv1d(xin, sub["conv_w"])
                log_a, bx = _gates(sub, xconv)

                def comb(e1, e2):
                    return e1[0] + e2[0], jnp.exp(e2[0]) * e1[1] + e2[1]
                _, hseq = jax.lax.associative_scan(comb, (log_a, bx), axis=1)
                outs[f"s{i}_h"] = hseq[:, -1]
                outs[f"s{i}_conv"] = xin[:, S - (cfg.rglru.conv_width - 1):]
                xc, _ = rec_block(cfg, sub, xc)
            else:
                y = L.rmsnorm(xc, sub["attn_norm"], cfg.norm_eps)
                _, k, v = dense._qkv(cfg, sub, y, positions)
                kw = jnp.roll(k[:, S - win:], shift=S % win, axis=1)
                vw = jnp.roll(v[:, S - win:], shift=S % win, axis=1)
                outs[f"s{i}_k"], outs[f"s{i}_v"] = kw, vw
                xc, _ = attn_block(cfg, sub, xc, positions)
        return xc, outs

    x, cache = jax.lax.scan(body, x, params["layers"])
    rem = cfg.num_layers % len(pat)
    for j in range(rem):
        tail = jax.tree.map(lambda a: a[0], params[f"tail{j}"])
        if pat[j] == "r":
            y = L.rmsnorm(x, tail["norm"], cfg.norm_eps)
            xin = y @ tail["w_in"]
            xconv, _ = L.causal_conv1d(xin, tail["conv_w"])
            log_a, bx = _gates(tail, xconv)

            def comb(e1, e2):
                return e1[0] + e2[0], jnp.exp(e2[0]) * e1[1] + e2[1]
            _, hseq = jax.lax.associative_scan(comb, (log_a, bx), axis=1)
            cache[f"tail{j}_h"] = hseq[:, -1]
            cache[f"tail{j}_conv"] = xin[:, S - (cfg.rglru.conv_width - 1):]
            x, _ = rec_block(cfg, tail, x)
        else:
            y = L.rmsnorm(x, tail["attn_norm"], cfg.norm_eps)
            _, k, v = dense._qkv(cfg, tail, y, positions)
            cache[f"tail{j}_k"] = jnp.roll(k[:, S - win:], S % win, axis=1)
            cache[f"tail{j}_v"] = jnp.roll(v[:, S - win:], S % win, axis=1)
            x, _ = attn_block(cfg, tail, x, positions)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x[:, -1:, :])[:, 0]
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], token, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    pat = cfg.rglru.pattern

    def body(xc, inp):
        lp = inp
        outs = {}
        for i, c in enumerate(pat):
            sub = _sub(lp, i)
            if c == "r":
                st = (lp[f"__c_s{i}_h"], lp[f"__c_s{i}_conv"])
                xc, (h, conv) = rec_block(cfg, sub, xc, state=st, decode=True)
                outs[f"s{i}_h"], outs[f"s{i}_conv"] = h, conv
            else:
                ck, cv = lp[f"__c_s{i}_k"], lp[f"__c_s{i}_v"]
                xc, (ck, cv) = attn_block(cfg, sub, xc, None,
                                          cache=(ck, cv), pos=pos)
                outs[f"s{i}_k"], outs[f"s{i}_v"] = ck, cv
        return xc, outs

    xs = dict(params["layers"])
    for name, arr in cache.items():
        if not name.startswith("tail"):
            xs[f"__c_{name}"] = arr
    x, new_cache = jax.lax.scan(body, x, xs)
    rem = cfg.num_layers % len(pat)
    for j in range(rem):
        tail = jax.tree.map(lambda a: a[0], params[f"tail{j}"])
        if pat[j] == "r":
            st = (cache[f"tail{j}_h"], cache[f"tail{j}_conv"])
            x, (h, conv) = rec_block(cfg, tail, x, state=st, decode=True)
            new_cache[f"tail{j}_h"], new_cache[f"tail{j}_conv"] = h, conv
        else:
            ck, cv = cache[f"tail{j}_k"], cache[f"tail{j}_v"]
            x, (ck, cv) = attn_block(cfg, tail, x, None, cache=(ck, cv),
                                     pos=pos)
            new_cache[f"tail{j}_k"], new_cache[f"tail{j}_v"] = ck, cv
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x)[:, 0]
    return logits, new_cache
