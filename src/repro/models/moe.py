"""MoE decoder family: arctic-480b (128e top-2 + dense residual),
granite-moe-1b-a400m (32e top-8).

Expert parallelism: activations are replicated along the "model" axis
(they already are, post-attention-allreduce), experts are sharded along it.
Each model-shard routes its *local copy* of the tokens, keeps only the
tokens destined for its resident experts, runs them through a capacity-
bounded [E_local, C, d] buffer, and the final psum over "model" combines
expert outputs — the same collective a TP dense FFN already pays, so EP
here adds **zero** extra all-to-all traffic. This is a deliberate TPU
adaptation (see DESIGN.md §3): classic all-to-all dispatch assumes token
shards differ per expert-shard, which is not true in 2-D (data, model)
meshes with replicated activations.

Dispatch inside a shard uses the sort-based grouping trick (argsort by
expert id, cumsum offsets, capacity drop) — no [T, E, C] one-hot.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.dist.sharding import (fsdp_spans_pods, get_mesh, logical_to_spec,
                                 shard, shard_map)
from repro.models import layers as L
from repro.models.common import ParamDef, attn_defs, embed_defs, mlp_defs
from repro.models import dense


def defs(cfg: ModelConfig) -> dict:
    Ln, d, m = cfg.num_layers, cfg.d_model, cfg.moe
    layer = {**attn_defs(cfg, Ln)}
    layer["moe_norm"] = ParamDef((Ln, d), (None, "fsdp"), "zeros")
    layer["router"] = ParamDef((Ln, d, m.num_experts), (None, "fsdp", None))
    layer["we1"] = ParamDef((Ln, m.num_experts, d, m.expert_d_ff),
                            (None, "expert", "fsdp", None))
    layer["we2"] = ParamDef((Ln, m.num_experts, m.expert_d_ff, d),
                            (None, "expert", None, "fsdp"))
    if cfg.act == "swiglu":
        layer["we3"] = ParamDef((Ln, m.num_experts, d, m.expert_d_ff),
                                (None, "expert", "fsdp", None))
    if m.dense_residual:
        layer.update(mlp_defs(cfg, Ln, cfg.d_ff))
    else:
        layer["mlp_norm"] = layer.pop("moe_norm")  # single pre-FFN norm name
    out = {"layers": layer}
    out.update(embed_defs(cfg))
    return out


# ------------------------------------------------- quantised FSDP gather


def _q8_axis(w, axis):
    s = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True) \
        / 127.0
    s = jnp.maximum(s, 1e-20)
    q = jnp.round(w.astype(jnp.float32) / s).astype(jnp.int8)
    return q, s


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def q8_all_gather(w, axis_name, gather_axis, quant_axis):
    """ZeRO++-style int8 weight all-gather: quantise the local shard
    (per-row scales along `quant_axis`), gather int8 + scales, dequantise.
    Halves the FSDP gather's ICI bytes. Backward = the same
    reduce-scatter the bf16 gather would produce (straight-through)."""
    q, s = _q8_axis(w, quant_axis)
    qf = jax.lax.all_gather(q, axis_name, axis=gather_axis, tiled=True)
    sf = jax.lax.all_gather(s, axis_name, axis=gather_axis, tiled=True)
    return qf.astype(jnp.bfloat16) * sf.astype(jnp.bfloat16)


def _q8_fwd(w, axis_name, gather_axis, quant_axis):
    return (q8_all_gather(w, axis_name, gather_axis, quant_axis),
            jnp.zeros((), w.dtype))


def _q8_bwd(axis_name, gather_axis, quant_axis, res, g):
    gw = jax.lax.psum_scatter(g.astype(jnp.float32), axis_name,
                              scatter_dimension=gather_axis, tiled=True)
    return (gw.astype(res.dtype),)


q8_all_gather.defvjp(_q8_fwd, _q8_bwd)


# ----------------------------------------------------------- dispatch core


def _local_moe(cfg: ModelConfig, x, router, we1, we2, we3, e_offset, E_total,
               capacity: int | None = None):
    """Token-choice top-k MoE over the experts resident in this shard.

    x: [T, d] local tokens; we*: [El, ...] local experts covering global
    ids [e_offset, e_offset + El). Returns (y [T, d] partial sum over local
    experts, aux load-balance loss term).

    `capacity` overrides the capacity_factor-derived per-expert buffer
    size. Serving paths pass T — the true no-drop bound, since top_k
    assigns distinct experts per token: every token's output then
    depends only on its own row, which is what makes wave/paged decode
    bit-identical and a slot's tokens independent of its co-residents.
    Training keeps the capacity_factor drops.
    """
    m = cfg.moe
    T, d = x.shape
    El = we1.shape[0]
    k = m.top_k
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    topv, topi = jax.lax.top_k(probs, k)                        # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # aux loss (computed identically on every shard; fine under psum/mean)
    f = jnp.zeros(E_total, jnp.float32).at[topi.reshape(-1)].add(1.0) / (T * k)
    pbar = probs.mean(0)
    aux = E_total * jnp.sum(f * pbar)

    C = capacity or max(4, int(T * k * m.capacity_factor) // E_total)
    eids = topi.reshape(-1)                                     # [T*k]
    local = (eids >= e_offset) & (eids < e_offset + El)
    leids = jnp.where(local, eids - e_offset, El)               # El = trash
    order = jnp.argsort(leids)
    sorted_ids = leids[order]
    counts = jnp.zeros(El + 1, jnp.int32).at[leids].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[sorted_ids]
    keep = (sorted_ids < El) & (pos < C)
    dest = jnp.where(keep, sorted_ids * C + pos, El * C)
    src_tok = order // k
    buf = jnp.zeros((El * C + 1, d), x.dtype).at[dest].set(x[src_tok])
    h = buf[: El * C].reshape(El, C, d)
    a = jnp.einsum("ecd,edf->ecf", h, we1)
    if cfg.act == "swiglu":
        a = jax.nn.silu(a) * jnp.einsum("ecd,edf->ecf", h, we3)
    elif cfg.act == "sq_relu":
        a = jnp.square(jax.nn.relu(a))
    else:
        a = jax.nn.gelu(a)
    out = jnp.einsum("ecf,efd->ecd", a, we2).reshape(El * C, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)
    slot_vals = out[dest]                                       # [T*k, d]
    w = topv.reshape(-1)[order].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[src_tok].add(
        jnp.where(keep[:, None], slot_vals * w[:, None], 0))
    return y, aux


def moe_ffn(cfg: ModelConfig, lp, x, *, out_scatter: bool = False,
            drop: bool = True):
    """x: [B, S, d] -> (y, aux). Uses shard_map EP on-mesh, local off-mesh.

    out_scatter (train/seq_sp path): the combining reduction over "model"
    is emitted as psum_scatter over the sequence dim instead of a full
    all-reduce — the residual stream is sequence-sharded anyway, so this
    halves the combine's ICI traffic and skips the re-shard.

    drop=False (every serving path: prefill, wave decode, paged decode):
    per-expert capacity is raised to the theoretical max T (top_k picks
    DISTINCT experts per token, so one expert can receive at most one
    slot per token) — no token is ever dropped, and a co-batched (or
    junk co-resident) token can never displace another request's
    expert slot.
    """
    b, s, d = x.shape
    mesh = get_mesh()
    m = cfg.moe
    if mesh is None or "model" not in mesh.axis_names:
        cap = None if drop else b * s
        y, aux = _local_moe(cfg, x.reshape(-1, d), lp["router"], lp["we1"],
                            lp["we2"], lp.get("we3"), 0, m.num_experts,
                            capacity=cap)
        return y.reshape(b, s, d), aux

    tp = mesh.shape["model"]
    El = m.num_experts // tp
    scatter = out_scatter and s % tp == 0
    batch_spec = logical_to_spec(mesh, ("batch", None, None))
    out_spec = logical_to_spec(mesh, ("batch", "seq_sp", None)) if scatter \
        else batch_spec
    fsdp_ax = ("pod", "data") if (fsdp_spans_pods() and
                                  "pod" in mesh.axis_names) else "data"

    def gather(wl, gather_axis, quant_axis):
        if m.int8_gather:
            return q8_all_gather(wl, fsdp_ax, gather_axis, quant_axis)
        return jax.lax.all_gather(wl, fsdp_ax, axis=gather_axis, tiled=True)

    def body(xl, router_l, we1_l, we2_l, we3_l):
        # ZeRO-3 per-layer gather of the FSDP ("data") weight dimension
        router_f = gather(router_l, 0, 1)
        we1_f = gather(we1_l, 1, 2)
        we2_f = gather(we2_l, 2, 1)
        we3_f = gather(we3_l, 1, 2) if cfg.act == "swiglu" else None
        midx = jax.lax.axis_index("model")
        xt = xl.reshape(-1, d)
        cap = None if drop else xt.shape[0]
        y, aux = _local_moe(cfg, xt, router_f, we1_f, we2_f, we3_f,
                            midx * El, m.num_experts, capacity=cap)
        y = y.reshape(xl.shape)
        if scatter:
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                     tiled=True)
        else:
            y = jax.lax.psum(y, "model")
        aux = jax.lax.psum(aux, "model") / tp
        return y, aux

    specs_in = (batch_spec, P(fsdp_ax, None), P("model", fsdp_ax, None),
                P("model", None, fsdp_ax),
                P("model", fsdp_ax, None) if cfg.act == "swiglu" else P())
    fn = shard_map(body, mesh=mesh, in_specs=specs_in,
                   out_specs=(out_spec, P()))
    we3 = lp.get("we3")
    if we3 is None:
        we3 = jnp.zeros((), x.dtype)
    y, aux = fn(x, lp["router"], lp["we1"], lp["we2"], we3)
    return y, aux


# ----------------------------------------------------------- blocks


def block(cfg: ModelConfig, lp, x, positions, *, seq_sp: bool,
          inference: bool = False):
    """One MoE transformer block. `inference` (serving prefill): expert
    capacity never drops tokens (see `moe_ffn(drop=False)`)."""
    h = cfg.num_heads
    sp = "seq_sp" if seq_sp else None
    res = x
    y = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = dense._qkv(cfg, lp, y, positions)
    ctx = L.attention(q, k, v, causal=True)
    ctx = ctx[:, :, :h, :]
    y = ctx.reshape(ctx.shape[0], ctx.shape[1], -1) @ lp["wo"]
    y = shard(y, "batch", sp, None)   # reduce-scatter, not all-reduce
    x = res + y
    x = shard(x, "batch", sp, None)
    res = x
    norm_name = "moe_norm" if cfg.moe.dense_residual else "mlp_norm"
    y = L.rmsnorm(x, lp[norm_name], cfg.norm_eps)
    ymoe, aux = moe_ffn(cfg, lp, y, out_scatter=seq_sp, drop=not inference)
    if cfg.moe.dense_residual:
        yd = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        ydense = L.mlp(yd, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
        ymoe = ymoe + shard(ydense, "batch", sp, None)
    x = res + ymoe
    return shard(x, "batch", sp, None), aux


def hidden_states(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    x, positions = dense.embed_inputs(cfg, params, batch)

    def body(carry, lp):
        xc, aux = carry
        xc, a = block(cfg, lp, xc, positions, seq_sp=seq_sp)
        return (xc, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def forward_logits(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    x, aux = hidden_states(cfg, params, batch, seq_sp=seq_sp)
    return dense.logits_from_hidden(cfg, params, x), aux


# ----------------------------------------------------------- serving

init_cache = dense.init_cache
init_page_pool = dense.init_page_pool
cache_specs = dense.cache_specs


def prefill(cfg: ModelConfig, params, batch):
    """Full-sequence forward; returns (last-position logits, kv cache).
    Inference capacity semantics: no expert ever drops a token (a
    co-batched prompt must not perturb another request's logits)."""
    x, positions = dense.embed_inputs(cfg, params, batch)

    def body(carry, lp):
        xc, aux = carry
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        _, k, v = dense._qkv(cfg, lp, y, positions)
        xc, a = block(cfg, lp, xc, positions, seq_sp=False, inference=True)
        return (xc, aux + a), (k, v)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, _), (k, v) = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                  params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x[:, -1:, :])[:, 0]
    return logits, {"k": k, "v": v}


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], token, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    zero = jnp.int32(0)

    def body(carry, inp):
        xc, ck_all, cv_all = carry
        lp, idx = inp
        h = cfg.num_heads
        b = xc.shape[0]
        res = xc
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        positions = jnp.full((b, 1), pos, jnp.int32)
        q, k, v = dense._qkv(cfg, lp, y, positions)
        # in-place carry update (see dense.block_decode)
        ck_all = jax.lax.dynamic_update_slice(
            ck_all, k[None].astype(ck_all.dtype), (idx, zero, pos, zero, zero))
        cv_all = jax.lax.dynamic_update_slice(
            cv_all, v[None].astype(cv_all.dtype), (idx, zero, pos, zero, zero))
        ck = jax.lax.dynamic_index_in_dim(ck_all, idx, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, idx, 0, keepdims=False)
        ctx = L.decode_attention(q, ck.astype(k.dtype), cv.astype(v.dtype),
                                 pos + 1)
        ctx = ctx[:, :, :h, :]
        xc = res + ctx.reshape(b, 1, -1) @ lp["wo"]
        res = xc
        norm_name = "moe_norm" if cfg.moe.dense_residual else "mlp_norm"
        y = L.rmsnorm(xc, lp[norm_name], cfg.norm_eps)
        ymoe, _ = moe_ffn(cfg, lp, y, drop=False)
        if cfg.moe.dense_residual:
            yd = L.rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
            ymoe = ymoe + L.mlp(yd, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
        return (res + ymoe, ck_all, cv_all), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, k, v), _ = jax.lax.scan(body, (x, cache["k"], cache["v"]),
                                (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x)[:, 0]
    return logits, {"k": k, "v": v}


# ------------------------------------------------- slot-paged serving


def decode_step_paged(cfg: ModelConfig, params, cache, token, pos, active,
                      table, *, page_size: int, ring_len: int = 0):
    """MoE mirror of `dense.decode_step_paged`: the attention/cache layer
    is the shared `dense.paged_attn_decode` (block-table scatter/gather,
    OOB-drop for inactive slots, ring/int8 variants); only the FFN
    differs. Expert routing is per token, so the slot dimension threads
    straight through dispatch/combine — with `drop=False` capacity a
    slot's expert outputs depend only on its own row, never on
    co-residents."""
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], token, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    b = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    table = jnp.asarray(table, jnp.int32)

    def body(carry, inp):
        xc, cd = carry
        lp, idx = inp
        h = cfg.num_heads
        res = xc
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        ctx, cd = dense.paged_attn_decode(cfg, lp, y, pos, table, active,
                                          cd, idx, page_size=page_size,
                                          ring_len=ring_len)
        ctx = ctx[:, :, :h, :]
        xc = res + ctx.reshape(b, 1, -1) @ lp["wo"]
        res = xc
        norm_name = "moe_norm" if cfg.moe.dense_residual else "mlp_norm"
        y = L.rmsnorm(xc, lp[norm_name], cfg.norm_eps)
        ymoe, _ = moe_ffn(cfg, lp, y, drop=False)
        if cfg.moe.dense_residual:
            yd = L.rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
            ymoe = ymoe + L.mlp(yd, lp["w1"], lp["w2"], lp.get("w3"),
                                cfg.act)
        return (res + ymoe, cd), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(body, (x, dict(cache)),
                                 (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x)[:, 0]
    return logits, cache


def prefill_chunk_paged(cfg: ModelConfig, params, cache, tokens, row,
                        offset, limit=None, *, page_size: int,
                        ring_len: int = 0, abs_len: int = 0):
    """MoE mirror of `dense.prefill_chunk_paged` (shared
    `dense.paged_attn_chunk` block-table attention, drop-free MoE FFN)."""
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], tokens, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    c = tokens.shape[1]
    positions = offset + jnp.arange(c)[None, :]
    limit = offset + c if limit is None else limit
    row = jnp.asarray(row, jnp.int32)

    def body(carry, inp):
        xc, cd = carry
        lp, idx = inp
        h = cfg.num_heads
        res = xc
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        ctx, cd = dense.paged_attn_chunk(cfg, lp, y, positions, row,
                                         offset, limit, cd, idx,
                                         page_size=page_size,
                                         ring_len=ring_len,
                                         abs_len=abs_len)
        ctx = ctx[:, :, :h, :]
        xc = res + ctx.reshape(1, c, -1) @ lp["wo"]
        res = xc
        norm_name = "moe_norm" if cfg.moe.dense_residual else "mlp_norm"
        y = L.rmsnorm(xc, lp[norm_name], cfg.norm_eps)
        ymoe, _ = moe_ffn(cfg, lp, y, drop=False)
        if cfg.moe.dense_residual:
            yd = L.rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
            ymoe = ymoe + L.mlp(yd, lp["w1"], lp["w2"], lp.get("w3"),
                                cfg.act)
        return (res + ymoe, cd), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(body, (x, dict(cache)),
                                 (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dense.logits_from_hidden(cfg, params, x)
    return logits, cache
