"""Dense decoder LM family: qwen2-72b, mistral-large-123b, nemotron-4-15b,
h2o-danube-1.8b (SWA), qwen2-vl-2b (M-RoPE + patch stub), gte-small
(bidirectional encoder), qwen2.5-0.5b.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models.common import ParamDef, attn_defs, embed_defs, mlp_defs

N_IMG = 256          # stubbed visual tokens (dynamic resolution fixed here)
IMG_GRID = 16        # 16x16 patch grid for M-RoPE spatial ids


# ------------------------------------------------------------- params


def defs(cfg: ModelConfig) -> dict:
    Ln = cfg.num_layers
    d = {"layers": {**attn_defs(cfg, Ln), **mlp_defs(cfg, Ln, cfg.d_ff)}}
    d.update(embed_defs(cfg))
    return d


# ------------------------------------------------------------- embedding


def embed_inputs(cfg: ModelConfig, params, batch):
    """Return (x [B,S,d], positions) handling modality stubs."""
    tokens = batch["tokens"]
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    if cfg.modality == "vision":
        patches = batch["patches"]                       # [B, N_IMG, d]
        txt = jnp.take(params["tok_embed"], tokens[:, N_IMG:], axis=0)
        img = patches.astype(txt.dtype) @ params["patch_proj"]
        x = jnp.concatenate([img, txt], axis=1) * emb_scale
        positions = mrope_positions(tokens.shape[1])[None]  # [1,S,3]
        positions = jnp.broadcast_to(positions, (x.shape[0],) + positions.shape[1:])
    else:
        x = jnp.take(params["tok_embed"], tokens, axis=0) * emb_scale
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                                     tokens.shape)
    return x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32), positions


def mrope_positions(S: int, offset: int = 0):
    """Qwen2-VL M-RoPE ids [S,3]: patches get (0,h,w) on a grid; text
    continues at max(grid) + j on all three streams."""
    idx = jnp.arange(S)
    is_img = idx < N_IMG
    t = jnp.where(is_img, 0, IMG_GRID + idx - N_IMG)
    h = jnp.where(is_img, idx // IMG_GRID, IMG_GRID + idx - N_IMG)
    w = jnp.where(is_img, idx % IMG_GRID, IMG_GRID + idx - N_IMG)
    return jnp.stack([t + offset, h + offset, w + offset], axis=-1)


def _rope(cfg: ModelConfig, x, positions):
    if cfg.rope_type == "mrope":
        return L.apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    if cfg.rope_type == "rope":
        return L.apply_rope(x, positions, cfg.rope_theta)
    return x


# ------------------------------------------------------------- blocks


def _qkv(cfg: ModelConfig, lp, x, positions):
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    tp = L.tp_degree()
    q, _ = L.pad_heads(q, tp)
    k = L.expand_kv(k, tp)
    v = L.expand_kv(v, tp)
    q = shard(q, "batch", None, "tp", None)
    k = shard(k, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)
    return q, k, v


def block(cfg: ModelConfig, lp, x, positions, *, seq_sp: bool,
          fake_quant_kv: bool = False):
    """One transformer block (training / prefill full-sequence path).

    `fake_quant_kv` (serving prefill of int8-KV configs): attention reads
    `dequantize_kv(quantize_kv(k))` instead of raw k/v — exactly the
    values every later decode step reads back from the int8 cache, so
    wave prefill and chunked paged prefill see bit-identical KV and the
    wave/continuous greedy-parity contract extends to `kv_quant` configs.
    Training never sets it."""
    h = cfg.num_heads
    res = x
    y = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, y, positions)
    if fake_quant_kv and cfg.kv_quant:
        k = L.dequantize_kv(*L.quantize_kv(k), k.dtype)
        v = L.dequantize_kv(*L.quantize_kv(v), v.dtype)
    ctx = L.attention(q, k, v, causal=cfg.causal, window=cfg.sliding_window)
    ctx = ctx[:, :, :h, :]                           # drop padded heads
    y = ctx.reshape(ctx.shape[0], ctx.shape[1], -1) @ lp["wo"]
    # constrain the TP-contracted projections seq-sharded *pre-residual* so
    # SPMD lowers their reductions as reduce-scatter, not all-reduce
    y = shard(y, "batch", "seq_sp" if seq_sp else None, None)
    x = res + y
    x = shard(x, "batch", "seq_sp" if seq_sp else None, None)
    res = x
    y = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    y = L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
    y = shard(y, "batch", "seq_sp" if seq_sp else None, None)
    x = res + y
    return shard(x, "batch", "seq_sp" if seq_sp else None, None)


def block_decode(cfg: ModelConfig, lp, x, pos, cache, idx,
                 window_cache: bool):
    """One block for a single decode position.

    cache: dict of FULL stacked arrays [L, B, Sc, G, dh], updated
    *in place* at layer `idx` (scan-carry form). Writing only the new
    token's slice and then slicing the layer keeps per-step cache traffic
    at ~1x the layer cache instead of the 4-6x that scan-ys collection
    costs (see EXPERIMENTS.md §Perf, hillclimb 1).
    """
    h = cfg.num_heads
    b = x.shape[0]
    res = x
    y = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    if cfg.rope_type == "mrope":
        positions = mrope_positions_decode(pos, b)
    else:
        positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(cfg, lp, y, positions)
    cache = dict(cache)
    sc = cache["k"].shape[2]
    slot = pos % sc if window_cache else pos
    zero = jnp.int32(0)

    def put(name, val):
        pos5 = (idx, zero, slot, zero, zero)[: val.ndim + 1]
        cache[name] = jax.lax.dynamic_update_slice(
            cache[name], val[None].astype(cache[name].dtype), pos5)

    def layer(name):
        return jax.lax.dynamic_index_in_dim(cache[name], idx, 0,
                                            keepdims=False)

    if cfg.kv_quant:
        kq, ks = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
        put("k", kq)
        put("k_s", ks)
        put("v", vq)
        put("v_s", vs)
        ctx = L.decode_attention_q8(
            q, layer("k"), layer("k_s"), layer("v"), layer("v_s"), pos + 1,
            window=cfg.sliding_window, ring=window_cache)
    else:
        put("k", k)
        put("v", v)
        ctx = L.decode_attention(
            q, layer("k").astype(k.dtype), layer("v").astype(v.dtype),
            pos + 1, window=cfg.sliding_window, ring=window_cache)
    ctx = ctx[:, :, :h, :]
    y = ctx.reshape(b, 1, -1) @ lp["wo"]
    x = res + y
    res = x
    y = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    y = L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
    return res + y, cache


def _cache_layer(c: dict, name: str, idx):
    return jax.lax.dynamic_index_in_dim(c[name], idx, 0, keepdims=False)


def paged_attn_decode(cfg: ModelConfig, lp, y, pos, slot, bidx, c, idx):
    """One layer of slot-paged decode attention, shared by the dense and
    moe families (moe.decode_step_paged reuses it verbatim; only the FFN
    differs between the two paged decode bodies).

    y [B,1,d] (already normed); pos [B] absolute per-slot positions; slot
    [B] per-slot WRITE CURSORS (`pos % sc` for sliding-window ring pages,
    `pos` otherwise; the out-of-bounds sentinel `sc` for inactive slots —
    their scatters drop); c: dict of full stacked cache arrays
    [L, slots, sc, G, dh] (+ [L, slots, sc, G] scales when `kv_quant`).
    Returns (ctx [B,1,Hp,dh], updated c). int8 configs quantize this
    step's k/v with per-slot per-head scales and attend through
    `decode_attention_q8`; ring caches mask all filled slots valid
    (`min(kv_len, sc)` — position order inside the ring is irrelevant to
    decode because RoPE is already baked into the stored keys."""
    q, k, v = _qkv(cfg, lp, y, pos[:, None])
    ring = cfg.sliding_window is not None
    if cfg.kv_quant:
        kq, ks = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
        c["k"] = c["k"].at[idx, bidx, slot].set(kq[:, 0], mode="drop")
        c["k_s"] = c["k_s"].at[idx, bidx, slot].set(ks[:, 0], mode="drop")
        c["v"] = c["v"].at[idx, bidx, slot].set(vq[:, 0], mode="drop")
        c["v_s"] = c["v_s"].at[idx, bidx, slot].set(vs[:, 0], mode="drop")
        ctx = L.decode_attention_q8(
            q, _cache_layer(c, "k", idx), _cache_layer(c, "k_s", idx),
            _cache_layer(c, "v", idx), _cache_layer(c, "v_s", idx),
            pos + 1, ring=ring)
    else:
        c["k"] = c["k"].at[idx, bidx, slot].set(
            k[:, 0].astype(c["k"].dtype), mode="drop")
        c["v"] = c["v"].at[idx, bidx, slot].set(
            v[:, 0].astype(c["v"].dtype), mode="drop")
        ctx = L.decode_attention(
            q, _cache_layer(c, "k", idx).astype(k.dtype),
            _cache_layer(c, "v", idx).astype(v.dtype), pos + 1, ring=ring)
    return ctx, c


def paged_attn_chunk(cfg: ModelConfig, lp, y, positions, slot, offset,
                     limit, c, idx, page_len: int):
    """One layer of chunked paged prefill attention (dense + moe shared).

    y [1,C,d] (already normed); slot/offset/limit traced scalars (`limit`
    = offset + the chunk's REAL token count, pre-padding). Non-ring pages:
    write the chunk at [offset, offset+C) and attend the slot's page
    prefix (dequantized from int8 when `kv_quant`). Ring pages
    (sliding-window with sc < page_len): the slot's ring is first
    re-materialized into ABSOLUTE position order (ring slot j holds
    position `offset-1-((offset-1-j) % sc)`), the chunk is appended at
    its absolute offset, and attention runs over that [page_len] buffer
    with the same causal/window masks the wave prefill uses — identical
    index placement is what keeps greedy parity bit-exact. Only the real
    tokens are then scattered into the ring at cursors `p % sc`: the
    padded tail of a final ragged chunk must NOT evict positions still
    inside other queries' windows. Returns (ctx [1,C,Hp,dh], c)."""
    csz = y.shape[1]
    q, k, v = _qkv(cfg, lp, y, positions)
    sc = c["k"].shape[2]
    ring = cfg.sliding_window is not None and sc < page_len
    zero = jnp.int32(0)
    if cfg.kv_quant:
        kq, ks = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
    if ring:
        # 1. history (pre-chunk ring contents) in absolute position order
        j = jnp.arange(sc)
        p_hist = offset - 1 - ((offset - 1 - j) % sc)
        hist_dst = jnp.where(p_hist >= 0, p_hist, page_len)  # <0 -> drop
        if cfg.kv_quant:
            kslot = L.dequantize_kv(
                jax.lax.dynamic_index_in_dim(
                    _cache_layer(c, "k", idx), slot, 0, keepdims=False),
                jax.lax.dynamic_index_in_dim(
                    _cache_layer(c, "k_s", idx), slot, 0, keepdims=False),
                k.dtype)
            vslot = L.dequantize_kv(
                jax.lax.dynamic_index_in_dim(
                    _cache_layer(c, "v", idx), slot, 0, keepdims=False),
                jax.lax.dynamic_index_in_dim(
                    _cache_layer(c, "v_s", idx), slot, 0, keepdims=False),
                v.dtype)
            k_new = L.dequantize_kv(kq, ks, k.dtype)[0]
            v_new = L.dequantize_kv(vq, vs, v.dtype)[0]
        else:
            kslot = jax.lax.dynamic_index_in_dim(
                _cache_layer(c, "k", idx), slot, 0,
                keepdims=False).astype(k.dtype)
            vslot = jax.lax.dynamic_index_in_dim(
                _cache_layer(c, "v", idx), slot, 0,
                keepdims=False).astype(v.dtype)
            k_new, v_new = k[0], v[0]
        g, dh = kslot.shape[1], kslot.shape[2]
        kfull = jnp.zeros((page_len, g, dh), k_new.dtype
                          ).at[hist_dst].set(kslot, mode="drop")
        vfull = jnp.zeros((page_len, g, dh), v_new.dtype
                          ).at[hist_dst].set(vslot, mode="drop")
        # 2. append the chunk at its absolute positions and attend
        kfull = jax.lax.dynamic_update_slice(kfull, k_new, (offset, zero,
                                                            zero))
        vfull = jax.lax.dynamic_update_slice(vfull, v_new, (offset, zero,
                                                            zero))
        ctx = L.attention(q, kfull[None], vfull[None], causal=True,
                          window=cfg.sliding_window, q_offset=offset,
                          kv_len=offset + csz)
        # 3. ring-write only the REAL tokens at their per-position cursors
        p_new = offset + jnp.arange(csz)
        dst = jnp.where(p_new < limit, p_new % sc, sc)   # pad tail -> drop
        if cfg.kv_quant:
            c["k"] = c["k"].at[idx, slot, dst].set(kq[0], mode="drop")
            c["k_s"] = c["k_s"].at[idx, slot, dst].set(ks[0], mode="drop")
            c["v"] = c["v"].at[idx, slot, dst].set(vq[0], mode="drop")
            c["v_s"] = c["v_s"].at[idx, slot, dst].set(vs[0], mode="drop")
        else:
            c["k"] = c["k"].at[idx, slot, dst].set(
                k[0].astype(c["k"].dtype), mode="drop")
            c["v"] = c["v"].at[idx, slot, dst].set(
                v[0].astype(c["v"].dtype), mode="drop")
        return ctx, c
    if cfg.kv_quant:
        c["k"] = jax.lax.dynamic_update_slice(
            c["k"], kq[None], (idx, slot, offset, zero, zero))
        c["k_s"] = jax.lax.dynamic_update_slice(
            c["k_s"], ks[None], (idx, slot, offset, zero))
        c["v"] = jax.lax.dynamic_update_slice(
            c["v"], vq[None], (idx, slot, offset, zero, zero))
        c["v_s"] = jax.lax.dynamic_update_slice(
            c["v_s"], vs[None], (idx, slot, offset, zero))
        kslot = L.dequantize_kv(
            jax.lax.dynamic_slice_in_dim(
                _cache_layer(c, "k", idx), slot, 1, axis=0),
            jax.lax.dynamic_slice_in_dim(
                _cache_layer(c, "k_s", idx), slot, 1, axis=0), k.dtype)
        vslot = L.dequantize_kv(
            jax.lax.dynamic_slice_in_dim(
                _cache_layer(c, "v", idx), slot, 1, axis=0),
            jax.lax.dynamic_slice_in_dim(
                _cache_layer(c, "v_s", idx), slot, 1, axis=0), v.dtype)
    else:
        c["k"] = jax.lax.dynamic_update_slice(
            c["k"], k[None].astype(c["k"].dtype),
            (idx, slot, offset, zero, zero))
        c["v"] = jax.lax.dynamic_update_slice(
            c["v"], v[None].astype(c["v"].dtype),
            (idx, slot, offset, zero, zero))
        kslot = jax.lax.dynamic_slice_in_dim(
            _cache_layer(c, "k", idx), slot, 1, axis=0).astype(k.dtype)
        vslot = jax.lax.dynamic_slice_in_dim(
            _cache_layer(c, "v", idx), slot, 1, axis=0).astype(v.dtype)
    ctx = L.attention(q, kslot, vslot, causal=True,
                      window=cfg.sliding_window, q_offset=offset,
                      kv_len=offset + csz)
    return ctx, c


def paged_cursor(cfg: ModelConfig, sc: int, pos, active):
    """Per-slot write cursor for one paged decode step: `pos % sc` on a
    sliding-window ring page (position p lives in ring slot p % sc —
    the invariant prefill rolls, chunk-prefill scatters and decode all
    share), plain `pos` otherwise; the OOB sentinel `sc` for inactive
    slots so their scatters drop instead of clobbering a page a
    co-resident is still filling."""
    cursor = pos % sc if cfg.sliding_window is not None else pos
    return jnp.where(active, cursor, sc)


def decode_step_paged(cfg: ModelConfig, params, cache, token, pos, active):
    """One decode step over a slot-paged cache (continuous batching).

    token [B,1] int32; pos [B] int32 — the per-slot write position (== the
    slot's current kv length); active [B] bool. Every slot advances one
    position at ITS OWN cursor (see `paged_cursor`): k/v land at
    cache[:, b, cursor[b]] via a scatter, attention masks each row to its
    own kv_len = pos[b]+1 (clamped to the ring size for sliding-window
    pages, where every filled slot is valid). Inactive slots (free, or
    mid-prefill-admission) scatter out of bounds with mode="drop" so they
    cannot clobber a page another request is filling; their logits rows
    are garbage the engine discards. Covers plain, sliding-window (ring)
    and int8-KV dense configs.
    """
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], token, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    b = token.shape[0]
    sc = cache["k"].shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    slot = paged_cursor(cfg, sc, pos, active)
    bidx = jnp.arange(b)

    def body(carry, inp):
        xc, cd = carry
        lp, idx = inp
        h = cfg.num_heads
        res = xc
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        ctx, cd = paged_attn_decode(cfg, lp, y, pos, slot, bidx, cd, idx)
        ctx = ctx[:, :, :h, :]
        xc = res + ctx.reshape(b, 1, -1) @ lp["wo"]
        res = xc
        y = L.rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        y = L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
        return (res + y, cd), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(body, (x, dict(cache)),
                                 (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)[:, 0]
    return logits, cache


def prefill_chunk_paged(cfg: ModelConfig, params, cache, tokens, slot,
                        offset, limit=None, *, page_len: int = 0):
    """One prefill chunk of an admitted prompt, written into one slot of
    the paged cache while the other slots keep decoding between chunks.

    tokens [1, C] int32; slot / offset / limit: traced scalars (`limit` =
    offset + the chunk's real token count; defaults to offset + C).
    `page_len`: the engine's static page length (0 -> the cache's own
    seq dim; ring reconstruction needs the true page size because a
    sliding-window cache is allocated at only `window` positions). The
    chunk's k/v land at cache[:, slot, offset:offset+C] (ring cursors
    `p % sc` for sliding-window configs, int8+scales for `kv_quant`
    configs); its queries attend the page prefix [0, offset+C) causally
    (L.attention's q_offset/kv_len path), so a prompt longer than C is
    prefilled in several calls that all compile to the same [1, C] shape.
    On non-ring pages, rows past the prompt's true end (final ragged
    chunk padded up to C) write junk that is either overwritten by the
    next write at that position or masked by kv_len before anything
    attends it; ring pages drop those writes via `limit` (see
    `paged_attn_chunk`). Returns (chunk logits [1, C, V], cache).
    """
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], tokens, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    c = tokens.shape[1]
    positions = offset + jnp.arange(c)[None, :]
    limit = offset + c if limit is None else limit
    plen = page_len or cache["k"].shape[2]

    def body(carry, inp):
        xc, cd = carry
        lp, idx = inp
        h = cfg.num_heads
        res = xc
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        ctx, cd = paged_attn_chunk(cfg, lp, y, positions, slot, offset,
                                   limit, cd, idx, plen)
        ctx = ctx[:, :, :h, :]
        xc = res + ctx.reshape(1, c, -1) @ lp["wo"]
        res = xc
        y = L.rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        y = L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
        return (res + y, cd), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(body, (x, dict(cache)),
                                 (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)
    return logits, cache


def mrope_positions_decode(pos, b):
    p = IMG_GRID + pos - N_IMG
    return jnp.broadcast_to(jnp.stack([p, p, p])[None, None, :], (b, 1, 3))


# ------------------------------------------------------------- forward


def _scan_blocks(cfg: ModelConfig, params, x, positions, *, seq_sp: bool,
                 collect_kv: bool = False, fake_quant_kv: bool = False):
    stacked = params["layers"]

    def body(xc, lp):
        if collect_kv:
            # recompute k/v for the cache (prefill)
            y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
            _, k, v = _qkv(cfg, lp, y, positions)
            out = block(cfg, lp, xc, positions, seq_sp=seq_sp,
                        fake_quant_kv=fake_quant_kv)
            return out, (k, v)
        return block(cfg, lp, xc, positions, seq_sp=seq_sp), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, kv = jax.lax.scan(body, x, stacked)
    return x, kv


def hidden_states(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    x, positions = embed_inputs(cfg, params, batch)
    x = shard(x, "batch", "seq_sp" if seq_sp else None, None)
    x, _ = _scan_blocks(cfg, params, x, positions, seq_sp=seq_sp)
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def logits_from_hidden(cfg: ModelConfig, params, x):
    head = params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    return shard(logits, "batch", None, "tp")


def forward_logits(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    return logits_from_hidden(cfg, params, hidden_states(
        cfg, params, batch, seq_sp=seq_sp))


def encode(cfg: ModelConfig, params, batch):
    """Mean-pooled, L2-normalised sentence embeddings (gte-small path)."""
    x = hidden_states(cfg, params, batch)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(batch["tokens"].shape, x.dtype)
    mask = mask.astype(x.dtype)[..., None]
    pooled = jnp.sum(x * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1), 1)
    pooled = pooled.astype(jnp.float32)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)


# ------------------------------------------------------------- serving


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def kv_expanded_heads(cfg: ModelConfig) -> int:
    tp = L.tp_degree()
    return max(cfg.num_kv_heads, tp)


def init_cache(cfg: ModelConfig, b: int, seq_len: int, dtype=jnp.bfloat16):
    g, hd = kv_expanded_heads(cfg), cfg.resolved_head_dim
    sc = cache_len(cfg, seq_len)
    shape = (cfg.num_layers, b, sc, g, hd)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(shape[:-1], jnp.float32),
                "v_s": jnp.zeros(shape[:-1], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg: ModelConfig):
    axes = (None, "batch", None, "tp", None)
    if cfg.kv_quant:
        return {"k": axes, "v": axes, "k_s": axes[:-1], "v_s": axes[:-1]}
    return {"k": axes, "v": axes}


def prefill(cfg: ModelConfig, params, batch):
    """Full-sequence forward; returns (last-position logits, kv cache).

    For `kv_quant` configs the forward attends fake-quantized k/v (see
    `block`): the int8 cache is the single source of truth, so prefill
    must read the same values decode will — that is what makes the
    wave and chunked-paged prefill paths token-identical."""
    x, positions = embed_inputs(cfg, params, batch)
    x = shard(x, "batch", None, None)
    x, (k, v) = _scan_blocks(cfg, params, x, positions, seq_sp=False,
                             collect_kv=True, fake_quant_kv=True)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, -1:, :])[:, 0]
    S = k.shape[2]
    sc = cache_len(cfg, S)
    if sc != S:  # SWA ring layout: position p lives in slot p % sc
        k = jnp.roll(k[:, :, S - sc:], shift=S % sc, axis=2)
        v = jnp.roll(v[:, :, S - sc:], shift=S % sc, axis=2)
    if cfg.kv_quant:
        kq, ks = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
        return logits, {"k": kq, "k_s": ks, "v": vq, "v_s": vs}
    return logits, {"k": k, "v": v}


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """token [B,1] int32; pos scalar int32 (position being written).

    The cache rides in the scan CARRY (in-place per-layer updates), not in
    xs/ys — collecting updated caches as scan outputs double-buffers the
    whole cache and (on some backends) round-trips it through f32.
    """
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], token, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    ring = cache["k"].shape[2] != 0 and cfg.sliding_window is not None

    def body(carry, inp):
        xc, c = carry
        lp, idx = inp
        xc, c = block_decode(cfg, lp, xc, pos, c, idx, ring)
        return (xc, c), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(body, (x, dict(cache)),
                                 (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)[:, 0]
    return logits, cache
