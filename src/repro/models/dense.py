"""Dense decoder LM family: qwen2-72b, mistral-large-123b, nemotron-4-15b,
h2o-danube-1.8b (SWA), qwen2-vl-2b (M-RoPE + patch stub), gte-small
(bidirectional encoder), qwen2.5-0.5b.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models.common import ParamDef, attn_defs, embed_defs, mlp_defs

N_IMG = 256          # stubbed visual tokens (dynamic resolution fixed here)
IMG_GRID = 16        # 16x16 patch grid for M-RoPE spatial ids


# ------------------------------------------------------------- params


def defs(cfg: ModelConfig) -> dict:
    Ln = cfg.num_layers
    d = {"layers": {**attn_defs(cfg, Ln), **mlp_defs(cfg, Ln, cfg.d_ff)}}
    d.update(embed_defs(cfg))
    return d


# ------------------------------------------------------------- embedding


def embed_inputs(cfg: ModelConfig, params, batch):
    """Return (x [B,S,d], positions) handling modality stubs."""
    tokens = batch["tokens"]
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    if cfg.modality == "vision":
        patches = batch["patches"]                       # [B, N_IMG, d]
        txt = jnp.take(params["tok_embed"], tokens[:, N_IMG:], axis=0)
        img = patches.astype(txt.dtype) @ params["patch_proj"]
        x = jnp.concatenate([img, txt], axis=1) * emb_scale
        positions = mrope_positions(tokens.shape[1])[None]  # [1,S,3]
        positions = jnp.broadcast_to(positions, (x.shape[0],) + positions.shape[1:])
    else:
        x = jnp.take(params["tok_embed"], tokens, axis=0) * emb_scale
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                                     tokens.shape)
    return x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32), positions


def mrope_positions(S: int, offset: int = 0):
    """Qwen2-VL M-RoPE ids [S,3]: patches get (0,h,w) on a grid; text
    continues at max(grid) + j on all three streams."""
    idx = jnp.arange(S)
    is_img = idx < N_IMG
    t = jnp.where(is_img, 0, IMG_GRID + idx - N_IMG)
    h = jnp.where(is_img, idx // IMG_GRID, IMG_GRID + idx - N_IMG)
    w = jnp.where(is_img, idx % IMG_GRID, IMG_GRID + idx - N_IMG)
    return jnp.stack([t + offset, h + offset, w + offset], axis=-1)


def _rope(cfg: ModelConfig, x, positions):
    if cfg.rope_type == "mrope":
        return L.apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    if cfg.rope_type == "rope":
        return L.apply_rope(x, positions, cfg.rope_theta)
    return x


# ------------------------------------------------------------- blocks


def _qkv(cfg: ModelConfig, lp, x, positions):
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    tp = L.tp_degree()
    q, _ = L.pad_heads(q, tp)
    k = L.expand_kv(k, tp)
    v = L.expand_kv(v, tp)
    q = shard(q, "batch", None, "tp", None)
    k = shard(k, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)
    return q, k, v


def block(cfg: ModelConfig, lp, x, positions, *, seq_sp: bool,
          fake_quant_kv: bool = False):
    """One transformer block (training / prefill full-sequence path).

    `fake_quant_kv` (serving prefill of int8-KV configs): attention reads
    `dequantize_kv(quantize_kv(k))` instead of raw k/v — exactly the
    values every later decode step reads back from the int8 cache, so
    wave prefill and chunked paged prefill see bit-identical KV and the
    wave/continuous greedy-parity contract extends to `kv_quant` configs.
    Training never sets it."""
    h = cfg.num_heads
    res = x
    y = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, y, positions)
    if fake_quant_kv and cfg.kv_quant:
        k = L.dequantize_kv(*L.quantize_kv(k), k.dtype)
        v = L.dequantize_kv(*L.quantize_kv(v), v.dtype)
    ctx = L.attention(q, k, v, causal=cfg.causal, window=cfg.sliding_window)
    ctx = ctx[:, :, :h, :]                           # drop padded heads
    y = ctx.reshape(ctx.shape[0], ctx.shape[1], -1) @ lp["wo"]
    # constrain the TP-contracted projections seq-sharded *pre-residual* so
    # SPMD lowers their reductions as reduce-scatter, not all-reduce
    y = shard(y, "batch", "seq_sp" if seq_sp else None, None)
    x = res + y
    x = shard(x, "batch", "seq_sp" if seq_sp else None, None)
    res = x
    y = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    y = L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
    y = shard(y, "batch", "seq_sp" if seq_sp else None, None)
    x = res + y
    return shard(x, "batch", "seq_sp" if seq_sp else None, None)


def block_decode(cfg: ModelConfig, lp, x, pos, cache, idx,
                 window_cache: bool):
    """One block for a single decode position.

    cache: dict of FULL stacked arrays [L, B, Sc, G, dh], updated
    *in place* at layer `idx` (scan-carry form). Writing only the new
    token's slice and then slicing the layer keeps per-step cache traffic
    at ~1x the layer cache instead of the 4-6x that scan-ys collection
    costs (see EXPERIMENTS.md §Perf, hillclimb 1).
    """
    h = cfg.num_heads
    b = x.shape[0]
    res = x
    y = L.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    if cfg.rope_type == "mrope":
        positions = mrope_positions_decode(pos, b)
    else:
        positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(cfg, lp, y, positions)
    cache = dict(cache)
    sc = cache["k"].shape[2]
    slot = pos % sc if window_cache else pos
    zero = jnp.int32(0)

    def put(name, val):
        pos5 = (idx, zero, slot, zero, zero)[: val.ndim + 1]
        cache[name] = jax.lax.dynamic_update_slice(
            cache[name], val[None].astype(cache[name].dtype), pos5)

    def layer(name):
        return jax.lax.dynamic_index_in_dim(cache[name], idx, 0,
                                            keepdims=False)

    if cfg.kv_quant:
        kq, ks = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
        put("k", kq)
        put("k_s", ks)
        put("v", vq)
        put("v_s", vs)
        ctx = L.decode_attention_q8(
            q, layer("k"), layer("k_s"), layer("v"), layer("v_s"), pos + 1,
            window=cfg.sliding_window, ring=window_cache)
    else:
        put("k", k)
        put("v", v)
        ctx = L.decode_attention(
            q, layer("k").astype(k.dtype), layer("v").astype(v.dtype),
            pos + 1, window=cfg.sliding_window, ring=window_cache)
    ctx = ctx[:, :, :h, :]
    y = ctx.reshape(b, 1, -1) @ lp["wo"]
    x = res + y
    res = x
    y = L.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    y = L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
    return res + y, cache


def _cache_layer(c: dict, name: str, idx):
    return jax.lax.dynamic_index_in_dim(c[name], idx, 0, keepdims=False)


def _pool_gather(pool, table, ps: int):
    """Gather a logical K/V buffer out of a page pool through a table.

    pool [P, ps, ...] (one layer of the pool); table [..., W] int32 page
    ids — entry w backs logical positions [w*ps, (w+1)*ps). Returns the
    logically contiguous [..., W*ps, ...] buffer: flat element j comes
    from pool page table[j // ps] at in-page offset j % ps. Unmapped
    tail entries (callers fill them with page 0) produce junk the caller
    masks via kv_len — the gathered VALID prefix is element-for-element
    identical to what a slot-contiguous cache would hold, which is what
    keeps paged decode bit-exact against the wave path."""
    P = pool.shape[0]
    flat = pool.reshape((P * ps,) + pool.shape[2:])
    W = table.shape[-1]
    j = jnp.arange(W * ps)
    idx = table[..., j // ps] * ps + (j % ps)
    return jnp.take(flat, idx, axis=0)


def paged_attn_decode(cfg: ModelConfig, lp, y, pos, table, active, c, idx,
                      *, page_size: int, ring_len: int):
    """One layer of block-table paged decode attention, shared by the
    dense and moe families (moe.decode_step_paged reuses it verbatim;
    only the FFN differs between the two paged decode bodies).

    y [B,1,d] (already normed); pos [B] absolute per-slot positions;
    table [B, W] int32 page ids (this slot's mapped pages, in logical
    order; unmapped tail entries hold page 0 and are masked by kv_len);
    active [B] bool; c: page-pool cache dict [L, P, ps, G, dh]
    (+ [L, P, ps, G] scales when `kv_quant`). `ring_len` > 0 marks a
    sliding-window ring: logical position p lives at ring cursor
    p % ring_len, and the per-row mask length is min(pos+1, ring_len)
    (every filled ring slot is valid — position order inside the ring is
    irrelevant because RoPE is baked into the stored keys). The new k/v
    scatter resolves (page, offset) through the table; inactive slots
    scatter to the out-of-bounds page sentinel and drop. Shared
    (refcounted) prefix pages are never written here: all decode writes
    land at positions >= the request's prompt length, which by the
    pager's COW contract sit in slot-private pages."""
    ps = page_size
    q, k, v = _qkv(cfg, lp, y, pos[:, None])
    npages = c["k"].shape[1]
    lw = pos % ring_len if ring_len else pos
    pg = jnp.take_along_axis(table, (lw // ps)[:, None], axis=1)[:, 0]
    pg = jnp.where(active, pg, npages)           # OOB sentinel -> drop
    off = lw % ps
    lens = jnp.minimum(pos + 1, ring_len) if ring_len else pos + 1
    if cfg.kv_quant:
        kq, ks = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
        c["k"] = c["k"].at[idx, pg, off].set(kq[:, 0], mode="drop")
        c["k_s"] = c["k_s"].at[idx, pg, off].set(ks[:, 0], mode="drop")
        c["v"] = c["v"].at[idx, pg, off].set(vq[:, 0], mode="drop")
        c["v_s"] = c["v_s"].at[idx, pg, off].set(vs[:, 0], mode="drop")
        ctx = L.decode_attention_q8(
            q, _pool_gather(_cache_layer(c, "k", idx), table, ps),
            _pool_gather(_cache_layer(c, "k_s", idx), table, ps),
            _pool_gather(_cache_layer(c, "v", idx), table, ps),
            _pool_gather(_cache_layer(c, "v_s", idx), table, ps), lens)
    else:
        c["k"] = c["k"].at[idx, pg, off].set(
            k[:, 0].astype(c["k"].dtype), mode="drop")
        c["v"] = c["v"].at[idx, pg, off].set(
            v[:, 0].astype(c["v"].dtype), mode="drop")
        ctx = L.decode_attention(
            q, _pool_gather(_cache_layer(c, "k", idx), table,
                            ps).astype(k.dtype),
            _pool_gather(_cache_layer(c, "v", idx), table,
                         ps).astype(v.dtype), lens)
    return ctx, c


def paged_attn_chunk(cfg: ModelConfig, lp, y, positions, row, offset,
                     limit, c, idx, *, page_size: int, ring_len: int,
                     abs_len: int):
    """One layer of chunked block-table prefill attention (dense + moe
    shared).

    y [1,C,d] (already normed); row [W] int32 — the admitting slot's
    page-table row; offset/limit traced scalars (`limit` = offset + the
    chunk's REAL token count, pre-padding; `offset` can start past 0
    when the pager matched a cached prefix and skipped its chunks).
    Non-ring: scatter the chunk at logical [offset, offset+C) through
    the table and attend the gathered [W*ps] logical buffer (dequantized
    from int8 when `kv_quant`) with the same q_offset/kv_len masks the
    slot-contiguous path used — positions past the valid prefix are
    masked to exact-zero probability, so the longer gathered buffer
    changes nothing bitwise. Ring (sliding-window, ring_len > 0): the
    ring is re-materialized into ABSOLUTE position order (ring cursor j
    holds position `offset-1-((offset-1-j) % ring_len)`) in an [abs_len]
    buffer, the chunk is appended at its absolute offset, attention runs
    with the wave prefill's causal/window masks, and only the REAL
    tokens scatter back at ring cursors `p % ring_len` — the padded tail
    of a final ragged chunk must NOT evict positions still inside other
    queries' windows. Shared prefix pages are never written: every store
    lands at logical position >= offset >= the pager's matched length,
    which sits in slot-private (fresh or COW) pages. Returns
    (ctx [1,C,Hp,dh], c)."""
    ps = page_size
    csz = y.shape[1]
    q, k, v = _qkv(cfg, lp, y, positions)
    npages = c["k"].shape[1]
    W = row.shape[0]
    zero = jnp.int32(0)
    if cfg.kv_quant:
        kq, ks = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
    p_new = offset + jnp.arange(csz)
    if ring_len:
        lw = p_new % ring_len
        dst_pg = jnp.where(p_new < limit, row[lw // ps], npages)
        dst_off = lw % ps
        # 1. history (pre-chunk ring contents) in absolute position order
        j = jnp.arange(ring_len)
        p_hist = offset - 1 - ((offset - 1 - j) % ring_len)
        hist_dst = jnp.where(p_hist >= 0, p_hist, abs_len)   # <0 -> drop
        if cfg.kv_quant:
            kslot = L.dequantize_kv(
                _pool_gather(_cache_layer(c, "k", idx), row, ps)[:ring_len],
                _pool_gather(_cache_layer(c, "k_s", idx), row,
                             ps)[:ring_len], k.dtype)
            vslot = L.dequantize_kv(
                _pool_gather(_cache_layer(c, "v", idx), row, ps)[:ring_len],
                _pool_gather(_cache_layer(c, "v_s", idx), row,
                             ps)[:ring_len], v.dtype)
            k_new = L.dequantize_kv(kq, ks, k.dtype)[0]
            v_new = L.dequantize_kv(vq, vs, v.dtype)[0]
        else:
            kslot = _pool_gather(_cache_layer(c, "k", idx), row,
                                 ps)[:ring_len].astype(k.dtype)
            vslot = _pool_gather(_cache_layer(c, "v", idx), row,
                                 ps)[:ring_len].astype(v.dtype)
            k_new, v_new = k[0], v[0]
        g, dh = kslot.shape[1], kslot.shape[2]
        kfull = jnp.zeros((abs_len, g, dh), k_new.dtype
                          ).at[hist_dst].set(kslot, mode="drop")
        vfull = jnp.zeros((abs_len, g, dh), v_new.dtype
                          ).at[hist_dst].set(vslot, mode="drop")
        # 2. append the chunk at its absolute positions and attend
        kfull = jax.lax.dynamic_update_slice(kfull, k_new, (offset, zero,
                                                            zero))
        vfull = jax.lax.dynamic_update_slice(vfull, v_new, (offset, zero,
                                                            zero))
        ctx = L.attention(q, kfull[None], vfull[None], causal=True,
                          window=cfg.sliding_window, q_offset=offset,
                          kv_len=offset + csz)
        # 3. ring-write only the REAL tokens at their per-position cursors
        if cfg.kv_quant:
            c["k"] = c["k"].at[idx, dst_pg, dst_off].set(kq[0], mode="drop")
            c["k_s"] = c["k_s"].at[idx, dst_pg, dst_off].set(ks[0],
                                                             mode="drop")
            c["v"] = c["v"].at[idx, dst_pg, dst_off].set(vq[0], mode="drop")
            c["v_s"] = c["v_s"].at[idx, dst_pg, dst_off].set(vs[0],
                                                             mode="drop")
        else:
            c["k"] = c["k"].at[idx, dst_pg, dst_off].set(
                k[0].astype(c["k"].dtype), mode="drop")
            c["v"] = c["v"].at[idx, dst_pg, dst_off].set(
                v[0].astype(c["v"].dtype), mode="drop")
        return ctx, c
    # non-ring: positions past the mapped width scatter to the sentinel
    # (a final ragged chunk's pad tail can cross the last mapped page)
    dst_pg = jnp.where(p_new // ps < W,
                       row[jnp.minimum(p_new // ps, W - 1)], npages)
    dst_off = p_new % ps
    if cfg.kv_quant:
        c["k"] = c["k"].at[idx, dst_pg, dst_off].set(kq[0], mode="drop")
        c["k_s"] = c["k_s"].at[idx, dst_pg, dst_off].set(ks[0], mode="drop")
        c["v"] = c["v"].at[idx, dst_pg, dst_off].set(vq[0], mode="drop")
        c["v_s"] = c["v_s"].at[idx, dst_pg, dst_off].set(vs[0], mode="drop")
        kslot = L.dequantize_kv(
            _pool_gather(_cache_layer(c, "k", idx), row, ps),
            _pool_gather(_cache_layer(c, "k_s", idx), row, ps),
            k.dtype)[None]
        vslot = L.dequantize_kv(
            _pool_gather(_cache_layer(c, "v", idx), row, ps),
            _pool_gather(_cache_layer(c, "v_s", idx), row, ps),
            v.dtype)[None]
    else:
        c["k"] = c["k"].at[idx, dst_pg, dst_off].set(
            k[0].astype(c["k"].dtype), mode="drop")
        c["v"] = c["v"].at[idx, dst_pg, dst_off].set(
            v[0].astype(c["v"].dtype), mode="drop")
        kslot = _pool_gather(_cache_layer(c, "k", idx), row,
                             ps)[None].astype(k.dtype)
        vslot = _pool_gather(_cache_layer(c, "v", idx), row,
                             ps)[None].astype(v.dtype)
    ctx = L.attention(q, kslot, vslot, causal=True,
                      window=cfg.sliding_window, q_offset=offset,
                      kv_len=offset + csz)
    return ctx, c


def decode_step_paged(cfg: ModelConfig, params, cache, token, pos, active,
                      table, *, page_size: int, ring_len: int = 0):
    """One decode step over a block-table paged cache (continuous
    batching).

    token [B,1] int32; pos [B] int32 — the per-slot write position (== the
    slot's current kv length); active [B] bool; table [B, W] int32 page
    ids (each slot's mapped pages in logical order; unmapped tail entries
    hold page 0 and are masked). Every slot advances one position at ITS
    OWN cursor: k/v land at pool page table[b, cursor//ps] offset
    cursor%ps via a scatter, attention gathers the slot's logical buffer
    through its table and masks each row to its own kv_len = pos[b]+1
    (clamped to `ring_len` for sliding-window rings, where every filled
    cursor is valid). Inactive slots (free, or mid-prefill-admission)
    scatter to the out-of-bounds page sentinel with mode="drop" so they
    cannot clobber a page another request is filling — or a SHARED prefix
    page mapped read-only into several slots; their logits rows are
    garbage the engine discards. Covers plain, sliding-window (ring) and
    int8-KV dense configs.
    """
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], token, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    b = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    table = jnp.asarray(table, jnp.int32)

    def body(carry, inp):
        xc, cd = carry
        lp, idx = inp
        h = cfg.num_heads
        res = xc
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        ctx, cd = paged_attn_decode(cfg, lp, y, pos, table, active, cd,
                                    idx, page_size=page_size,
                                    ring_len=ring_len)
        ctx = ctx[:, :, :h, :]
        xc = res + ctx.reshape(b, 1, -1) @ lp["wo"]
        res = xc
        y = L.rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        y = L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
        return (res + y, cd), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(body, (x, dict(cache)),
                                 (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)[:, 0]
    return logits, cache


def prefill_chunk_paged(cfg: ModelConfig, params, cache, tokens, row,
                        offset, limit=None, *, page_size: int,
                        ring_len: int = 0, abs_len: int = 0):
    """One prefill chunk of an admitted prompt, written through one
    slot's page-table row while the other slots keep decoding between
    chunks.

    tokens [1, C] int32; row [W] int32 (the slot's mapped pages); offset /
    limit: traced scalars (`limit` = offset + the chunk's real token
    count; defaults to offset + C; `offset` starts at the pager's matched
    prefix length when shared pages were mapped — their chunks are
    skipped entirely). `abs_len`: static length of the absolute-order
    scratch buffer ring re-materialization builds (sliding-window only).
    The chunk's k/v scatter to logical [offset, offset+C) through the
    row; its queries attend the gathered logical buffer [0, offset+C)
    causally (L.attention's q_offset/kv_len path), so a prompt longer
    than C is prefilled in several calls that all compile to the same
    [1, C] shape. On non-ring rows, positions past the prompt's true end
    (final ragged chunk padded up to C) write junk into slot-PRIVATE
    pages that is either overwritten by the next write at that position
    or masked by kv_len before anything attends it; ring rows drop those
    writes via `limit` (see `paged_attn_chunk`). Returns
    (chunk logits [1, C, V], cache).
    """
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], tokens, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    c = tokens.shape[1]
    positions = offset + jnp.arange(c)[None, :]
    limit = offset + c if limit is None else limit
    row = jnp.asarray(row, jnp.int32)

    def body(carry, inp):
        xc, cd = carry
        lp, idx = inp
        h = cfg.num_heads
        res = xc
        y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        ctx, cd = paged_attn_chunk(cfg, lp, y, positions, row, offset,
                                   limit, cd, idx, page_size=page_size,
                                   ring_len=ring_len, abs_len=abs_len)
        ctx = ctx[:, :, :h, :]
        xc = res + ctx.reshape(1, c, -1) @ lp["wo"]
        res = xc
        y = L.rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        y = L.mlp(y, lp["w1"], lp["w2"], lp.get("w3"), cfg.act)
        return (res + y, cd), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(body, (x, dict(cache)),
                                 (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)
    return logits, cache


def mrope_positions_decode(pos, b):
    p = IMG_GRID + pos - N_IMG
    return jnp.broadcast_to(jnp.stack([p, p, p])[None, None, :], (b, 1, 3))


# ------------------------------------------------------------- forward


def _scan_blocks(cfg: ModelConfig, params, x, positions, *, seq_sp: bool,
                 collect_kv: bool = False, fake_quant_kv: bool = False):
    stacked = params["layers"]

    def body(xc, lp):
        if collect_kv:
            # recompute k/v for the cache (prefill)
            y = L.rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
            _, k, v = _qkv(cfg, lp, y, positions)
            out = block(cfg, lp, xc, positions, seq_sp=seq_sp,
                        fake_quant_kv=fake_quant_kv)
            return out, (k, v)
        return block(cfg, lp, xc, positions, seq_sp=seq_sp), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, kv = jax.lax.scan(body, x, stacked)
    return x, kv


def hidden_states(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    x, positions = embed_inputs(cfg, params, batch)
    x = shard(x, "batch", "seq_sp" if seq_sp else None, None)
    x, _ = _scan_blocks(cfg, params, x, positions, seq_sp=seq_sp)
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def logits_from_hidden(cfg: ModelConfig, params, x):
    head = params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    return shard(logits, "batch", None, "tp")


def forward_logits(cfg: ModelConfig, params, batch, *, seq_sp: bool = False):
    return logits_from_hidden(cfg, params, hidden_states(
        cfg, params, batch, seq_sp=seq_sp))


def encode(cfg: ModelConfig, params, batch):
    """Mean-pooled, L2-normalised sentence embeddings (gte-small path)."""
    x = hidden_states(cfg, params, batch)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(batch["tokens"].shape, x.dtype)
    mask = mask.astype(x.dtype)[..., None]
    pooled = jnp.sum(x * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1), 1)
    pooled = pooled.astype(jnp.float32)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)


# ------------------------------------------------------------- serving


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def kv_expanded_heads(cfg: ModelConfig) -> int:
    tp = L.tp_degree()
    return max(cfg.num_kv_heads, tp)


def init_cache(cfg: ModelConfig, b: int, seq_len: int, dtype=jnp.bfloat16):
    g, hd = kv_expanded_heads(cfg), cfg.resolved_head_dim
    sc = cache_len(cfg, seq_len)
    shape = (cfg.num_layers, b, sc, g, hd)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(shape[:-1], jnp.float32),
                "v_s": jnp.zeros(shape[:-1], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_page_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                   dtype=jnp.bfloat16):
    """Global block-table KV pool: [L, num_pages, page_size, G, dh] per
    tensor (+ [L, num_pages, page_size, G] f32 scales for `kv_quant`).
    Pages are the pager's allocation unit — a slot maps an ordered list
    of them through its [W] table row, and refcounted prefix pages can
    back several slots at once (serving/pager.py)."""
    g, hd = kv_expanded_heads(cfg), cfg.resolved_head_dim
    shape = (cfg.num_layers, num_pages, page_size, g, hd)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(shape[:-1], jnp.float32),
                "v_s": jnp.zeros(shape[:-1], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg: ModelConfig):
    axes = (None, "batch", None, "tp", None)
    if cfg.kv_quant:
        return {"k": axes, "v": axes, "k_s": axes[:-1], "v_s": axes[:-1]}
    return {"k": axes, "v": axes}


def prefill(cfg: ModelConfig, params, batch):
    """Full-sequence forward; returns (last-position logits, kv cache).

    For `kv_quant` configs the forward attends fake-quantized k/v (see
    `block`): the int8 cache is the single source of truth, so prefill
    must read the same values decode will — that is what makes the
    wave and chunked-paged prefill paths token-identical."""
    x, positions = embed_inputs(cfg, params, batch)
    x = shard(x, "batch", None, None)
    x, (k, v) = _scan_blocks(cfg, params, x, positions, seq_sp=False,
                             collect_kv=True, fake_quant_kv=True)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, -1:, :])[:, 0]
    S = k.shape[2]
    sc = cache_len(cfg, S)
    if sc != S:  # SWA ring layout: position p lives in slot p % sc
        k = jnp.roll(k[:, :, S - sc:], shift=S % sc, axis=2)
        v = jnp.roll(v[:, :, S - sc:], shift=S % sc, axis=2)
    if cfg.kv_quant:
        kq, ks = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
        return logits, {"k": kq, "k_s": ks, "v": vq, "v_s": vs}
    return logits, {"k": k, "v": v}


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """token [B,1] int32; pos scalar int32 (position being written).

    The cache rides in the scan CARRY (in-place per-layer updates), not in
    xs/ys — collecting updated caches as scan outputs double-buffers the
    whole cache and (on some backends) round-trips it through f32.
    """
    emb_scale = cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0
    x = jnp.take(params["tok_embed"], token, axis=0) * emb_scale
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    ring = cache["k"].shape[2] != 0 and cfg.sliding_window is not None

    def body(carry, inp):
        xc, c = carry
        lp, idx = inp
        xc, c = block_decode(cfg, lp, xc, pos, c, idx, ring)
        return (xc, c), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(body, (x, dict(cache)),
                                 (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)[:, 0]
    return logits, cache
