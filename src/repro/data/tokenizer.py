"""Deterministic offline tokenizer: word-level hashing with an incremental
id->word table for detokenisation of seen vocabulary. No external files."""
from __future__ import annotations

import hashlib
import re
from typing import Iterable, List

import numpy as np

_WORD_RE = re.compile(r"\w+|[^\w\s]")


class HashTokenizer:
    def __init__(self, vocab_size: int = 32000, reserved: int = 4):
        self.vocab_size = vocab_size
        self.reserved = reserved  # 0 pad, 1 bos, 2 eos, 3 unk
        self.pad_id, self.bos_id, self.eos_id, self.unk_id = 0, 1, 2, 3
        self.id_to_word: dict[int, str] = {}

    def _hash(self, w: str) -> int:
        h = int.from_bytes(hashlib.md5(w.lower().encode()).digest()[:4],
                           "little")
        return self.reserved + h % (self.vocab_size - self.reserved)

    def encode(self, text: str, *, bos: bool = False,
               eos: bool = False) -> List[int]:
        ids = []
        if bos:
            ids.append(self.bos_id)
        for w in _WORD_RE.findall(text):
            i = self._hash(w)
            self.id_to_word.setdefault(i, w)
            ids.append(i)
        if eos:
            ids.append(self.eos_id)
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        out = []
        for i in map(int, ids):
            if i < self.reserved:
                continue
            out.append(self.id_to_word.get(i, f"<{i}>"))
        return " ".join(out)

    def encode_batch(self, texts: List[str], max_len: int,
                     pad: bool = True) -> np.ndarray:
        rows = []
        for t in texts:
            ids = self.encode(t)[:max_len]
            if pad:
                ids = ids + [self.pad_id] * (max_len - len(ids))
            rows.append(ids)
        return np.asarray(rows, np.int32)
