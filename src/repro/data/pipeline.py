"""Host data pipeline: sharded deterministic batching with background
prefetch and straggler-tolerant shard re-issue.

Each host loads only its shard (seeded, index-based — any host can
recompute any other host's shard, which is what makes backup re-issue and
elastic re-sharding trivial: deliverable for fault tolerance at 1000+
nodes)."""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class ShardSpec:
    host_index: int = 0
    host_count: int = 1


class LMBatcher:
    """Chops a token stream into (tokens, labels) LM batches."""

    def __init__(self, stream: np.ndarray, batch: int, seq: int,
                 shard: ShardSpec = ShardSpec(), seed: int = 0):
        self.stream = stream
        self.batch = batch
        self.seq = seq
        self.shard = shard
        self.rng = np.random.default_rng(seed)
        self.per_step = batch * (seq + 1)

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (recomputable anywhere)."""
        n = len(self.stream) - self.seq - 1
        rng = np.random.default_rng((step << 16) ^ 0x5EED)
        starts = rng.integers(0, n, self.batch)
        tok = np.stack([self.stream[s: s + self.seq] for s in starts])
        lab = np.stack([self.stream[s + 1: s + self.seq + 1] for s in starts])
        lo = self.shard.host_index * self.batch // self.shard.host_count
        hi = (self.shard.host_index + 1) * self.batch // self.shard.host_count
        return {"tokens": tok[lo:hi].astype(np.int32),
                "labels": lab[lo:hi].astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch with bounded queue + timeout re-issue
    (straggler mitigation: if the producer misses the deadline the consumer
    recomputes the deterministic batch synchronously)."""

    def __init__(self, batch_fn, depth: int = 2, timeout_s: float = 30.0):
        self.batch_fn = batch_fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.timeout_s = timeout_s
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = 0
        while not self._stop.is_set():
            b = self.batch_fn(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, b), timeout=0.5)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> dict:
        try:
            step, b = self.q.get(timeout=self.timeout_s)
        except queue.Empty:
            # straggler path: recompute deterministically
            b = self.batch_fn(self._step)
            step = self._step
        self._step = step + 1
        return b

    def stop(self):
        self._stop.set()
