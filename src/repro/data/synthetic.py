"""Seeded synthetic datasets, distribution-matched to the paper's corpora
(the originals are not redistributable inside this container):

  * SIFT-like  : 128-d non-negative int-valued patch descriptors,
  * NYTimes-like: 256-d clustered, L2-normalised text embeddings,
  * QA corpora  : SQuAD- / HotpotQA- / TriviaQA-style documents with
    *planted* answer sentences so retrieval accuracy is measurable offline
    (HotpotQA-style plants the answer across two documents: multi-hop).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

_TOPICS = ["tiramisu", "volcano", "telescope", "marathon", "sourdough",
           "glacier", "jazz", "satellite", "orchid", "chess", "espresso",
           "monsoon", "fresco", "compiler", "harbor", "meteor", "violin",
           "reef", "tundra", "pagoda"]
_FACTS = ["originated in {p}", "was first described in {y}",
          "requires {n} distinct steps", "is celebrated every {m}",
          "costs about {n} dollars", "measures {n} meters",
          "was invented by the {p} school", "peaks during {m}"]
_PLACES = ["Italy", "Kyoto", "Peru", "Norway", "Cairo", "Texas", "Mumbai",
           "Prague", "Nairobi", "Quebec"]
_MONTHS = ["January", "April", "July", "October"]
_FILLER = ["Many visitors find this interesting.",
           "Local records mention it repeatedly.",
           "The details vary between sources.",
           "Several studies have examined the phenomenon.",
           "Its popularity has grown in recent years.",
           "Experts continue to debate the finer points.",
           "The history involves several regions.",
           "Archives preserve a number of accounts."]


def sift_like(n: int = 10000, nq: int = 100, d: int = 128, seed: int = 0):
    """Non-negative, heavy-tailed int-valued descriptors (SIFT histograms)."""
    rng = np.random.default_rng(seed)
    base = rng.gamma(2.0, 12.0, size=(n, d)).astype(np.float32)
    base = np.floor(np.clip(base, 0, 218))
    qidx = rng.choice(n, nq, replace=False)
    queries = base[qidx] + rng.normal(0, 2.0, (nq, d)).astype(np.float32)
    return base, np.clip(queries, 0, 218).astype(np.float32)


def nytimes_like(n: int = 5000, nq: int = 100, d: int = 256, seed: int = 0,
                 n_topics: int = 50):
    """Clustered, unit-norm embeddings (topic structure like text vectors)."""
    rng = np.random.default_rng(seed)
    topics = rng.normal(size=(n_topics, d)).astype(np.float32)
    topics /= np.linalg.norm(topics, axis=1, keepdims=True)
    assign = rng.integers(0, n_topics, n)
    base = topics[assign] + 0.3 * rng.normal(size=(n, d)).astype(np.float32)
    base /= np.linalg.norm(base, axis=1, keepdims=True)
    qidx = rng.choice(n, nq, replace=False)
    queries = base[qidx] + 0.05 * rng.normal(size=(nq, d)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    return base.astype(np.float32), queries.astype(np.float32)


@dataclass
class QAExample:
    question: str
    answer: str
    doc_ids: Tuple[int, ...]     # documents containing the evidence


@dataclass
class QACorpus:
    docs: List[str]
    examples: List[QAExample]
    style: str


def _sent(rng) -> str:
    return str(rng.choice(_FILLER))


def make_qa_corpus(style: str = "squad", n_docs: int = 200,
                   n_questions: int = 50, sentences_per_doc: int = 12,
                   seed: int = 0) -> QACorpus:
    """Plant unique (topic, fact) answer sentences inside filler documents.

    squad   : single-doc factoid; answer sentence in one doc.
    hotpot  : multi-hop; evidence split across two docs (bridge entity).
    trivia  : factoid with distractor mentions of the topic in other docs.
    """
    rng = np.random.default_rng(seed)
    docs: List[List[str]] = [[_sent(rng) for _ in range(sentences_per_doc)]
                             for _ in range(n_docs)]
    examples: List[QAExample] = []
    for qi in range(n_questions):
        topic = f"{_TOPICS[qi % len(_TOPICS)]}{qi}"
        fact = str(rng.choice(_FACTS))
        answer = fact.format(p=str(rng.choice(_PLACES)),
                             y=str(rng.integers(1500, 2020)),
                             n=str(rng.integers(2, 90)),
                             m=str(rng.choice(_MONTHS)))
        if style == "hotpot":
            d1, d2 = rng.choice(n_docs, 2, replace=False)
            bridge = f"entity{qi}"
            s1 = f"The {topic} is closely associated with {bridge}."
            s2 = f"Records state that {bridge} {answer}."
            docs[d1][rng.integers(1, sentences_per_doc - 1)] = s1
            docs[d2][rng.integers(1, sentences_per_doc - 1)] = s2
            q = f"What do records state about the {topic}?"
            examples.append(QAExample(q, answer, (int(d1), int(d2))))
        else:
            d1 = int(rng.integers(0, n_docs))
            s1 = f"The {topic} {answer}."
            docs[d1][rng.integers(1, sentences_per_doc - 1)] = s1
            if style == "trivia":
                # distractors: mention the topic elsewhere without the fact
                for _ in range(2):
                    dd = int(rng.integers(0, n_docs))
                    if dd != d1:
                        docs[dd][rng.integers(1, sentences_per_doc - 1)] = \
                            f"Some mention the {topic} only in passing."
            q = f"What is known about the {topic}?"
            examples.append(QAExample(q, answer, (d1,)))
    return QACorpus([" ".join(s) for s in docs], examples, style)


def lm_token_stream(tokenizer, n_tokens: int, seed: int = 0) -> np.ndarray:
    """Token stream for LM training from generated documents."""
    corpus = make_qa_corpus("squad", n_docs=max(20, n_tokens // 400),
                            n_questions=50, seed=seed)
    ids: List[int] = []
    for doc in corpus.docs:
        ids.extend(tokenizer.encode(doc, bos=True, eos=True))
        if len(ids) >= n_tokens:
            break
    while len(ids) < n_tokens:
        ids.extend(ids[: n_tokens - len(ids)])
    return np.asarray(ids[:n_tokens], np.int32)
