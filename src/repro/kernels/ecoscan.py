"""EcoVector inverted-list scan kernel (the paper's §3.2 on TPU).

The mobile algorithm loads one inverted list at a time from flash into RAM
and searches its small graph. The TPU analogue: cluster blocks live in HBM
([NC, CAP, d], one block per cluster); the *scalar-prefetched* probe list
drives the BlockSpec index_map so only the probed clusters' blocks are
DMA'd into VMEM; distances for the whole (padded) cluster are one MXU
matmul; a running top-k merge lives in the revisited output block.

Grid: (B, T) — T probe *tiles* per query (PROBE_TILE clusters DMA'd and
scanned per step), sequential on a TPU core, so the output block for query
b is revisited T times (init at t == 0, merge otherwise). Tiling probes
amortizes the output-block revisits P/PROBE_TILE-fold versus the old
one-probe-per-step grid.

Probe ids < 0 are padding and contribute no candidates (DESIGN.md §4);
`route_and_scan` fuses centroid routing (matmul + lax.top_k) with the scan
so the whole route->scan path is one jitted device call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG

DEFAULT_PROBE_TILE = 4


def _merge_topk_sort(cand_d, cand_i, out_d_ref, out_i_ref, k: int):
    """Sort-based merge: concat the running top-k with the new candidates
    ([1, M]) and take the k smallest in one stable sort_key_val (ties keep
    flat candidate order, matching lax.top_k in the reference)."""
    all_d = jnp.concatenate([out_d_ref[...], cand_d], axis=1)   # [1, K+M]
    all_i = jnp.concatenate([out_i_ref[...], cand_i], axis=1)
    sd, si = jax.lax.sort_key_val(all_d, all_i, dimension=1)
    out_d_ref[...] = jax.lax.slice_in_dim(sd, 0, k, axis=1)
    out_i_ref[...] = jax.lax.slice_in_dim(si, 0, k, axis=1)


def _merge_topk_argmin(cand_d, cand_i, out_d_ref, out_i_ref, k: int):
    """Legacy O(k·M) sequential-argmin merge — kept for the before/after
    microbenchmark (bench_kernels.py) and as a lowering fallback."""
    cur_d = out_d_ref[...]
    cur_i = out_i_ref[...]
    all_d = jnp.concatenate([cur_d, cand_d], axis=1)   # [1, K+M]
    all_i = jnp.concatenate([cur_i, cand_i], axis=1)

    def body(j, carry):
        ad, ai, od, oi = carry
        pos = jnp.argmin(ad[0])
        dval = ad[0, pos]
        # an exhausted (all-sentinel) pool re-selects position 0, whose id
        # slot holds an already-picked real id — emit -1 for sentinels
        ival = jnp.where(dval >= NEG, jnp.int32(-1), ai[0, pos])
        od = jax.lax.dynamic_update_slice(od, dval[None, None], (0, j))
        oi = jax.lax.dynamic_update_slice(oi, ival[None, None], (0, j))
        ad = ad.at[0, pos].set(NEG)
        return ad, ai, od, oi

    od = jnp.zeros((1, k), jnp.float32)
    oi = jnp.zeros((1, k), jnp.int32)
    _, _, od, oi = jax.lax.fori_loop(0, k, body, (all_d, all_i, od, oi))
    out_d_ref[...] = od
    out_i_ref[...] = oi


_MERGES = {"sort": _merge_topk_sort, "argmin": _merge_topk_argmin}


def _kernel(probe_ref, lens_ref, bmap_ref, q_ref, *refs, k: int, cap: int,
            pt: int, merge: str):
    data_refs = refs[:pt]                           # pt x [1, CAP, d]
    out_d_ref, out_i_ref = refs[pt], refs[pt + 1]
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_d_ref[...] = jnp.full(out_d_ref.shape, NEG, jnp.float32)
        out_i_ref[...] = jnp.full(out_i_ref.shape, -1, jnp.int32)

    b = pl.program_id(0)
    q = q_ref[...]                                  # [1, d]
    qq = jnp.sum(q * q)
    cand_d = []
    cand_i = []
    for j in range(pt):
        cid = probe_ref[b, t * pt + j]
        blk = bmap_ref[jnp.maximum(cid, 0)]         # cluster -> scan block
        safe = jnp.maximum(blk, 0)                  # masked/padded -> block 0
        x = data_refs[j][0]                         # [CAP, d]
        # L2 distance via matmul on the MXU: ||x||^2 - 2 x.q + ||q||^2
        xx = jnp.sum(x * x, axis=1, keepdims=True)  # [CAP, 1]
        xq = jax.lax.dot_general(x, q, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        dist = (xx - 2.0 * xq).T + qq               # [1, CAP]
        slot = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)
        valid = (slot < lens_ref[safe]) & (cid >= 0) & (blk >= 0)
        cand_d.append(jnp.where(valid, dist, NEG))
        cand_i.append(jnp.where(valid, safe * cap + slot, -1))
    cand_d = cand_d[0] if pt == 1 else jnp.concatenate(cand_d, axis=1)
    cand_i = cand_i[0] if pt == 1 else jnp.concatenate(cand_i, axis=1)
    _MERGES[merge](cand_d, cand_i, out_d_ref, out_i_ref, k)


def _data_index(b, t, pr, ln, bm, *, j, pt):
    # Padded (-1) or unmapped probes are clamped to block 0; the kernel
    # masks their candidates, so the wasted DMA is harmless.
    return (jnp.maximum(bm[jnp.maximum(pr[b, t * pt + j], 0)], 0), 0, 0)


@functools.partial(jax.jit,
                   static_argnames=("k", "interpret", "merge", "probe_tile"))
def ecoscan(q, data, lens, probe_ids, k: int = 10, interpret: bool = True,
            merge: str = "sort", probe_tile: int | None = None,
            block_map=None):
    """q: [B, d] f32; data: [R, CAP, d] f32; lens: [R] i32;
    probe_ids: [B, P] i32 (ids < 0 are skipped padding).
    Returns (dists [B, k], ids [B, k]) — ids are global slots r*CAP+j,
    -1 where fewer than k valid candidates exist.

    `block_map` ([NC] i32, optional) decouples *cluster ids* in
    `probe_ids` from *scan rows* in `data`: probing cluster c scans block
    row block_map[c]; entries < 0 mask the cluster entirely (its
    candidates never surface). Identity when omitted. This is what lets a
    tiered index scan an arbitrary hot subset plus a per-batch gathered
    cold scratch through the exact same kernel math (DESIGN.md §14)."""
    B, d = q.shape
    R, CAP, _ = data.shape
    P = probe_ids.shape[1]
    if probe_tile is not None and probe_tile < 1:
        raise ValueError(f"probe_tile must be >= 1, got {probe_tile}")
    if P == 0:                                      # nothing probed
        return (jnp.full((B, k), NEG, jnp.float32),
                jnp.full((B, k), -1, jnp.int32))
    pt = min(probe_tile or DEFAULT_PROBE_TILE, P)
    T = pl.cdiv(P, pt)
    probe_ids = probe_ids.astype(jnp.int32)
    if T * pt != P:                                 # pad to a whole tile
        probe_ids = jnp.pad(probe_ids, ((0, 0), (0, T * pt - P)),
                            constant_values=-1)
    if block_map is None:
        block_map = jnp.arange(R, dtype=jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                      # probe_ids, lens, bmap
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, t, pr, ln, bm: (b, 0)),
            *[pl.BlockSpec((1, CAP, d),
                           functools.partial(_data_index, j=j, pt=pt))
              for j in range(pt)],
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, t, pr, ln, bm: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t, pr, ln, bm: (b, 0)),
        ],
    )
    kern = pl.pallas_call(
        functools.partial(_kernel, k=k, cap=CAP, pt=pt, merge=merge),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, k), jnp.float32),
                   jax.ShapeDtypeStruct((B, k), jnp.int32)],
        interpret=interpret,
    )
    data = data.astype(jnp.float32)
    out_d, out_i = kern(probe_ids, lens.astype(jnp.int32),
                        block_map.astype(jnp.int32),
                        q.astype(jnp.float32), *([data] * pt))
    return out_d, out_i


@functools.partial(jax.jit, static_argnames=("n_probe",))
def route_topk(q, centroids, n_probe: int):
    """Centroid routing: one MXU matmul + lax.top_k -> probes [B, n_probe].

    Shared by the fused `route_and_scan` and the tiered index's split
    route->gather->scan path, so both pick bitwise-identical probes."""
    q = q.astype(jnp.float32)
    cent = centroids.astype(jnp.float32)
    d2 = (jnp.sum(q * q, axis=1, keepdims=True)
          - 2.0 * q @ cent.T
          + jnp.sum(cent * cent, axis=1)[None, :])  # [B, NC]
    _, probes = jax.lax.top_k(-d2, n_probe)
    return probes.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("n_probe", "k", "interpret", "merge",
                                    "probe_tile"))
def route_and_scan(q, centroids, data, lens, n_probe: int = 4, k: int = 10,
                   interpret: bool = True, merge: str = "sort",
                   probe_tile: int | None = None):
    """Fused route->scan: centroid routing (one MXU matmul + lax.top_k) and
    the ecoscan kernel inside a single jit — no host round-trip between
    choosing the probes and scanning them (DESIGN.md §4).

    q: [B, d]; centroids: [NC, d]; data/lens as in `ecoscan`.
    Returns (dists [B, k], slots [B, k], probes [B, n_probe])."""
    probes = route_topk(q, centroids, n_probe)
    dists, slots = ecoscan(q, data, lens, probes, k=k, interpret=interpret,
                           merge=merge, probe_tile=probe_tile)
    return dists, slots, probes
