"""EcoVector inverted-list scan kernel (the paper's §3.2 on TPU).

The mobile algorithm loads one inverted list at a time from flash into RAM
and searches its small graph. The TPU analogue: cluster blocks live in HBM
([NC, CAP, d], one block per cluster); the *scalar-prefetched* probe list
drives the BlockSpec index_map so only the probed clusters' blocks are
DMA'd into VMEM; distances for the whole (padded) cluster are one MXU
matmul; a running top-k merge lives in VMEM scratch across grid steps.

Grid: (B, P) — P probes per query, sequential on a TPU core, so the output
block for query b is revisited P times (init at p == 0, merge otherwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = 3.4e38  # "+infinity" sentinel (plain float: jnp consts can't be captured)


def _merge_topk(cand_d, cand_i, out_d_ref, out_i_ref, k: int):
    """Merge candidate (dists [1, M], ids [1, M]) into sorted refs [1, K]."""
    cur_d = out_d_ref[...]
    cur_i = out_i_ref[...]
    all_d = jnp.concatenate([cur_d, cand_d], axis=1)   # [1, K+M]
    all_i = jnp.concatenate([cur_i, cand_i], axis=1)

    def body(j, carry):
        ad, ai, od, oi = carry
        pos = jnp.argmin(ad[0])
        od = jax.lax.dynamic_update_slice(od, ad[0, pos][None, None], (0, j))
        oi = jax.lax.dynamic_update_slice(oi, ai[0, pos][None, None], (0, j))
        ad = ad.at[0, pos].set(NEG)
        return ad, ai, od, oi

    od = jnp.zeros((1, k), jnp.float32)
    oi = jnp.zeros((1, k), jnp.int32)
    _, _, od, oi = jax.lax.fori_loop(0, k, body, (all_d, all_i, od, oi))
    out_d_ref[...] = od
    out_i_ref[...] = oi


def _kernel(probe_ref, lens_ref, q_ref, data_ref, out_d_ref, out_i_ref, *,
            k: int, cap: int):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        out_d_ref[...] = jnp.full(out_d_ref.shape, NEG, jnp.float32)
        out_i_ref[...] = jnp.full(out_i_ref.shape, -1, jnp.int32)

    b = pl.program_id(0)
    cid = probe_ref[b, p]
    q = q_ref[...]                                  # [1, d]
    x = data_ref[0]                                 # [CAP, d]
    # L2 distance via matmul on the MXU:  ||x||^2 - 2 x.q  (+||q||^2 const)
    xx = jnp.sum(x * x, axis=1, keepdims=True)      # [CAP, 1]
    xq = jax.lax.dot_general(x, q, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [CAP, 1]
    dist = (xx - 2.0 * xq).T                        # [1, CAP]
    qq = jnp.sum(q * q)
    dist = dist + qq
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)
    valid = slot < lens_ref[cid]
    dist = jnp.where(valid, dist, NEG)
    gids = jnp.where(valid, cid * cap + slot, -1)
    _merge_topk(dist, gids, out_d_ref, out_i_ref, k)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ecoscan(q, data, lens, probe_ids, k: int = 10, interpret: bool = True):
    """q: [B, d] f32; data: [NC, CAP, d] f32; lens: [NC] i32;
    probe_ids: [B, P] i32. Returns (dists [B, k], ids [B, k])."""
    B, d = q.shape
    NC, CAP, _ = data.shape
    P = probe_ids.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                      # probe_ids, lens
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, p, pr, ln: (b, 0)),
            pl.BlockSpec((1, CAP, d), lambda b, p, pr, ln: (pr[b, p], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, p, pr, ln: (b, 0)),
            pl.BlockSpec((1, k), lambda b, p, pr, ln: (b, 0)),
        ],
    )
    kern = pl.pallas_call(
        functools.partial(_kernel, k=k, cap=CAP),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, k), jnp.float32),
                   jax.ShapeDtypeStruct((B, k), jnp.int32)],
        interpret=interpret,
    )
    out_d, out_i = kern(probe_ids.astype(jnp.int32), lens.astype(jnp.int32),
                        q.astype(jnp.float32), data.astype(jnp.float32))
    return out_d, out_i
