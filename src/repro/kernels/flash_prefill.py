"""Causal (optionally sliding-window) flash attention for prefill.

Tiled [TQ x TS] with online softmax in VMEM scratch. The causal band is
honoured *statically*: KV tiles strictly above the diagonal (or outside the
sliding window) are skipped by clamping the grid per q-tile via masking
inside the kernel; fully-masked tiles short-circuit to a no-op. kv heads
must be pre-expanded to the q head count by the wrapper (GQA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            tq: int, ts: int, nsteps: int, scale: float, causal: bool,
            window):
    qi = pl.program_id(1)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q_start = qi * tq
    s_start = si * ts
    # static-ish band check (traced but cheap): skip fully-masked tiles
    needed = jnp.asarray(True)
    if causal:
        needed = needed & (s_start <= q_start + tq - 1)
    if window is not None:
        needed = needed & (s_start + ts - 1 >= q_start - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0]                                  # [TQ, dh]
        k = k_ref[0]                                  # [TS, dh]
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (tq, ts), 0)
        kpos = s_start + jax.lax.broadcasted_iota(jnp.int32, (tq, ts), 1)
        mask = jnp.ones((tq, ts), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(si == nsteps - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "tq", "ts",
                                             "interpret"))
def flash_prefill(q, k, v, *, causal: bool = True, window=None,
                  tq: int = 128, ts: int = 128, interpret: bool = True):
    """q,k,v: [B, H, S, dh] (kv pre-expanded to H). Returns [B, H, S, dh]."""
    B, H, S, dh = q.shape
    import math
    qf = q.reshape(B * H, S, dh)
    kf = k.reshape(B * H, S, dh)
    vf = v.reshape(B * H, S, dh)
    pad = (-S) % math.lcm(tq, ts)
    if pad:
        z = jnp.zeros((B * H, pad, dh), q.dtype)
        qf = jnp.concatenate([qf, z], 1)
        kf = jnp.concatenate([kf, z], 1)
        vf = jnp.concatenate([vf, z], 1)
    Sp = qf.shape[1]
    nq, ns = Sp // tq, Sp // ts
    scale = 1.0 / (dh ** 0.5)
    out = pl.pallas_call(
        functools.partial(_kernel, tq=tq, ts=ts, nsteps=ns, scale=scale,
                          causal=causal, window=window),
        grid=(B * H, nq, ns),
        in_specs=[pl.BlockSpec((1, tq, dh), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, ts, dh), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, ts, dh), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, tq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((tq, 1), jnp.float32),
                        pltpu.VMEM((tq, 1), jnp.float32),
                        pltpu.VMEM((tq, dh), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :S].reshape(B, H, S, dh)