"""Jit'd dispatch wrappers: Pallas kernel on TPU (or interpret=True on CPU
for validation), pure-jnp reference otherwise. `use_pallas` is the build
switch; interpret mode is selected automatically off-TPU.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.ecoscan import ecoscan as _ecoscan
from repro.kernels.ecoscan import route_and_scan as _route_and_scan
from repro.kernels.ecoscan import route_topk as _route_topk
from repro.kernels.kmeans_assign import kmeans_assign as _kmeans_assign
from repro.kernels.scr_score import scr_score as _scr_score
from repro.kernels.scr_select import scr_select as _scr_select
from repro.kernels.pq_adc import pq_adc as _pq_adc
from repro.kernels.decode_attention import decode_attention as _decode_attn
from repro.kernels.decode_attention import (
    decode_attention_paged as _decode_attn_paged)
from repro.kernels.flash_prefill import flash_prefill as _flash_prefill


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Backend-aware interpret default shared by every kernel dispatch:
    compiled Mosaic on real TPU, interpret mode (correctness-grade, runs
    the kernel body through XLA) everywhere else. Kernel entry points
    take `interpret=None` and resolve it here, so callers never hardcode
    a backend assumption."""
    return not _on_tpu()


# Mosaic support for lax.sort_key_val inside kernel bodies varies by
# version; if the sort-based merge fails to lower on real TPU we fall back
# to the argmin merge and remember (interpret mode always sorts). A racy
# write from concurrent serving threads is benign: worst case both compile.
_SORT_MERGE_BROKEN = False
_SORT_MERGE_FAILS = 0
# a genuine lowering failure sticks immediately; anything else (possibly
# transient, e.g. RESOURCE_EXHAUSTED) gets this many sort retries before
# we stop paying a doomed trace+compile on every call
_SORT_MERGE_MAX_RETRIES = 3

# deliberately narrow: the failing op is sort_key_val, so loose substrings
# like "sort" would match transient errors too and defeat the retry budget
_LOWERING_MARKERS = ("mosaic", "unimplemented", "not implemented",
                     "unsupported", "cannot lower", "failed to lower")


def _with_merge_fallback(call, merge, interpret):
    global _SORT_MERGE_BROKEN, _SORT_MERGE_FAILS
    if merge == "sort" and not interpret and _SORT_MERGE_BROKEN:
        merge = "argmin"
    try:
        out = call(merge)
        if merge == "sort" and not interpret:
            _SORT_MERGE_FAILS = 0       # budget counts CONSECUTIVE failures
        return out
    except Exception as e:
        if merge == "sort" and not interpret:
            out = call("argmin")         # re-raises if merge wasn't the issue
            _SORT_MERGE_FAILS += 1
            is_lowering = any(m in str(e).lower() for m in _LOWERING_MARKERS)
            if is_lowering or _SORT_MERGE_FAILS >= _SORT_MERGE_MAX_RETRIES:
                import warnings
                warnings.warn(
                    f"ecoscan sort merge failed on "
                    f"{jax.default_backend()} ({type(e).__name__}"
                    f"{'' if is_lowering else ', persistent'}); using "
                    f"the argmin merge from now on", stacklevel=3)
                _SORT_MERGE_BROKEN = True
            return out
        raise


def ecoscan(q, data, lens, probe_ids, k=10, use_pallas=True, merge="sort",
            block_map=None):
    if use_pallas:
        interpret = not _on_tpu()
        return _with_merge_fallback(
            lambda m: _ecoscan(q, data, lens, probe_ids, k=k,
                               interpret=interpret, merge=m,
                               block_map=block_map),
            merge, interpret)
    return ref.ecoscan(q, data, lens, probe_ids, k, block_map=block_map)


def route_topk(q, centroids, n_probe=4, use_pallas=True):
    """Centroid routing only (matmul + lax.top_k) -> probes [B, n_probe].
    Same math as the routing half of `route_and_scan`, so a split
    route->scan caller picks bitwise-identical probes."""
    del use_pallas      # pure jnp either way; one implementation on purpose
    return _route_topk(q, centroids, n_probe)


def route_and_scan(q, centroids, data, lens, n_probe=4, k=10,
                   use_pallas=True, merge="sort"):
    """One fused device call: centroid routing + probed-cluster scan.
    Returns (dists [B,k], slots [B,k], probes [B,n_probe])."""
    if use_pallas:
        interpret = not _on_tpu()
        return _with_merge_fallback(
            lambda m: _route_and_scan(q, centroids, data, lens,
                                      n_probe=n_probe, k=k,
                                      interpret=interpret, merge=m),
            merge, interpret)
    return ref.route_and_scan(q, centroids, data, lens, n_probe, k)


def kmeans_assign(x, centroids, use_pallas=True):
    if use_pallas:
        return _kmeans_assign(x, centroids, interpret=not _on_tpu())
    return ref.kmeans_assign(x, centroids)


def scr_score(windows, q, use_pallas=True):
    if use_pallas:
        return _scr_score(windows, q, interpret=default_interpret())
    return ref.scr_score(windows, q)


def scr_select(q, data, lens, doc_ids, use_pallas=True):
    """Fused SCR select: per-(query, retrieved doc) best window id and
    query·window score in one device call (DESIGN.md §7)."""
    if use_pallas:
        return _scr_select(q, data, lens, doc_ids,
                           interpret=default_interpret())
    return ref.scr_select(q, data, lens, doc_ids)


def pq_adc(lut, codes, use_pallas=True):
    if use_pallas:
        return _pq_adc(lut, codes, interpret=default_interpret())
    return ref.pq_adc(lut, codes)


def decode_attention(q, k, v, kv_len, use_pallas=True, ring=False):
    """Flash-decode attention; `kv_len` scalar or per-row [B] vector,
    `ring=True` for per-slot sliding-window ring pages (mask length
    min(kv_len, S) per row)."""
    if use_pallas:
        return _decode_attn(q, k, v, kv_len, interpret=default_interpret(),
                            ring=ring)
    return ref.decode_attention(q, k, v, kv_len, ring=ring)


def decode_attention_paged(q, k, v, kv_len, table, use_pallas=True):
    """Block-table flash decode: K/V page pools [P, ps, G, dh] gathered
    through a per-row page table [B, W] (scalar-prefetched on TPU so each
    grid step DMAs exactly one mapped page). `kv_len` [B] masks unmapped
    tail entries; ring callers pre-clamp it to the ring modulus."""
    if use_pallas:
        return _decode_attn_paged(q, k, v, kv_len, table,
                                  interpret=default_interpret())
    return ref.decode_attention_paged(q, k, v, kv_len, table)


def flash_prefill(q, k, v, causal=True, window=None, use_pallas=True):
    if use_pallas:
        return _flash_prefill(q, k, v, causal=causal, window=window,
                              interpret=not _on_tpu())
    return ref.flash_prefill(q, k, v, causal=causal, window=window)
