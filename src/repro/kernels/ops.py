"""Jit'd dispatch wrappers: Pallas kernel on TPU (or interpret=True on CPU
for validation), pure-jnp reference otherwise. `use_pallas` is the build
switch; interpret mode is selected automatically off-TPU.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.ecoscan import ecoscan as _ecoscan
from repro.kernels.kmeans_assign import kmeans_assign as _kmeans_assign
from repro.kernels.scr_score import scr_score as _scr_score
from repro.kernels.pq_adc import pq_adc as _pq_adc
from repro.kernels.decode_attention import decode_attention as _decode_attn
from repro.kernels.flash_prefill import flash_prefill as _flash_prefill


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ecoscan(q, data, lens, probe_ids, k=10, use_pallas=True):
    if use_pallas:
        return _ecoscan(q, data, lens, probe_ids, k=k,
                        interpret=not _on_tpu())
    return ref.ecoscan(q, data, lens, probe_ids, k)


def kmeans_assign(x, centroids, use_pallas=True):
    if use_pallas:
        return _kmeans_assign(x, centroids, interpret=not _on_tpu())
    return ref.kmeans_assign(x, centroids)


def scr_score(windows, q, use_pallas=True):
    if use_pallas:
        return _scr_score(windows, q, interpret=not _on_tpu())
    return ref.scr_score(windows, q)


def pq_adc(lut, codes, use_pallas=True):
    if use_pallas:
        return _pq_adc(lut, codes, interpret=not _on_tpu())
    return ref.pq_adc(lut, codes)


def decode_attention(q, k, v, kv_len, use_pallas=True):
    if use_pallas:
        return _decode_attn(q, k, v, kv_len, interpret=not _on_tpu())
    return ref.decode_attention(q, k, v, kv_len)


def flash_prefill(q, k, v, causal=True, window=None, use_pallas=True):
    if use_pallas:
        return _flash_prefill(q, k, v, causal=causal, window=window,
                              interpret=not _on_tpu())
    return ref.flash_prefill(q, k, v, causal=causal, window=window)
