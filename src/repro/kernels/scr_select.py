"""Fused batched SCR select kernel (§4 steps 1+2 on TPU).

`scr_score` computes every query x window similarity and leaves the
per-document best-window selection to a host Python scan. This kernel
fuses both: window embeddings live corpus-resident in HBM as one padded
[ND, CAPW, d] block per document (the SCR analogue of the EcoVector
[NC, CAP, d] cluster pack, DESIGN.md §6), the *scalar-prefetched*
retrieved-doc id matrix drives the BlockSpec index_map so only the
retrieved documents' blocks are DMA'd into VMEM, and each block's
query·window scores AND segment-argmax (best window id + score) come out
of one MXU matmul + row reduction — no [B, NW] score matrix ever leaves
the device.

Grid: (B, T) — T doc *tiles* per query (DOC_TILE document blocks DMA'd
and reduced per step). Each step owns its private (1, DOC_TILE) slice of
the output, so there are no revisited output blocks and no cross-step
merge: the segment boundaries are exactly the document blocks.

Doc ids < 0 are padding (queries that retrieved fewer than K docs):
their block index is clamped to 0 and every window masked, yielding the
(-NEG, -1) sentinel pair. Ties on the max score resolve to the lowest
window id, matching the host `max()` scan and `jnp.argmax`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG

DEFAULT_DOC_TILE = 8


def _kernel(ids_ref, lens_ref, q_ref, *refs, capw: int, dt: int):
    data_refs = refs[:dt]                           # dt x [1, CAPW, d]
    out_s_ref, out_w_ref = refs[dt], refs[dt + 1]
    b = pl.program_id(0)
    t = pl.program_id(1)
    q = q_ref[...]                                  # [1, d]
    best_s, best_w = [], []
    for j in range(dt):
        did = ids_ref[b, t * dt + j]
        safe = jnp.maximum(did, 0)                  # padded doc -> block 0
        w = data_refs[j][0]                         # [CAPW, d]
        s = jax.lax.dot_general(w, q, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s.T                                     # [1, CAPW]
        slot = jax.lax.broadcasted_iota(jnp.int32, (1, capw), 1)
        valid = (slot < lens_ref[safe]) & (did >= 0)
        s = jnp.where(valid, s, -NEG)
        # segment-argmax within the document block: first max wins ties,
        # matching the host scan (Python max / jnp.argmax semantics)
        best_s.append(jnp.max(s, axis=1, keepdims=True))          # [1, 1]
        win = jnp.argmax(s, axis=1).astype(jnp.int32)[:, None]    # [1, 1]
        has = jnp.any(valid)
        best_w.append(jnp.where(has, win, -1))
    out_s_ref[...] = (best_s[0] if dt == 1
                      else jnp.concatenate(best_s, axis=1))       # [1, dt]
    out_w_ref[...] = (best_w[0] if dt == 1
                      else jnp.concatenate(best_w, axis=1))


def _data_index(b, t, ids, ln, *, j, dt):
    # Padded doc ids (-1) are clamped to block 0; the kernel masks them.
    return (jnp.maximum(ids[b, t * dt + j], 0), 0, 0)


@functools.partial(jax.jit, static_argnames=("interpret", "doc_tile"))
def scr_select(q, data, lens, doc_ids, interpret: bool | None = None,
               doc_tile: int | None = None):
    """q: [B, d] f32 query batch; data: [ND, CAPW, d] f32 window-embedding
    blocks; lens: [ND] i32 valid windows per doc; doc_ids: [B, K] i32
    retrieved docs per query (ids < 0 are padding).

    Returns (scores [B, K] f32, wins [B, K] i32): the best window's
    query·window score and its within-document window id for every
    retrieved doc — (-NEG, -1) where the slot is padding or the document
    has no windows."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    B, d = q.shape
    ND, CAPW, _ = data.shape
    K = doc_ids.shape[1]
    if doc_tile is not None and doc_tile < 1:
        raise ValueError(f"doc_tile must be >= 1, got {doc_tile}")
    if B == 0 or K == 0 or ND == 0 or CAPW == 0:
        return (jnp.full((B, K), -NEG, jnp.float32),
                jnp.full((B, K), -1, jnp.int32))
    dt = min(doc_tile or DEFAULT_DOC_TILE, K)
    T = pl.cdiv(K, dt)
    doc_ids = doc_ids.astype(jnp.int32)
    if T * dt != K:                                 # pad to a whole tile
        doc_ids = jnp.pad(doc_ids, ((0, 0), (0, T * dt - K)),
                          constant_values=-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                      # doc_ids, lens
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, t, ids, ln: (b, 0)),
            *[pl.BlockSpec((1, CAPW, d),
                           functools.partial(_data_index, j=j, dt=dt))
              for j in range(dt)],
        ],
        out_specs=[
            pl.BlockSpec((1, dt), lambda b, t, ids, ln: (b, t)),
            pl.BlockSpec((1, dt), lambda b, t, ids, ln: (b, t)),
        ],
    )
    kern = pl.pallas_call(
        functools.partial(_kernel, capw=CAPW, dt=dt),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, T * dt), jnp.float32),
                   jax.ShapeDtypeStruct((B, T * dt), jnp.int32)],
        interpret=interpret,
    )
    data = data.astype(jnp.float32)
    out_s, out_w = kern(doc_ids, lens.astype(jnp.int32),
                        q.astype(jnp.float32), *([data] * dt))
    return out_s[:, :K], out_w[:, :K]
