"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# "+infinity" sentinel shared with the ecoscan kernel (plain float: jnp
# consts can't be captured inside Pallas kernel bodies).
NEG = 3.4e38


def ecoscan(q, data, lens, probe_ids, k, block_map=None):
    """EcoVector inverted-list scan reference.

    q: [B, d]; data: [R, CAP, d]; lens: [R] valid counts;
    probe_ids: [B, P] cluster ids per query (ids < 0 are skipped padding);
    block_map: optional [NC] i32 cluster-id -> scan-row indirection
    (entries < 0 mask the cluster; identity when omitted).
    Returns (dists [B,K], ids [B,K]) where ids are global slot ids
    row*CAP+j (-1 for missing candidates), L2 distances ascending.
    """
    B, d = q.shape
    R, CAP, _ = data.shape
    if block_map is None:
        block_map = jnp.arange(R, dtype=jnp.int32)
    blk = block_map[jnp.maximum(probe_ids, 0)]    # [B, P] scan rows
    safe = jnp.maximum(blk, 0)
    gathered = data[safe]                         # [B, P, CAP, d]
    diff = gathered - q[:, None, None, :]
    dist = jnp.sum(diff * diff, axis=-1)          # [B, P, CAP]
    slot = jnp.arange(CAP)[None, None, :]
    valid = ((slot < lens[safe][:, :, None])
             & (probe_ids[:, :, None] >= 0) & (blk[:, :, None] >= 0))
    dist = jnp.where(valid, dist, NEG)
    ids = jnp.where(valid, safe[:, :, None] * CAP + slot, -1)
    flat_d = dist.reshape(B, -1)
    flat_i = ids.reshape(B, -1).astype(jnp.int32)
    vals, idx = jax.lax.top_k(-flat_d, k)
    return -vals, jnp.take_along_axis(flat_i, idx, axis=1)


def route_and_scan(q, centroids, data, lens, n_probe, k):
    """Fused route->scan reference: dense centroid top-k then `ecoscan`."""
    q = q.astype(jnp.float32)
    cent = centroids.astype(jnp.float32)
    d2 = (jnp.sum(q * q, axis=1, keepdims=True) - 2.0 * q @ cent.T
          + jnp.sum(cent * cent, axis=1)[None, :])
    _, probes = jax.lax.top_k(-d2, n_probe)
    probes = probes.astype(jnp.int32)
    dists, slots = ecoscan(q, data, lens, probes, k)
    return dists, slots, probes


def kmeans_assign(x, centroids):
    """x: [N, d]; centroids: [NC, d] -> (assign [N] i32, sqdist [N])."""
    d2 = (jnp.sum(x * x, 1)[:, None] - 2 * x @ centroids.T +
          jnp.sum(centroids * centroids, 1)[None, :])
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return a, jnp.take_along_axis(d2, a[:, None], axis=1)[:, 0]


def scr_score(windows, q):
    """windows: [B, NW, d]; q: [B, d] -> cosine-style scores [B, NW]."""
    return jnp.einsum("bnd,bd->bn", windows, q)


def scr_select(q, data, lens, doc_ids):
    """Fused SCR select reference (§4 steps 1+2).

    q: [B, d]; data: [ND, CAPW, d] window-embedding blocks; lens: [ND]
    valid windows per doc; doc_ids: [B, K] retrieved docs per query
    (ids < 0 are padding). Returns (scores [B, K], wins [B, K]): the best
    window's query·window inner product and its within-doc window id per
    retrieved doc, (-NEG, -1) for padding slots / windowless docs. Ties
    resolve to the lowest window id (first max)."""
    B, K = doc_ids.shape
    if data.shape[0] == 0 or data.shape[1] == 0:    # no docs / no windows
        return (jnp.full((B, K), -NEG, jnp.float32),
                jnp.full((B, K), -1, jnp.int32))
    safe = jnp.maximum(doc_ids, 0)
    g = data[safe]                                  # [B, K, CAPW, d]
    s = jnp.einsum("bkwd,bd->bkw", g.astype(jnp.float32),
                   q.astype(jnp.float32))
    CAPW = data.shape[1]
    slot = jnp.arange(CAPW)[None, None, :]
    valid = (slot < lens[safe][:, :, None]) & (doc_ids[:, :, None] >= 0)
    s = jnp.where(valid, s, -NEG)
    wins = jnp.argmax(s, axis=-1).astype(jnp.int32)
    scores = jnp.take_along_axis(s, wins[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
    wins = jnp.where(jnp.any(valid, axis=-1), wins, -1)
    return scores, wins


def pq_adc(lut, codes):
    """lut: [B, M, 256] distance tables; codes: [N, M] uint8 ->
    scores [B, N] = sum_m lut[b, m, codes[n, m]]."""
    g = jnp.take_along_axis(
        lut[:, None, :, :],                          # [B,1,M,256]
        codes.astype(jnp.int32)[None, :, :, None],   # [1,N,M,1]
        axis=3)[..., 0]                              # [B,N,M]
    return jnp.sum(g, axis=-1)


def decode_attention(q, k, v, kv_len, ring: bool = False):
    """q: [B, H, dh]; k,v: [B, S, G, dh]; H % G == 0. Softmax over the
    first kv_len positions (kv_len: scalar or per-row [B] vector).
    `ring=True`: per-slot sliding-window ring pages — every filled slot
    is valid, i.e. the mask length is min(kv_len, S) per row."""
    B, H, dh = q.shape
    S, G = k.shape[1], k.shape[2]
    qg = q.reshape(B, G, H // G, dh)
    s = jnp.einsum("bgnd,bsgd->bgns", qg, k) / jnp.sqrt(dh).astype(q.dtype)
    s = s.astype(jnp.float32)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    if ring:
        lens = jnp.minimum(lens, S)
    mask = jnp.arange(S)[None, None, None, :] < lens[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgns,bsgd->bgnd", p.astype(v.dtype), v)
    return o.reshape(B, H, dh)


def decode_attention_paged(q, k, v, kv_len, table):
    """Block-table decode reference. q: [B, H, dh]; k, v: [P, ps, G, dh]
    page pools; kv_len: [B]; table: [B, W] int32 page ids (entry w backs
    logical positions [w*ps, (w+1)*ps); unmapped tail entries are masked
    by kv_len). Gathers each row's logical [W*ps] K/V through its table
    and defers to the contiguous oracle."""
    P, ps, G, dh = k.shape
    W = table.shape[1]
    j = jnp.arange(W * ps)
    idx = table[:, j // ps] * ps + (j % ps)            # [B, W*ps]
    kg = jnp.take(k.reshape(P * ps, G, dh), idx, axis=0)
    vg = jnp.take(v.reshape(P * ps, G, dh), idx, axis=0)
    return decode_attention(q, kg, vg, kv_len)


def flash_prefill(q, k, v, *, causal=True, window=None):
    """q,k,v: [B, H, S, dh] (kv pre-expanded to H heads)."""
    B, H, S, dh = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(dh).astype(q.dtype)
    s = s.astype(jnp.float32)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
