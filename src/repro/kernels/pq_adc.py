"""PQ asymmetric-distance (ADC) kernel for the IVFPQ/HNSWPQ baselines.

GPU/CPU ADC is a gather per sub-quantizer; gathers are poison for the TPU
vector unit. TPU adaptation: one-hot(codes) @ LUT — the lookup becomes an
MXU matmul (codes one-hot [TN, 256] x LUT row [256]) per sub-quantizer,
accumulated in f32. See DESIGN.md §2 (hardware adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, l_ref, o_ref, *, M: int):
    codes = c_ref[...]                                 # [TN, M] i32
    lut = l_ref[0]                                     # [M, 256]
    tn = codes.shape[0]
    acc = jnp.zeros((tn, 1), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, 256), 1)
    for m in range(M):                                 # static unroll
        oh = (codes[:, m][:, None] == iota).astype(jnp.float32)  # [TN,256]
        acc += jax.lax.dot_general(
            oh, lut[m][None, :], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # [TN, 1]
    o_ref[...] = acc.T                                 # [1, TN]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def pq_adc(lut, codes, tile: int = 512, interpret: bool | None = None):
    """lut: [B, M, 256] f32; codes: [N, M] uint8 -> scores [B, N].
    interpret=None resolves backend-aware (compiled on TPU, interpret
    elsewhere)."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    B, M, _ = lut.shape
    N = codes.shape[0]
    pad = (-N) % tile
    cp = jnp.pad(codes.astype(jnp.int32), ((0, pad), (0, 0)))
    grid = (B, cp.shape[0] // tile)
    out = pl.pallas_call(
        functools.partial(_kernel, M=M),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, M), lambda b, i: (i, 0)),
                  pl.BlockSpec((1, M, 256), lambda b, i: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, cp.shape[0]), jnp.float32),
        interpret=interpret,
    )(cp, lut.astype(jnp.float32))
    return out[:, :N]
