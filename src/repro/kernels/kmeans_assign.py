"""k-means assignment kernel (EcoVector build stage, §3.1.1).

Tiles X over the grid; the centroid table rides along in VMEM (it is the
small structure the paper keeps in the fast tier). Distances are one MXU
matmul per tile; argmin on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, a_ref, d_ref):
    x = x_ref[...]                                   # [TN, d]
    c = c_ref[...]                                   # [NC, d]
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [TN, NC]
    cc = jnp.sum(c * c, axis=1)[None, :]
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    d2 = xx - 2.0 * xc + cc
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    a_ref[...] = a[:, None]
    d_ref[...] = jnp.min(d2, axis=1)[:, None]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def kmeans_assign(x, centroids, tile: int = 512, interpret: bool = True):
    """x: [N, d]; centroids: [NC, d] -> (assign [N] i32, sqdist [N] f32)."""
    N, d = x.shape
    NC = centroids.shape[0]
    pad = (-N) % tile
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (xp.shape[0] // tile,)
    a, dist = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, d), lambda i: (i, 0)),
                  pl.BlockSpec((NC, d), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((tile, 1), lambda i: (i, 0)),
                   pl.BlockSpec((tile, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.int32),
                   jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(xp.astype(jnp.float32), centroids.astype(jnp.float32))
    return a[:N, 0], dist[:N, 0]
