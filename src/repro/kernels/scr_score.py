"""SCR window-scoring kernel (§4 step 1): batched query x sliding-window
similarity. A thin gemv, but the hot inner loop of Selective Content
Reduction when documents explode into hundreds of windows."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, q_ref, o_ref):
    w = w_ref[0]                                      # [TN, d]
    q = q_ref[...]                                    # [1, d]
    s = jax.lax.dot_general(w, q, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [TN, 1]
    o_ref[...] = s.T                                  # [1, TN]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def scr_score(windows, q, tile: int = 256, interpret: bool | None = None):
    """windows: [B, NW, d]; q: [B, d] -> scores [B, NW] (inner product).
    interpret=None resolves backend-aware (compiled on TPU, interpret
    elsewhere)."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    B, NW, d = windows.shape
    pad = (-NW) % tile
    wp = jnp.pad(windows, ((0, 0), (0, pad), (0, 0)))
    grid = (B, wp.shape[1] // tile)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile, d), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((1, d), lambda b, i: (b, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, wp.shape[1]), jnp.float32),
        interpret=interpret,
    )(wp.astype(jnp.float32), q.astype(jnp.float32))
    return out[:, :NW]
