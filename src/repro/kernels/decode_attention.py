"""Flash-decode GQA attention kernel: one query position against a long KV
cache, online softmax over KV tiles in VMEM scratch. The serving hot spot
for decode_32k / long_500k cells."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            ts: int, nsteps: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    b = pl.program_id(0)
    q = q_ref[0, 0]                                   # [Hg, dh]
    k = k_ref[0, :, 0, :]                             # [TS, dh]
    v = v_ref[0, :, 0, :]
    kv_len = len_ref[b]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = s_idx * ts + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kv_len, s, NEG)               # [Hg, TS]
    m_prev, l_prev = m_ref[...], l_ref[...]           # [Hg, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                            # [Hg, TS]
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == nsteps - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
                  l_ref, acc_ref, *, ps: int, nsteps: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    b = pl.program_id(0)
    q = q_ref[0, 0]                                   # [Hg, dh]
    k = k_ref[0, :, 0, :]                             # [ps, dh]
    v = v_ref[0, :, 0, :]
    kv_len = len_ref[b]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # logical position of lane j inside this page: pages are mapped in
    # table order, so page w covers positions [w*ps, (w+1)*ps)
    pos = s_idx * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kv_len, s, NEG)               # [Hg, ps]
    m_prev, l_prev = m_ref[...], l_ref[...]           # [Hg, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == nsteps - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_paged(q, k, v, kv_len, table,
                           interpret: bool | None = None):
    """Block-table flash decode: K/V live in a global page pool and each
    batch row reads its pages through a scalar-prefetched table.

    q: [B, H, dh]; k, v: [P, ps, G, dh] page pools (H % G == 0);
    kv_len: [B] per-row logical lengths (ring callers pre-clamp to the
    ring modulus); table: [B, W] int32 page ids — entry w backs logical
    positions [w*ps, (w+1)*ps). Unmapped tail entries may point anywhere
    valid (callers use page 0): every lane past kv_len is masked. The
    table is the second scalar-prefetch operand, so each (b, g, w) grid
    step DMAs exactly the one page `table[b, w]` — the pool itself never
    streams through in slot order. Returns [B, H, dh]."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    B, H, dh = q.shape
    P, ps, G = k.shape[0], k.shape[1], k.shape[2]
    W = table.shape[1]
    Hg = H // G
    qg = q.reshape(B, G, Hg, dh)
    scale = 1.0 / (dh ** 0.5)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    tbl = jnp.asarray(table, jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # lens, table
        grid=(B, G, W),
        in_specs=[
            pl.BlockSpec((1, 1, Hg, dh),
                         lambda b, g, w, ln, tb: (b, g, 0, 0)),
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, g, w, ln, tb: (tb[b, w], 0, g, 0)),
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, g, w, ln, tb: (tb[b, w], 0, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hg, dh),
                               lambda b, g, w, ln, tb: (b, g, 0, 0)),
        scratch_shapes=[pltpu.VMEM((Hg, 1), jnp.float32),
                        pltpu.VMEM((Hg, 1), jnp.float32),
                        pltpu.VMEM((Hg, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, ps=ps, nsteps=W, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, G, Hg, dh), q.dtype),
        interpret=interpret,
    )(lens, tbl, qg, k, v)
    return out.reshape(B, H, dh)


@functools.partial(jax.jit, static_argnames=("ts", "interpret", "ring"))
def decode_attention(q, k, v, kv_len, ts: int = 512,
                     interpret: bool | None = None, ring: bool = False):
    """q: [B, H, dh]; k, v: [B, S, G, dh] (H % G == 0); kv_len: i32 scalar
    (shared length) or [B] vector (slot-paged batches where every request
    sits at its own position). `ring=True`: each row's cache is a
    sliding-window ring page whose write cursor is `kv_len % S` — every
    FILLED slot is valid (evicted positions were overwritten in place),
    so the per-row mask length is `min(kv_len, S)`; position order inside
    the ring is irrelevant because RoPE is baked into the stored keys.
    Returns [B, H, dh]."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    B, H, dh = q.shape
    S, G = k.shape[1], k.shape[2]
    Hg = H // G
    qg = q.reshape(B, G, Hg, dh)
    pad = (-S) % ts
    if pad:
        kz = jnp.zeros((B, pad, G, dh), k.dtype)
        k = jnp.concatenate([k, kz], axis=1)
        v = jnp.concatenate([v, kz], axis=1)
    Sp = k.shape[1]
    nsteps = Sp // ts
    scale = 1.0 / (dh ** 0.5)
    lens = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    if ring:
        lens = jnp.minimum(lens, S)    # per-slot ring: filled slots valid

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # lens
        grid=(B, G, nsteps),
        in_specs=[
            pl.BlockSpec((1, 1, Hg, dh), lambda b, g, s, ln: (b, g, 0, 0)),
            pl.BlockSpec((1, ts, 1, dh), lambda b, g, s, ln: (b, s, g, 0)),
            pl.BlockSpec((1, ts, 1, dh), lambda b, g, s, ln: (b, s, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hg, dh), lambda b, g, s, ln: (b, g, 0, 0)),
        scratch_shapes=[pltpu.VMEM((Hg, 1), jnp.float32),
                        pltpu.VMEM((Hg, 1), jnp.float32),
                        pltpu.VMEM((Hg, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ts=ts, nsteps=nsteps, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, G, Hg, dh), q.dtype),
        interpret=interpret,
    )(lens, qg, k, v)
    return out.reshape(B, H, dh)