"""Deterministic fault injection for the serving stack.

A `FaultPlan` is a SEEDED schedule of faults, indexed by STEP COUNT —
never wall clock — so the same (seed, horizon, rates) always injects the
same faults at the same points in a run, on any host speed (DESIGN.md
§11). The plan derives an independent per-replica sub-schedule from
`default_rng([seed, replica_index])`, so adding replicas never perturbs
existing ones.

Fault kinds:

  replica_crash    `step()` raises `InjectedFault` AND the wrapped
                   engine's in-flight requests are cancelled — a crash
                   loses engine state, exactly what a real process death
                   does; the scheduler must re-queue and recover.
  slot_stall       `step()` returns no events for `stall_steps`
                   consecutive steps (the engine stops producing tokens),
                   which is what the scheduler's stall hedging watches.
  slow_step        `step()` sleeps `slow_s` before running — latency
                   pressure without failure.
  retrieval_error  the Nth `answer_batch` call on a wrapped pipeline
                   raises — exercises the RagSession retry/failed path.

`ChaosEngine` wraps any engine-like (submit/step/available_slots/cancel)
and injects the replica-side kinds; `ChaosPipeline` wraps a RAG pipeline
and injects retrieval errors by call index. Both delegate everything else
untouched, so they drop into `SlotScheduler` / `RagSession` unchanged —
the harness behind the chaos soak test and `bench_serving --chaos`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by injected replica crashes / retrieval errors so tests can
    tell scripted chaos apart from real bugs."""


DEFAULT_RATES = {
    "replica_crash": 0.0,
    "slot_stall": 0.0,
    "slow_step": 0.0,
    "retrieval_error": 0.0,
}


@dataclass
class ReplicaFaults:
    """One replica's materialised schedule: step index -> fault kind
    (plus the stall window bookkeeping)."""
    crashes: frozenset
    stalls: frozenset                 # steps that BEGIN a stall window
    slows: frozenset
    stall_steps: int
    slow_s: float
    _stall_until: int = field(default=-1, compare=False)

    def at(self, step: int) -> Optional[str]:
        """The fault active at `step` (crash wins over stall over slow)."""
        if step in self.crashes:
            return "replica_crash"
        if step in self.stalls:
            self._stall_until = max(self._stall_until,
                                    step + self.stall_steps)
        if step < self._stall_until:
            return "slot_stall"
        if step in self.slows:
            return "slow_step"
        return None


class FaultPlan:
    """Seeded, step-indexed fault schedule over N replicas + a pipeline.

    `rates` maps fault kind -> per-step probability inside `[0, horizon)`;
    past the horizon the chaos tapers to nothing, so every run has a calm
    tail in which stragglers finish and drained replicas pass probation.
    The schedule for replica r depends only on (seed, r): replaying the
    same plan reproduces the same faults at the same step indices.
    """

    def __init__(self, seed: int = 0, *, horizon: int = 200,
                 rates: Optional[Dict[str, float]] = None,
                 stall_steps: int = 40, slow_s: float = 0.01):
        self.seed = seed
        self.horizon = horizon
        self.rates = dict(DEFAULT_RATES)
        if rates:
            unknown = set(rates) - set(DEFAULT_RATES)
            if unknown:
                raise ValueError(f"unknown fault kinds: {sorted(unknown)}")
            self.rates.update(rates)
        self.stall_steps = stall_steps
        self.slow_s = slow_s

    @classmethod
    def quick(cls, seed: int = 0) -> "FaultPlan":
        """The CI soak mix: crashes, stalls and slow steps frequent
        enough that a 3-replica run sees drains AND recoveries inside a
        short horizon."""
        return cls(seed, horizon=60,
                   rates={"replica_crash": 0.05, "slot_stall": 0.02,
                          "slow_step": 0.05, "retrieval_error": 0.15},
                   stall_steps=25, slow_s=0.005)

    def _steps(self, rng: np.random.Generator, kind: str) -> frozenset:
        hits = rng.random(self.horizon) < self.rates[kind]
        return frozenset(np.flatnonzero(hits).tolist())

    def replica(self, ridx: int) -> ReplicaFaults:
        """Materialise replica `ridx`'s independent sub-schedule."""
        rng = np.random.default_rng([self.seed, ridx])
        return ReplicaFaults(self._steps(rng, "replica_crash"),
                             self._steps(rng, "slot_stall"),
                             self._steps(rng, "slow_step"),
                             self.stall_steps, self.slow_s)

    def retrieval_errors(self) -> frozenset:
        """Call indices (0-based, per wrapped pipeline) whose
        `answer_batch` raises."""
        rng = np.random.default_rng([self.seed, 10_000])
        return self._steps(rng, "retrieval_error")


class ChaosEngine:
    """Engine-like wrapper injecting one replica's scheduled faults.

    Delegates every attribute to the wrapped engine; only `step()` is
    intercepted. The step counter is THIS wrapper's own — faults key on
    how often the scheduler drove this replica, which is deterministic
    under a deterministic driver."""

    def __init__(self, inner, plan: FaultPlan, ridx: int):
        self.inner = inner
        self.ridx = ridx
        self.faults = plan.replica(ridx)
        self.step_idx = 0
        self.injected: Dict[str, int] = {"replica_crash": 0,
                                         "slot_stall": 0, "slow_step": 0}

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def _emit_injected(self, kind: str) -> None:
        """One comp="chaos" instant per fired fault, into the wrapped
        engine's sink — so the trace checker can demand that every
        injected fault surfaces as a well-formed span chain."""
        sink = getattr(self.inner, "trace", None)
        if sink is not None:
            sink.emit("chaos", "injected",
                      src=getattr(self.inner, "trace_src", ""),
                      kind=kind, ridx=self.ridx, step=self.step_idx - 1,
                      inflight=len(getattr(self.inner, "_inflight", ())))

    def _crash(self) -> None:
        """A crash loses the engine's in-flight state: cancel everything
        (slots freed, requests forgotten) before raising — the scheduler
        must notice via the exception and re-queue its placements."""
        for rid in list(getattr(self.inner, "_inflight", {})):
            self.inner.cancel(rid)
        raise InjectedFault(
            f"replica {self.ridx} crash @ step {self.step_idx}")

    def step(self):
        fault = self.faults.at(self.step_idx)
        self.step_idx += 1
        if fault is not None:
            self.injected[fault] += 1
            self._emit_injected(fault)
        if fault == "replica_crash":
            self._crash()
        if fault == "slot_stall":
            return []                     # no progress: triggers hedging
        if fault == "slow_step":
            time.sleep(self.faults.slow_s)
        return self.inner.step()


class ChaosPipeline:
    """Pipeline wrapper injecting retrieval errors by `answer_batch`
    call index (step-indexed, deterministic). Everything else — including
    `_ensure_slm`, so RagSession construction works — delegates to the
    wrapped pipeline."""

    def __init__(self, inner, plan: FaultPlan,
                 trace: Optional[object] = None):
        self.inner = inner
        self.errors = plan.retrieval_errors()
        self.calls = 0
        self.injected = 0
        self.trace = trace            # optional shared TraceSink

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def answer_batch(self, queries, **kw):
        idx = self.calls
        self.calls += 1
        if idx in self.errors:
            self.injected += 1
            if self.trace is not None:
                self.trace.emit("chaos", "injected",
                                kind="retrieval_error", call=idx)
            raise InjectedFault(f"retrieval error @ call {idx}")
        return self.inner.answer_batch(queries, **kw)


def wrap_replicas(engines: List, plan: FaultPlan) -> List[ChaosEngine]:
    """Wrap each replica with its own deterministic sub-schedule."""
    return [ChaosEngine(e, plan, i) for i, e in enumerate(engines)]
