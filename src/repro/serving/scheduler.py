"""Request schedulers.

`SlotScheduler` is the request-centric path: N `ContinuousEngine`
replicas, slot admission instead of wave formation (a queued request goes
to the replica with the most free slots; the engines themselves admit on
EOS), and hedging on per-slot stall — a request that stops producing
tokens for `stall_s` while its replica is being stepped is re-submitted to
another replica, first completion wins; the stall budget re-arms after
every hedge, up to `max_hedges` placements per request.

Failure handling is built on the shared `dist.fault.HealthTracker`
strike/drain/probation state machine (the serve-side analogue of the
training-side RestartManager): a replica whose `step()` raises is struck
and its in-flight requests re-queued (an exception leaves engine state
unknown); at `max_strikes` it drains; a drained replica re-enters service
by passing one canary request after a cooldown (exponential backoff per
failed probe), and strikes decay on success so transient errors don't
accumulate into a drain. Requests carry optional deadlines — an expired
request is cancelled (its engine slots freed via `ContinuousEngine.cancel`)
and reported in `shed`, never silently lost — and the queue can be bounded
with a reject-or-degrade overflow policy. Every shed / degrade / failover
decision increments a `SchedCounters` field.

`Scheduler` keeps the legacy wave surface (length-bucketed waves over
engine callables with whole-wave deadline hedging) for generators without
a slot-paged engine.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.dist.fault import HealthConfig, HealthTracker
from repro.serving.trace import TraceSink

_SCHED_SEQ = [0]


@dataclass
class Request:
    """One queued wave-path request (legacy `Scheduler`)."""
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    submitted_s: float = field(default_factory=time.perf_counter)


@dataclass
class Completion:
    """One finished request: decoded tokens, the replica that won (first
    completion wins under hedging) and the submit->done latency."""
    rid: int
    tokens: List[int]
    replica: int
    latency_s: float
    hedged: bool = False


@dataclass
class Shed:
    """One request the scheduler explicitly gave up on (deadline expiry,
    queue overflow, or an engine-side refusal such as an oversize
    prompt). Together with `Completion`s these partition every submitted
    rid: nothing is ever silently lost."""
    rid: int
    reason: str                     # "deadline" | "queue_full" | "oversize"
    latency_s: float


@dataclass
class SchedCounters:
    """Every admission/shed/degrade/failover decision, counted."""
    submitted: int = 0
    completed: int = 0
    shed_deadline: int = 0
    shed_queue: int = 0
    shed_engine: int = 0
    degraded: int = 0
    hedges: int = 0
    strikes: int = 0
    drains: int = 0
    probes: int = 0
    recoveries: int = 0


@dataclass
class ReplicaState:
    """Health bookkeeping for one legacy wave replica. `warmed` marks the
    first successful dispatch: its wall time includes jit compilation, so
    it is excluded from the deadline check (a cold replica must not eat a
    spurious strike)."""
    healthy: bool = True
    strikes: int = 0
    served: int = 0
    warmed: bool = False


@dataclass
class ReplicaHealth:
    """SlotScheduler-side record for one replica: the shared
    HealthTracker state machine plus served-work and canary bookkeeping
    (`canary` is the scheduler rid probing this replica, if any)."""
    tracker: HealthTracker
    served: int = 0
    canary: Optional[int] = None

    @property
    def healthy(self) -> bool:
        return self.tracker.healthy

    @property
    def strikes(self) -> int:
        return self.tracker.strikes


@dataclass
class _SlotReq:
    """Scheduler-internal request state: per-replica placements (engine
    rids), progress timestamps for stall hedging, deadline, sampling
    mode. `hedges` is the ACTIVE hedge count — reset when the request is
    re-queued by a drain, so a rescued request can hedge again — while
    `ever_hedged` survives for the Completion report."""
    rid: int
    prompt: np.ndarray
    max_new: int
    submitted_s: float
    expires_s: Optional[float] = None
    # engine rid per replica currently decoding this request
    placements: Dict[int, int] = field(default_factory=dict)
    last_progress_s: float = 0.0
    hedges: int = 0
    last_hedge_s: float = 0.0
    ever_hedged: bool = False
    greedy: bool = True
    seed: int = 0


class SlotScheduler:
    """Slot-admission scheduling over ContinuousEngine replicas."""

    def __init__(self, engines: List, *, stall_s: float = 30.0,
                 max_strikes: int = 2, max_queue: Optional[int] = None,
                 overflow: str = "degrade", max_hedges: int = 2,
                 probe_cooldown_s: float = 0.25,
                 max_probes: Optional[int] = 8,
                 deadline_s: Optional[float] = None,
                 trace: Optional[TraceSink] = None):
        """engines: ContinuousEngine-likes (submit/step/available_slots,
        and ideally cancel). `stall_s`: per-slot stall budget — a placed
        request with no new token for this long (while its replica is
        stepped) is hedged to another replica, re-armed after each hedge
        up to `max_hedges`. `max_queue`: admission bound on the queue;
        `overflow="degrade"` halves an overflowing request's `max_new`
        (sheds outright past twice the bound), `overflow="reject"` sheds
        at the bound. `probe_cooldown_s`/`max_probes`: drained-replica
        probation (see dist.fault.HealthTracker). `deadline_s`: default
        per-request deadline (None = unbounded). `trace`: a shared
        TraceSink recording comp="sched" lifecycle + replica events
        (docs/OBSERVABILITY.md)."""
        assert overflow in ("degrade", "reject")
        self.engines = engines
        self.trace = trace
        self.trace_src = f"q{_SCHED_SEQ[0]}"
        _SCHED_SEQ[0] += 1
        hc = HealthConfig(max_strikes=max_strikes,
                          cooldown_s=probe_cooldown_s,
                          max_probes=max_probes)
        self.state = [ReplicaHealth(HealthTracker(hc)) for _ in engines]
        self.stall_s = stall_s
        self.max_queue = max_queue
        self.overflow = overflow
        self.max_hedges = max_hedges
        self.deadline_s = deadline_s
        self.queue: Deque[_SlotReq] = deque()
        self._live: Dict[int, _SlotReq] = {}
        self.shed: List[Shed] = []
        self.counters = SchedCounters()
        self._next_rid = 0

    def _emit(self, name: str, rid: int = -1, **attrs) -> None:
        if self.trace is not None:
            self.trace.emit("sched", name, rid, src=self.trace_src,
                            **attrs)

    def submit(self, prompt: np.ndarray, max_new: int = 32, *,
               greedy: bool = True, seed: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Queue one request; returns its scheduler rid. `deadline_s`
        (default: the scheduler-wide default) bounds submit->done wall
        time — an expired request is cancelled and reported in `shed`.
        When the queue is over `max_queue` the overflow policy applies:
        degrade (halved max_new; shed past 2x the bound) or reject.
        `greedy=False` samples on whichever replica hosts the request
        (per-request PRNG streams key on the ENGINE-assigned rid, so a
        hedged copy may draw a different — equally valid — sample; first
        completion still wins)."""
        rid = self._next_rid
        self._next_rid += 1
        self.counters.submitted += 1
        now = time.perf_counter()
        self._emit("queued", rid, max_new=max_new,
                   prompt_len=len(prompt))
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.overflow == "degrade" \
                    and len(self.queue) < 2 * self.max_queue:
                max_new = max(1, max_new // 2)
                self.counters.degraded += 1
                self._emit("degraded", rid, max_new=max_new)
            else:
                self.counters.shed_queue += 1
                self.shed.append(Shed(rid, "queue_full", 0.0))
                self._emit("shed", rid, reason="queue_full")
                return rid
        if deadline_s is None:
            deadline_s = self.deadline_s
        req = _SlotReq(rid, np.asarray(prompt, np.int32), max_new, now,
                       None if deadline_s is None else now + deadline_s,
                       greedy=greedy, seed=seed)
        self.queue.append(req)
        self._live[rid] = req
        return rid

    def _healthy(self) -> List[int]:
        """Indices of replicas fully in service (probing excluded — they
        carry only their canary until it completes)."""
        return [i for i, s in enumerate(self.state) if s.healthy]

    def _cancel_placement(self, ridx: int, erid: int) -> None:
        """Best-effort engine-side cancel: frees the slot on engines that
        support it; a broken/legacy engine just keeps the stale rid
        (whose events no longer match any placement and are dropped)."""
        eng = self.engines[ridx]
        if hasattr(eng, "cancel"):
            try:
                eng.cancel(erid)
            except Exception:
                pass

    def _requeue_placements(self, ridx: int) -> None:
        """Pull every request placed on `ridx` back off it (cancelling
        engine-side state best-effort); requests left with no placement
        re-queue at the FRONT with a fresh hedging budget."""
        for req in list(self._live.values()):
            erid = req.placements.pop(ridx, None)
            if erid is None:
                continue
            self._cancel_placement(ridx, erid)
            self._emit("requeue", req.rid, replica=ridx)
            if not req.placements:
                req.hedges = 0
                self.queue.appendleft(req)

    def _strike(self, ridx: int) -> None:
        """One failure strike through the HealthTracker; a drain (at
        max_strikes, or any probe failure) re-queues in-flight work."""
        self.counters.strikes += 1
        self._emit("strike", replica=ridx,
                   strikes=self.state[ridx].strikes + 1)
        h = self.state[ridx]
        if h.tracker.record_failure():
            self.counters.drains += 1
            self._emit("drain", replica=ridx)
            h.canary = None
            self._requeue_placements(ridx)

    def _expire(self, now: float) -> None:
        """Shed every live request past its deadline: cancel its engine
        placements (slots freed), drop it from queue/live, and record the
        shed — expiry is a terminal state, never a silent loss."""
        for req in list(self._live.values()):
            if req.expires_s is None or now <= req.expires_s:
                continue
            for ridx, erid in req.placements.items():
                self._cancel_placement(ridx, erid)
            try:
                self.queue.remove(req)
            except ValueError:
                pass
            del self._live[req.rid]
            self.counters.shed_deadline += 1
            self.shed.append(Shed(req.rid, "deadline",
                                  now - req.submitted_s))
            self._emit("shed", req.rid, reason="deadline")

    def _place(self, req: _SlotReq, ridx: int) -> None:
        """Submit `req` to replica `ridx` and record the placement.
        Sampling kwargs are only forwarded for sampled requests so
        greedy scheduling keeps working against any engine-like with a
        plain `submit(prompt, max_new)` signature."""
        eng = self.engines[ridx]
        if req.greedy:
            erid = eng.submit(req.prompt, req.max_new)
        else:
            erid = eng.submit(req.prompt, req.max_new, greedy=False,
                              seed=req.seed)
        req.placements[ridx] = erid
        req.last_progress_s = time.perf_counter()
        self._emit("placed", req.rid, replica=ridx, erid=erid)

    def _probe(self) -> None:
        """Drained-replica probation: a replica whose cooldown elapsed
        gets ONE canary (the head of the queue) — completing it recovers
        the replica to full service; failing it (step raise, or the
        canary resolving elsewhere) backs the cooldown off."""
        for ridx, h in enumerate(self.state):
            t = h.tracker
            if t.state == HealthTracker.PROBING:
                if h.canary is not None and h.canary not in self._live:
                    # canary completed on another replica or expired:
                    # this probe proved nothing — drain again, back off
                    t.record_failure()
                    h.canary = None
                    self._requeue_placements(ridx)
                continue
            if not self.queue or not t.probe_due():
                continue
            if self.engines[ridx].available_slots() <= 0:
                continue
            t.begin_probe()
            self.counters.probes += 1
            self._emit("probe", replica=ridx)
            req = self.queue.popleft()
            h.canary = req.rid
            try:
                self._place(req, ridx)
            except Exception:
                self._strike(ridx)            # probe failed at submit
                req.hedges = 0
                self.queue.appendleft(req)

    def _admit(self) -> None:
        """Queued requests go to the healthy replica with most free slots
        (admission happens slot-by-slot as engines free them on EOS)."""
        while self.queue:
            healthy = [i for i in self._healthy()
                       if self.engines[i].available_slots() > 0]
            if not healthy:
                return
            ridx = max(healthy,
                       key=lambda i: self.engines[i].available_slots())
            self._place(self.queue.popleft(), ridx)

    def _hedge_stalled(self) -> None:
        """Re-place requests with no progress for `stall_s` on another
        replica (first completion wins); the stalled replicas are struck.
        The budget re-arms after every hedge, so a request whose hedge
        target ALSO stalls can hedge again, up to `max_hedges`."""
        now = time.perf_counter()
        for req in list(self._live.values()):
            if not req.placements or req.hedges >= self.max_hedges:
                continue
            if now - max(req.last_progress_s, req.last_hedge_s) \
                    <= self.stall_s:
                continue
            targets = [i for i in self._healthy()
                       if i not in req.placements]
            if not targets:
                continue
            stalled = list(req.placements)
            ridx = max(targets,
                       key=lambda i: self.engines[i].available_slots())
            req.hedges += 1
            req.ever_hedged = True
            req.last_hedge_s = now
            self.counters.hedges += 1
            self._emit("hedge", req.rid, replica=ridx,
                       stalled=list(stalled))
            self._place(req, ridx)
            for s in stalled:
                self._strike(s)

    def _on_done(self, ridx: int, req: _SlotReq, ev,
                 done: List[Completion]) -> None:
        """First completion wins: cancel the other placements (hedges),
        retire the request, credit the replica (strike decay; probation
        canaries recover their replica here)."""
        for oidx, oerid in req.placements.items():
            if oidx != ridx:
                self._cancel_placement(oidx, oerid)
        self._live.pop(req.rid, None)
        h = self.state[ridx]
        h.served += 1
        self.counters.completed += 1
        if h.tracker.record_success():
            self.counters.recoveries += 1
            self._emit("recover", replica=ridx)
        if h.canary == req.rid:
            h.canary = None
        self._emit("done", req.rid, replica=ridx,
                   n_tokens=len(ev.result.tokens),
                   hedged=req.ever_hedged)
        done.append(Completion(req.rid, list(ev.result.tokens), ridx,
                               time.perf_counter() - req.submitted_s,
                               req.ever_hedged))

    def _on_shed(self, ridx: int, req: _SlotReq, ev) -> None:
        """An engine refused this placement (e.g. oversize prompt: its
        pages can never fit the replica's table width). The refusal is
        deterministic across identical replicas, so when no hedged
        placement remains the request is terminally shed — re-queueing
        it would loop forever — and recorded, never silently lost."""
        req.placements.pop(ridx, None)
        if req.placements:
            return                    # a hedged copy may still finish
        self._live.pop(req.rid, None)
        h = self.state[ridx]
        if h.canary == req.rid:       # a shed canary proves liveness too
            h.canary = None
            if h.tracker.record_success():
                self.counters.recoveries += 1
                self._emit("recover", replica=ridx)
        self.counters.shed_engine += 1
        self.shed.append(Shed(req.rid, ev.reason or "engine",
                              time.perf_counter() - req.submitted_s))
        self._emit("shed", req.rid, reason=ev.reason or "engine")

    def _idle(self) -> None:
        """Nothing progressed this pass. Benign while prefill chunks are
        mid-flight or a probe cooldown is pending; fatal when no replica
        can ever serve again or a live request is unreachable."""
        trackers = [h.tracker for h in self.state]
        if all(t.state == HealthTracker.DRAINED for t in trackers):
            if all(t.exhausted for t in trackers):
                raise RuntimeError(
                    "all replicas unhealthy (probe budget exhausted)")
            time.sleep(0.002)                 # wait out a probe cooldown
        elif self._live and not self.queue \
                and not any(r.placements for r in self._live.values()):
            raise RuntimeError("requests stuck with no placement")

    def run(self) -> List[Completion]:
        """Drain the queue; returns completions in finish order. Every
        submitted request ends in exactly one terminal state: a
        Completion here, or an entry in `self.shed` (deadline expiry /
        queue overflow) — chaos may delay requests, never strand them."""
        done: List[Completion] = []
        while self._live:
            self._expire(time.perf_counter())
            if not self._live:
                break
            self._probe()
            self._admit()
            self._hedge_stalled()
            progressed = False
            for ridx, h in enumerate(self.state):
                if h.tracker.state == HealthTracker.DRAINED:
                    continue
                try:
                    events = self.engines[ridx].step()
                except Exception:
                    # an exception mid-step leaves engine state unknown:
                    # strike AND re-queue its placements either way
                    self._strike(ridx)
                    self._requeue_placements(ridx)
                    continue
                for ev in events:
                    req = next((r for r in self._live.values()
                                if r.placements.get(ridx) == ev.rid), None)
                    if req is None:
                        continue          # stale/hedged rid: dropped
                    progressed = True
                    req.last_progress_s = time.perf_counter()
                    if ev.kind == "done":
                        self._on_done(ridx, req, ev, done)
                    elif ev.kind == "shed":
                        self._on_shed(ridx, req, ev)
            if not progressed:
                self._idle()
        return done


class Scheduler:
    """Legacy wave scheduler: length-bucketed waves over engine
    callables with whole-wave deadline/failure hedging — kept for
    generators without a slot-paged engine (see SlotScheduler for the
    request-centric path)."""

    def __init__(self, replicas: List[Callable], *, max_wave: int = 8,
                 deadline_s: float = 60.0, max_strikes: int = 2):
        """replicas: callables (prompts, max_new) -> list of token lists.
        A replica that raises or exceeds the deadline gets a strike —
        except its FIRST successful dispatch, whose wall time includes
        jit compilation and is exempt from the deadline check."""
        self.replicas = replicas
        self.state = [ReplicaState() for _ in replicas]
        self.max_wave = max_wave
        self.deadline_s = deadline_s
        self.max_strikes = max_strikes
        self.queue: List[Request] = []
        self._next_rid = 0

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        """Queue one request; returns its rid (wave path is greedy-only —
        it predates per-request PRNG streams)."""
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32), max_new))
        return rid

    def _healthy(self) -> List[int]:
        """Indices of replicas still accepting work."""
        return [i for i, s in enumerate(self.state) if s.healthy]

    def _form_wave(self) -> List[Request]:
        """Take up to max_wave equal-length requests (largest length
        bucket first) off the queue."""
        if not self.queue:
            return []
        # bucket by prompt length; take the largest bucket first
        buckets: Dict[int, List[Request]] = {}
        for r in self.queue:
            buckets.setdefault(len(r.prompt), []).append(r)
        length = max(buckets, key=lambda k: len(buckets[k]))
        wave = buckets[length][: self.max_wave]
        for r in wave:
            self.queue.remove(r)
        return wave

    def _dispatch(self, wave: List[Request], ridx: int,
                  hedged: bool) -> Optional[List[Completion]]:
        """Run one wave on replica `ridx`; None (plus a strike) on
        failure or deadline overrun — the caller re-dispatches. A cold
        replica's first successful dispatch pays jit compile time, so
        only WARMED replicas can overrun the deadline: strikes reflect
        real overruns, not first-call compilation."""
        t0 = time.perf_counter()
        st = self.state[ridx]
        try:
            outs = self.replicas[ridx]([r.prompt for r in wave],
                                       max(r.max_new for r in wave))
        except Exception:
            st.strikes += 1
            if st.strikes >= self.max_strikes:
                st.healthy = False
            return None
        dt = time.perf_counter() - t0
        if dt > self.deadline_s and st.warmed:
            st.strikes += 1
            if st.strikes >= self.max_strikes:
                st.healthy = False
            return None  # hedge: caller re-dispatches
        st.warmed = True
        st.served += len(wave)
        return [Completion(r.rid, list(o), ridx,
                           time.perf_counter() - r.submitted_s, hedged)
                for r, o in zip(wave, outs)]

    def run(self) -> List[Completion]:
        """Drain the queue wave by wave (round-robin over healthy
        replicas, re-dispatching failed/overdue waves)."""
        done: List[Completion] = []
        rr = 0
        while self.queue:
            wave = self._form_wave()
            if not wave:
                break
            healthy = self._healthy()
            if not healthy:
                raise RuntimeError("all replicas unhealthy")
            tried = []
            completed = None
            hedged = False
            for attempt in range(len(healthy)):
                ridx = healthy[(rr + attempt) % len(healthy)]
                if ridx in tried:
                    continue
                tried.append(ridx)
                completed = self._dispatch(wave, ridx, hedged)
                if completed is not None:
                    break
                hedged = True  # re-dispatch to the next replica
            rr += 1
            if completed is None:
                raise RuntimeError("wave failed on every healthy replica")
            done.extend(completed)
        return done
