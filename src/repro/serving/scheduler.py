"""Request scheduler: dynamic length-bucketed batching, latency budgets,
hedged re-dispatch (straggler mitigation), replica failover.

Model: N replicas (engine callables). Requests are queued; the scheduler
forms waves per replica. If a replica misses its p99 deadline, the wave is
re-dispatched to a healthy replica (the first response wins); replicas
that miss `max_strikes` deadlines are marked unhealthy and drained — the
serve-side analogue of the training-side RestartManager.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    submitted_s: float = field(default_factory=time.perf_counter)


@dataclass
class Completion:
    rid: int
    tokens: List[int]
    replica: int
    latency_s: float
    hedged: bool = False


@dataclass
class ReplicaState:
    healthy: bool = True
    strikes: int = 0
    served: int = 0


class Scheduler:
    def __init__(self, replicas: List[Callable], *, max_wave: int = 8,
                 deadline_s: float = 60.0, max_strikes: int = 2):
        """replicas: callables (prompts, max_new) -> list of token lists.
        A replica that raises or exceeds the deadline gets a strike."""
        self.replicas = replicas
        self.state = [ReplicaState() for _ in replicas]
        self.max_wave = max_wave
        self.deadline_s = deadline_s
        self.max_strikes = max_strikes
        self.queue: List[Request] = []
        self._next_rid = 0

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32), max_new))
        return rid

    def _healthy(self) -> List[int]:
        return [i for i, s in enumerate(self.state) if s.healthy]

    def _form_wave(self) -> List[Request]:
        if not self.queue:
            return []
        # bucket by prompt length; take the largest bucket first
        buckets: Dict[int, List[Request]] = {}
        for r in self.queue:
            buckets.setdefault(len(r.prompt), []).append(r)
        length = max(buckets, key=lambda k: len(buckets[k]))
        wave = buckets[length][: self.max_wave]
        for r in wave:
            self.queue.remove(r)
        return wave

    def _dispatch(self, wave: List[Request], ridx: int,
                  hedged: bool) -> Optional[List[Completion]]:
        t0 = time.perf_counter()
        try:
            outs = self.replicas[ridx]([r.prompt for r in wave],
                                       max(r.max_new for r in wave))
        except Exception:
            self.state[ridx].strikes += 1
            if self.state[ridx].strikes >= self.max_strikes:
                self.state[ridx].healthy = False
            return None
        dt = time.perf_counter() - t0
        if dt > self.deadline_s:
            self.state[ridx].strikes += 1
            if self.state[ridx].strikes >= self.max_strikes:
                self.state[ridx].healthy = False
            return None  # hedge: caller re-dispatches
        self.state[ridx].served += len(wave)
        return [Completion(r.rid, list(o), ridx,
                           time.perf_counter() - r.submitted_s, hedged)
                for r, o in zip(wave, outs)]

    def run(self) -> List[Completion]:
        done: List[Completion] = []
        rr = 0
        while self.queue:
            wave = self._form_wave()
            if not wave:
                break
            healthy = self._healthy()
            if not healthy:
                raise RuntimeError("all replicas unhealthy")
            tried = []
            completed = None
            hedged = False
            for attempt in range(len(healthy)):
                ridx = healthy[(rr + attempt) % len(healthy)]
                if ridx in tried:
                    continue
                tried.append(ridx)
                completed = self._dispatch(wave, ridx, hedged)
                if completed is not None:
                    break
                hedged = True  # re-dispatch to the next replica
            rr += 1
            if completed is None:
                raise RuntimeError("wave failed on every healthy replica")
            done.extend(completed)
        return done
