"""Request schedulers.

`SlotScheduler` is the request-centric path: N `ContinuousEngine`
replicas, slot admission instead of wave formation (a queued request goes
to the replica with the most free slots; the engines themselves admit on
EOS), and hedging on per-slot stall — a request that stops producing
tokens for `stall_s` while its replica is being stepped is re-submitted to
another replica, first completion wins. A replica whose `step()` raises is
drained: its in-flight requests re-queue and it is marked unhealthy — the
serve-side analogue of the training-side RestartManager.

`Scheduler` keeps the legacy wave surface (length-bucketed waves over
engine callables with whole-wave deadline hedging) for generators without
a slot-paged engine.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import numpy as np


@dataclass
class Request:
    """One queued wave-path request (legacy `Scheduler`)."""
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    submitted_s: float = field(default_factory=time.perf_counter)


@dataclass
class Completion:
    """One finished request: decoded tokens, the replica that won (first
    completion wins under hedging) and the submit->done latency."""
    rid: int
    tokens: List[int]
    replica: int
    latency_s: float
    hedged: bool = False


@dataclass
class ReplicaState:
    """Scheduler-side health bookkeeping for one replica."""
    healthy: bool = True
    strikes: int = 0
    served: int = 0


@dataclass
class _SlotReq:
    """Scheduler-internal request state: per-replica placements (engine
    rids), progress timestamps for stall hedging, sampling mode."""
    rid: int
    prompt: np.ndarray
    max_new: int
    submitted_s: float
    # engine rid per replica currently decoding this request
    placements: Dict[int, int] = field(default_factory=dict)
    last_progress_s: float = 0.0
    hedged: bool = False
    greedy: bool = True
    seed: int = 0


class SlotScheduler:
    """Slot-admission scheduling over ContinuousEngine replicas."""

    def __init__(self, engines: List, *, stall_s: float = 30.0,
                 max_strikes: int = 2):
        """engines: ContinuousEngine-likes (submit/step/available_slots).
        `stall_s`: per-slot stall budget — a placed request with no new
        token for this long (while its replica is stepped) is hedged to
        another replica."""
        self.engines = engines
        self.state = [ReplicaState() for _ in engines]
        self.stall_s = stall_s
        self.max_strikes = max_strikes
        self.queue: Deque[_SlotReq] = deque()
        self._live: Dict[int, _SlotReq] = {}
        self._next_rid = 0

    def submit(self, prompt: np.ndarray, max_new: int = 32, *,
               greedy: bool = True, seed: int = 0) -> int:
        """Queue one request; returns its scheduler rid. `greedy=False`
        samples on whichever replica hosts it (per-request PRNG streams
        are keyed by the ENGINE-assigned rid, so a hedged copy on a
        second replica may draw a different — equally valid — sample;
        first completion still wins)."""
        rid = self._next_rid
        self._next_rid += 1
        req = _SlotReq(rid, np.asarray(prompt, np.int32), max_new,
                       time.perf_counter(), greedy=greedy, seed=seed)
        self.queue.append(req)
        self._live[rid] = req
        return rid

    def _healthy(self) -> List[int]:
        """Indices of replicas still accepting work."""
        return [i for i, s in enumerate(self.state) if s.healthy]

    def _strike(self, ridx: int) -> None:
        """One failure strike; at max_strikes the replica is drained."""
        self.state[ridx].strikes += 1
        if self.state[ridx].strikes >= self.max_strikes:
            self._drain(ridx)

    def _drain(self, ridx: int) -> None:
        """Mark a replica unhealthy and re-queue its in-flight requests."""
        self.state[ridx].healthy = False
        for req in list(self._live.values()):
            if req.placements.pop(ridx, None) is not None \
                    and not req.placements:
                self.queue.appendleft(req)

    def _place(self, req: _SlotReq, ridx: int) -> None:
        """Submit `req` to replica `ridx` and record the placement.
        Sampling kwargs are only forwarded for sampled requests so
        greedy scheduling keeps working against any engine-like with a
        plain `submit(prompt, max_new)` signature."""
        eng = self.engines[ridx]
        if req.greedy:
            erid = eng.submit(req.prompt, req.max_new)
        else:
            erid = eng.submit(req.prompt, req.max_new, greedy=False,
                              seed=req.seed)
        req.placements[ridx] = erid
        req.last_progress_s = time.perf_counter()

    def _admit(self) -> None:
        """Queued requests go to the healthy replica with most free slots
        (admission happens slot-by-slot as engines free them on EOS)."""
        while self.queue:
            healthy = [i for i in self._healthy()
                       if self.engines[i].available_slots() > 0]
            if not healthy:
                if not self._healthy():
                    raise RuntimeError("all replicas unhealthy")
                return
            ridx = max(healthy,
                       key=lambda i: self.engines[i].available_slots())
            self._place(self.queue.popleft(), ridx)

    def _hedge_stalled(self) -> None:
        """Re-place requests with no progress for `stall_s` on another
        replica (first completion wins); the stalled replica is struck."""
        now = time.perf_counter()
        for req in self._live.values():
            if not req.placements or req.hedged:
                continue
            if now - req.last_progress_s <= self.stall_s:
                continue
            targets = [i for i in self._healthy()
                       if i not in req.placements]
            if targets:
                stalled = list(req.placements)
                ridx = max(targets,
                           key=lambda i: self.engines[i].available_slots())
                req.hedged = True
                self._place(req, ridx)
                for s in stalled:
                    self._strike(s)

    def run(self) -> List[Completion]:
        """Drain the queue; returns completions in finish order."""
        done: List[Completion] = []
        while self._live:
            self._admit()
            self._hedge_stalled()
            progressed = False
            for ridx in self._healthy():
                eng = self.engines[ridx]
                try:
                    events = eng.step()
                except Exception:
                    self._strike(ridx)
                    self._drain(ridx)
                    continue
                for ev in events:
                    req = next((r for r in self._live.values()
                                if r.placements.get(ridx) == ev.rid), None)
                    if req is None:
                        continue
                    progressed = True
                    req.last_progress_s = time.perf_counter()
                    if ev.kind == "done":
                        # first completion wins; other placements (hedges)
                        # keep decoding and their events are dropped above
                        self._live.pop(req.rid, None)
                        self.state[ridx].served += 1
                        done.append(Completion(
                            req.rid, list(ev.result.tokens), ridx,
                            time.perf_counter() - req.submitted_s,
                            req.hedged))
            if not progressed and not self.queue and self._live \
                    and not any(r.placements for r in self._live.values()):
                raise RuntimeError("requests stuck with no placement")
        return done


class Scheduler:
    """Legacy wave scheduler: length-bucketed waves over engine
    callables with whole-wave deadline/failure hedging — kept for
    generators without a slot-paged engine (see SlotScheduler for the
    request-centric path)."""

    def __init__(self, replicas: List[Callable], *, max_wave: int = 8,
                 deadline_s: float = 60.0, max_strikes: int = 2):
        """replicas: callables (prompts, max_new) -> list of token lists.
        A replica that raises or exceeds the deadline gets a strike."""
        self.replicas = replicas
        self.state = [ReplicaState() for _ in replicas]
        self.max_wave = max_wave
        self.deadline_s = deadline_s
        self.max_strikes = max_strikes
        self.queue: List[Request] = []
        self._next_rid = 0

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        """Queue one request; returns its rid (wave path is greedy-only —
        it predates per-request PRNG streams)."""
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32), max_new))
        return rid

    def _healthy(self) -> List[int]:
        """Indices of replicas still accepting work."""
        return [i for i, s in enumerate(self.state) if s.healthy]

    def _form_wave(self) -> List[Request]:
        """Take up to max_wave equal-length requests (largest length
        bucket first) off the queue."""
        if not self.queue:
            return []
        # bucket by prompt length; take the largest bucket first
        buckets: Dict[int, List[Request]] = {}
        for r in self.queue:
            buckets.setdefault(len(r.prompt), []).append(r)
        length = max(buckets, key=lambda k: len(buckets[k]))
        wave = buckets[length][: self.max_wave]
        for r in wave:
            self.queue.remove(r)
        return wave

    def _dispatch(self, wave: List[Request], ridx: int,
                  hedged: bool) -> Optional[List[Completion]]:
        """Run one wave on replica `ridx`; None (plus a strike) on
        failure or deadline overrun — the caller re-dispatches."""
        t0 = time.perf_counter()
        try:
            outs = self.replicas[ridx]([r.prompt for r in wave],
                                       max(r.max_new for r in wave))
        except Exception:
            self.state[ridx].strikes += 1
            if self.state[ridx].strikes >= self.max_strikes:
                self.state[ridx].healthy = False
            return None
        dt = time.perf_counter() - t0
        if dt > self.deadline_s:
            self.state[ridx].strikes += 1
            if self.state[ridx].strikes >= self.max_strikes:
                self.state[ridx].healthy = False
            return None  # hedge: caller re-dispatches
        self.state[ridx].served += len(wave)
        return [Completion(r.rid, list(o), ridx,
                           time.perf_counter() - r.submitted_s, hedged)
                for r, o in zip(wave, outs)]

    def run(self) -> List[Completion]:
        """Drain the queue wave by wave (round-robin over healthy
        replicas, re-dispatching failed/overdue waves)."""
        done: List[Completion] = []
        rr = 0
        while self.queue:
            wave = self._form_wave()
            if not wave:
                break
            healthy = self._healthy()
            if not healthy:
                raise RuntimeError("all replicas unhealthy")
            tried = []
            completed = None
            hedged = False
            for attempt in range(len(healthy)):
                ridx = healthy[(rr + attempt) % len(healthy)]
                if ridx in tried:
                    continue
                tried.append(ridx)
                completed = self._dispatch(wave, ridx, hedged)
                if completed is not None:
                    break
                hedged = True  # re-dispatch to the next replica
            rr += 1
            if completed is None:
                raise RuntimeError("wave failed on every healthy replica")
            done.extend(completed)
        return done
