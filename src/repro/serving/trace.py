"""Per-request span tracing + the SLO admission controller built on it.

One taxonomy for every request-visible state change in the serving
stack, recorded as structured, monotonically-timestamped records in an
OTel-flavoured schema (docs/OBSERVABILITY.md):

    comp="engine"   queued -> admitted -> prefill_chunk* -> first_token
                    -> token* -> done | shed | cancelled
    comp="session"  queued -> retrieved -> condensed
                    -> done | shed | failed   (+ degraded instants)
    comp="sched"    queued -> placed*/requeue* -> done | shed
                    (+ replica-level hedge/strike/drain/probe/recover)
    comp="pager"    prefix_hit / cow_fork instants + page_stats snapshots
    comp="chaos"    injected (one per fault the harness fired)

Every record carries (seq, ts, comp, src, rid, name, ph, attrs): `seq`
is a sink-assigned monotone sequence number, `ts` a monotone
perf_counter timestamp (clamped so the record stream is ordered even if
the clock hiccups), `src` the emitting component instance (engine
replicas share one sink without rid collisions), `rid` the request id in
the component's namespace (-1 for component-level records), and `ph` the
phase: "I" instant, or "B"/"E" bracketing a span (prefill_chunk,
decode_step, retrieve). In OTel terms: comp+src is the instrumentation
scope, rid the trace id, name the span name, B/E the span boundaries.

`TraceSink` is a bounded ring buffer (oldest records evicted, counted in
`evicted`) that is exportable to JSONL (`export_jsonl`) and queryable
in-process (`query`, `durations`, `percentile`). Recording is pure
host-side bookkeeping — a deque append — so tracing NEVER touches device
state: tokens are bit-identical with a sink attached or not
(tests/test_paged_families.py, tests/test_pager.py), and the overhead
gate in `bench_serving --trace-overhead` keeps it under 5% p50.

`SLOController` turns the live trace window into admission decisions:
it estimates a request's end-to-end cost from observed p95 stage costs
(per-query retrieval, prefill chunk, per-token decode step) and plans a
degrade ladder — clamp max_new, shrink retrieve_chunk, reduce n_probe —
before recommending a shed, so overload degrades answer quality before
it degrades availability (DESIGN.md §15). With no samples yet it always
admits: the controller never sheds blind.

tools/trace_check.py is the other half of the contract: the trace is a
correctness ORACLE, not just logging — lifecycle order, orphan spans,
exactly-one-terminal and page accounting are machine-checked over any
sink or JSONL export.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional

# Request lifecycle taxonomy. Terminal names are shared by every comp;
# which non-terminal names a comp may emit (and their order) is encoded
# in tools/trace_check.py's per-comp rules.
TERMINALS = ("done", "shed", "failed", "cancelled")


@dataclass
class TraceRecord:
    """One trace record (see module docstring for the schema)."""
    seq: int
    ts: float
    comp: str
    src: str
    rid: int
    name: str
    ph: str = "I"                 # "I" instant | "B" span begin | "E" end
    attrs: Dict[str, object] = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"seq": self.seq, "ts": self.ts, "comp": self.comp,
                "src": self.src, "rid": self.rid, "name": self.name,
                "ph": self.ph, "attrs": self.attrs}

    @classmethod
    def from_dict(cls, d: dict) -> "TraceRecord":
        return cls(int(d["seq"]), float(d["ts"]), d["comp"],
                   d.get("src", ""), int(d.get("rid", -1)), d["name"],
                   d.get("ph", "I"), dict(d.get("attrs") or {}))


class TraceSink:
    """Bounded ring buffer of TraceRecords, shared by every component of
    one serving stack (engines, session, scheduler, chaos wrappers)."""

    def __init__(self, capacity: int = 65536,
                 clock: Callable[[], float] = time.perf_counter):
        self.capacity = capacity
        self.clock = clock
        self._buf: deque = deque(maxlen=capacity)
        self._seq = 0
        self._last_ts = 0.0
        self.evicted = 0

    def __len__(self) -> int:
        return len(self._buf)

    # ------------------------------------------------------------ record

    def emit(self, comp: str, name: str, rid: int = -1, *, src: str = "",
             ph: str = "I", **attrs) -> TraceRecord:
        """Append one record. Timestamps are clamped monotone so the
        record stream is ordered by (seq, ts) even across clock quirks —
        the invariant tools/trace_check.py verifies first."""
        ts = self.clock()
        if ts < self._last_ts:
            ts = self._last_ts
        self._last_ts = ts
        rec = TraceRecord(self._seq, ts, comp, src, rid, name, ph, attrs)
        self._seq += 1
        if len(self._buf) == self.capacity:
            self.evicted += 1
        self._buf.append(rec)
        return rec

    @contextmanager
    def span(self, comp: str, name: str, rid: int = -1, *, src: str = "",
             **attrs):
        """Bracket a stage with B/E records (one span = one B + one E
        with the same (comp, src, name, rid) key)."""
        self.emit(comp, name, rid, src=src, ph="B", **attrs)
        try:
            yield
        finally:
            self.emit(comp, name, rid, src=src, ph="E")

    # ------------------------------------------------------------- query

    def records(self) -> List[TraceRecord]:
        """Snapshot of the buffer, oldest first."""
        return list(self._buf)

    def query(self, *, comp: Optional[str] = None,
              rid: Optional[int] = None, name: Optional[str] = None,
              src: Optional[str] = None) -> List[TraceRecord]:
        return [r for r in self._buf
                if (comp is None or r.comp == comp)
                and (rid is None or r.rid == rid)
                and (name is None or r.name == name)
                and (src is None or r.src == src)]

    def durations(self, comp: str, name: str, *,
                  window: Optional[int] = None) -> List[float]:
        """Completed span durations for (comp, name), oldest first,
        aggregated across src instances; `window` keeps only the most
        recent N (the "live trace window" the SLO controller reads)."""
        open_b: Dict[tuple, float] = {}
        out: List[float] = []
        for r in self._buf:
            if r.comp != comp or r.name != name:
                continue
            key = (r.src, r.rid)
            if r.ph == "B":
                open_b[key] = r.ts
            elif r.ph == "E" and key in open_b:
                out.append(r.ts - open_b.pop(key))
        return out[-window:] if window else out

    def percentile(self, comp: str, name: str, q: float = 95.0, *,
                   window: int = 256,
                   default: Optional[float] = None) -> Optional[float]:
        """q-th percentile of the last `window` completed (comp, name)
        span durations; `default` when no span completed yet."""
        ds = self.durations(comp, name, window=window)
        if not ds:
            return default
        ds = sorted(ds)
        idx = min(len(ds) - 1, int(round(q / 100.0 * (len(ds) - 1))))
        return ds[idx]

    # ------------------------------------------------------------ export

    def export_jsonl(self, path) -> int:
        """Write the buffer as JSON-lines; returns the record count."""
        recs = self.records()
        with open(path, "w") as f:
            for r in recs:
                f.write(json.dumps(r.to_dict(), default=str) + "\n")
        return len(recs)


def load_jsonl(path) -> List[TraceRecord]:
    """Read a TraceSink JSONL export back into records."""
    out: List[TraceRecord] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(TraceRecord.from_dict(json.loads(line)))
    return out


# --------------------------------------------------------------- SLO plan


@dataclass
class SLOPlan:
    """One admission decision: "admit" unchanged, "degrade" with the
    reduced knobs carried here, or "shed" (even the floor configuration
    cannot meet the budget). `est_s` is the p95-based cost estimate the
    decision was made on (None = no data, always admit)."""
    action: str
    max_new: int
    retrieve_chunk: int
    n_probe: int
    est_s: Optional[float] = None


class SLOController:
    """Plans the degrade-before-shed ladder from live trace p95s.

    Cost model per request, all terms p95 over the last `window`
    completed spans of the shared sink:

        retrieve_per_query = p95(session.retrieve) / mean chunk size
        prefill            = chunks(prompt) * p95(engine.prefill_chunk)
        decode             = max_new * p95(engine.decode_step)

    (one decode step emits one token per active slot, so the per-token
    cost IS the step cost). A missing term (cold window) disables the
    estimate and the plan is "admit" — the controller never sheds on no
    evidence. The ladder, in order: clamp max_new to what fits the
    budget after retrieval+prefill; shrink this step's retrieve_chunk;
    halve n_probe (floor `min_probe`). If the floor configuration
    (1 token, chunk 1, min probes) still exceeds the budget: "shed"."""

    def __init__(self, sink: TraceSink, *, window: int = 128,
                 min_tokens: int = 1, min_chunk: int = 1,
                 min_probe: int = 1):
        self.sink = sink
        self.window = window
        self.min_tokens = min_tokens
        self.min_chunk = min_chunk
        self.min_probe = min_probe

    # ------------------------------------------------------- stage costs

    def stage_costs(self) -> Dict[str, Optional[float]]:
        """p95 cost of each serving stage from the live trace window."""
        s = self.sink
        ret = None
        spans = s.durations("session", "retrieve", window=self.window)
        if spans:
            # retrieve spans carry chunk size in their B record attrs
            ns = [r.attrs.get("n", 1) for r in s.records()
                  if r.comp == "session" and r.name == "retrieve"
                  and r.ph == "B"][-len(spans):]
            per_q = sorted(d / max(int(n), 1) for d, n in zip(spans, ns))
            idx = min(len(per_q) - 1, int(round(0.95 * (len(per_q) - 1))))
            ret = per_q[idx]
        return {
            "retrieve_per_query_s": ret,
            "prefill_chunk_s": s.percentile("engine", "prefill_chunk",
                                            window=self.window),
            "decode_step_s": s.percentile("engine", "decode_step",
                                          window=self.window),
        }

    def estimate(self, max_new: int, *, prompt_chunks: int = 2,
                 costs: Optional[Dict[str, Optional[float]]] = None
                 ) -> Optional[float]:
        """p95-based end-to-end cost of one request, or None while any
        stage has no completed span in the window."""
        c = costs or self.stage_costs()
        ret, pre, dec = (c["retrieve_per_query_s"], c["prefill_chunk_s"],
                         c["decode_step_s"])
        if ret is None or pre is None or dec is None:
            return None
        return ret + prompt_chunks * pre + max_new * dec

    # ------------------------------------------------------------- plan

    def plan(self, budget_s: Optional[float], max_new: int,
             retrieve_chunk: int, n_probe: int, *,
             prompt_chunks: int = 2) -> SLOPlan:
        """Admission decision for one request with `budget_s` seconds of
        deadline budget left (None = unbounded: always admit)."""
        if budget_s is None:
            return SLOPlan("admit", max_new, retrieve_chunk, n_probe)
        costs = self.stage_costs()
        est = self.estimate(max_new, prompt_chunks=prompt_chunks,
                            costs=costs)
        if est is None or est <= budget_s:
            return SLOPlan("admit", max_new, retrieve_chunk, n_probe, est)
        ret, pre, dec = (costs["retrieve_per_query_s"],
                         costs["prefill_chunk_s"], costs["decode_step_s"])
        # ladder step 1: clamp max_new to what fits after retrieve+prefill
        fixed = ret + prompt_chunks * pre
        fit = int((budget_s - fixed) / dec) if dec > 0 else 0
        new_tokens = max(self.min_tokens, min(max_new, fit))
        # ladder steps 2+3: smaller retrieval chunk (this request's chunk
        # waits on fewer co-retrieved queries), fewer probes
        new_chunk = max(self.min_chunk, retrieve_chunk // 2)
        new_probe = max(self.min_probe, n_probe // 2)
        floor = self.estimate(self.min_tokens,
                              prompt_chunks=prompt_chunks, costs=costs)
        if floor is not None and floor > budget_s:
            return SLOPlan("shed", 0, new_chunk, new_probe, floor)
        return SLOPlan("degrade", new_tokens, new_chunk, new_probe, est)
