"""On-device sLM: a reduced-config language model behind `serving.Engine`,
with tokenisation, so RAG pipelines can run REAL generation on CPU.

The paper's phone-side models (Table 6) are stand-ins here: `qwen25_0_5b`
reduced to the CPU smoke size with randomly initialised weights. The point
is not answer quality — it is that the full on-device pipeline
(EcoVector retrieval -> SCR -> prefill -> decode loop) executes end to
end, with measured (not modelled) prefill/TTFT numbers next to the
analytical Table-6 estimates.

Prompts are left-truncated to the last `max_prompt` tokens and left-PADDED
up to the next `pad_multiple` bucket: a handful of prefill shapes get
compiled (not one per ragged prompt length, which on CPU would dominate
every measurement this module exists to make), while measured prefill
time still scales with prompt size — the paper's SCR claim is precisely
that shorter prompts cut TTFT, so a condensed MobileRAG prompt must land
in a smaller bucket than the full-document Naive-RAG prompt.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.data.tokenizer import HashTokenizer


@dataclass
class SLMGeneration:
    tokens: List[int]               # generated token ids (pre-EOS)
    text: str                       # detokenised generation
    prompt_tokens: int              # true (pre-pad) prompt length
    ttft_s: float                   # measured prefill + first-token time
    decode_s: float = 0.0


class ReducedSLM:
    """Lazy Engine wrapper: the model stack is imported and initialised on
    first use, so merely constructing pipelines (or importing rag.py) stays
    free of the jax model chain."""

    def __init__(self, arch: str = "qwen25_0_5b", *, max_prompt: int = 256,
                 max_new: int = 24, pad_multiple: int = 32, seed: int = 0,
                 page_size: int = 32):
        self.arch = arch
        self.max_prompt = max_prompt
        self.max_new = max_new
        self.pad_multiple = pad_multiple
        self.seed = seed
        self.page_size = page_size
        self._engine = None
        self._tok: Optional[HashTokenizer] = None

    def _ensure(self):
        if self._engine is None:
            import jax
            from repro.configs import get_reduced
            from repro.models import model
            from repro.serving.engine import Engine
            cfg = get_reduced(self.arch)
            params = model.init_params(cfg, jax.random.PRNGKey(self.seed))
            self._engine = Engine(cfg, params,
                                  max_len=self.max_prompt + self.max_new,
                                  page_size=self.page_size)
            self._tok = HashTokenizer(cfg.vocab_size)
        return self._engine, self._tok

    def encode_prompt(self, prompt: str, *, bucket: bool = True) -> np.ndarray:
        """Bucketed ids: left-truncate to max_prompt, left-pad to the
        next pad_multiple so prompt length maps to few prefill shapes.
        `bucket=False` skips the padding: the continuous engine prefills
        in fixed-size chunks, so ragged lengths cost no extra compiles and
        a shorter (SCR-condensed) prompt pays for exactly its own
        tokens."""
        _, tok = self._ensure()
        ids = tok.encode(prompt)[-self.max_prompt:]
        if not bucket:
            return np.asarray(ids or [tok.pad_id], np.int32)
        m = self.pad_multiple
        bucket_len = min(self.max_prompt, -(-max(len(ids), 1) // m) * m)
        pad = bucket_len - len(ids)
        return np.asarray([tok.pad_id] * pad + ids, np.int32)

    def continuous(self, slots: int = 4):
        """The shared slot-paged ContinuousEngine over this sLM's params
        (the RagSession decode backend)."""
        eng, _ = self._ensure()
        return eng.continuous(slots)

    @property
    def tokenizer(self) -> HashTokenizer:
        return self._ensure()[1]

    def warmup(self) -> None:
        """Compile the prefill/decode executables off the measured path."""
        self.generate(["warmup"], max_new=1)

    def generate(self, prompts: List[str], max_new: Optional[int] = None,
                 *, warm_first: bool = True) -> List[SLMGeneration]:
        eng, tok = self._ensure()
        if max_new is None:
            max_new = self.max_new
        if not 1 <= max_new <= self.max_new:
            raise ValueError(
                f"max_new={max_new} outside [1, {self.max_new}]: the "
                "Engine KV budget is sized at construction — build "
                "ReducedSLM(max_new=...) larger instead")
        arrs = [self.encode_prompt(p) for p in prompts]
        if warm_first:
            # one throwaway pass over the same wave shapes so ttft_s
            # reports execution, not XLA compilation of a cold bucket
            eng.generate(arrs, max_new=1)
        res = eng.generate(arrs, max_new=max_new)
        out = []
        for p, r in zip(prompts, res):
            gen = [t for t in r.tokens if t != tok.eos_id]
            out.append(SLMGeneration(
                tokens=list(r.tokens),
                text=tok.decode(gen),
                prompt_tokens=min(len(tok.encode(p)), self.max_prompt),
                ttft_s=r.prefill_s,
                decode_s=r.decode_s))
        return out

    def measure_ttft(self, prompt: str, *, warm: bool = True) -> float:
        """Measured prefill + first-token wall time for one prompt (the
        real-generation counterpart of the Table-6 prompt_tps estimate).
        `warm` runs the same shape once unmeasured first, so a prompt
        landing in a not-yet-compiled bucket doesn't report jit time."""
        eng, _ = self._ensure()
        arr = self.encode_prompt(prompt)
        if warm:
            eng.generate_wave([arr], max_new=1)
        t0 = time.perf_counter()
        res = eng.generate_wave([arr], max_new=1)
        del res
        return time.perf_counter() - t0
