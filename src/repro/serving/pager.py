"""Block-table KV pager: refcounted page pool + token-keyed prefix trie.

The ContinuousEngine's KV cache is one global page pool
`[L, num_pages, page_size, G, dh]` (models.init_page_pool); each slot
maps an ordered list of page ids through its `[W]` page-table row. This
module owns the HOST-side bookkeeping for that pool:

`PagePool` — a free list plus per-page refcounts. A page is mapped into
a slot (+1 ref per slot), and may additionally be RETAINED by the prefix
cache (+1 ref); it returns to the free list only when the last reference
drops. Nothing here touches device memory — the engine scatters/gathers
through page ids, so "freeing" a page is pure bookkeeping and its stale
contents are masked (kv_len) until overwritten.

`PrefixCache` — a trie over prompt TOKEN IDS with page-granular edges:
each full-page edge is keyed by the exact tuple of `page_size` tokens it
holds and carries the (immutable, refcounted) page id that backs them.
Leaf nodes can also carry partial-page "tails": a page whose first
`valid` positions hold prompt tokens (its remainder sees the owning
request's decode writes, so only the prompt prefix is trustworthy).

Matching a new prompt walks full-page edges exactly (those pages are
mapped READ-ONLY into the new slot: pure sharing, zero copies), then
looks for the longest common prefix against a tail or a divergent
full-page edge — that page becomes a COPY-ON-WRITE source: the engine
copies it into a fresh page and the new request's prefill resumes at the
first divergent token. The match length is capped at len(prompt) - 1 so
at least one real token always runs through prefill (the first-token
logits come from the last prompt position).

Invariants the engine relies on (tests/test_pager.py):
- a page's refcount == (#slots mapping it) + (1 if trie-retained);
- shared (refcount > 1 or retained) pages are never scattered to: all
  writes land at logical positions >= the request's matched length,
  which sit in slot-private (fresh or COW) pages;
- registration never replaces an existing edge's page id (first writer
  wins), so concurrent readers of a shared page never see it swapped;
- eviction (LRU over leaf edges/tails) only drops the TRIE's reference —
  a page still mapped by a live slot survives until that slot frees it.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class PoolStats:
    total: int
    free: int
    mapped_refs: int      # sum of refcounts held by slot mappings + trie
    retained: int         # pages the prefix cache holds a reference on


class PagePool:
    """Free list + refcounts over `num_pages` device pages (host-side
    bookkeeping only; the engine owns the device arrays)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self.refs = np.zeros(num_pages, np.int32)
        self._free: Deque[int] = deque(range(num_pages))

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate `n` pages at refcount 1, or None (all-or-nothing) —
        the caller may evict prefix-cache leaves and retry."""
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self.refs[p] = 1
        return pages

    def incref(self, pid: int) -> None:
        assert self.refs[pid] > 0, f"incref on free page {pid}"
        self.refs[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        assert self.refs[pid] > 0, f"decref on free page {pid}"
        self.refs[pid] -= 1
        if self.refs[pid] == 0:
            self._free.append(pid)
            return True
        return False


@dataclass
class _Tail:
    """A partial prompt page: only the first `valid` positions hold
    prompt tokens (the rest sees the owning request's decode writes)."""
    pid: int
    tokens: Tuple[int, ...]     # the `valid` prompt tokens, in order
    last_use: int = 0


@dataclass
class _Node:
    """One trie node; full-page edges keyed by their exact token tuple."""
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)
    pid: int = -1               # page backing the edge INTO this node
    tails: List[_Tail] = field(default_factory=list)
    last_use: int = 0


@dataclass
class PrefixMatch:
    """Result of matching a prompt: `full` pages map read-only into the
    new slot; `cow` (if any) is a (source page id, copy length) pair —
    the source's first `cow[1]` tokens extend the match past the last
    full page and must be copied into a fresh page before the slot may
    write to that region. `matched` = total matched token count
    (== len(full) * page_size + (cow[1] if cow else 0))."""
    full: List[int]
    cow: Optional[Tuple[int, int]]
    matched: int


class PrefixCache:
    """Token-keyed prefix trie over immutable prompt pages."""

    def __init__(self, pool: PagePool, page_size: int, *,
                 max_tails_per_node: int = 4):
        self.pool = pool
        self.ps = page_size
        self.root = _Node()
        self.max_tails = max_tails_per_node
        self._clock = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------- match

    def match(self, prompt: np.ndarray) -> PrefixMatch:
        """Longest cached prefix of `prompt`, capped at len(prompt) - 1."""
        ps = self.ps
        toks = [int(t) for t in prompt]
        plen = len(toks)
        now = self._tick()
        node = self.root
        full: List[int] = []
        consumed = 0
        # full-page walk: only pages whose ENTIRE ps tokens match, and
        # never past the cap (the last prompt token must prefill)
        while consumed + ps <= plen - 1:
            key = tuple(toks[consumed:consumed + ps])
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = now
            full.append(child.pid)
            node = child
            consumed += ps
        # partial extension: longest common prefix against this node's
        # tails and divergent full-page edges -> COW source
        rest = toks[consumed:]
        cap = (plen - 1) - consumed          # max extra tokens matchable
        best_m, best_pid = 0, -1
        for tail in node.tails:
            m = _lcp(rest, tail.tokens, cap)
            if m > best_m:
                best_m, best_pid = m, tail.pid
                tail.last_use = now
        for key, child in node.children.items():
            m = _lcp(rest, key, cap)
            if m > best_m:
                best_m, best_pid = m, child.pid
                child.last_use = now
        cow = (best_pid, best_m) if best_m > 0 else None
        return PrefixMatch(full, cow, consumed + best_m)

    # ---------------------------------------------------------- register

    def register(self, prompt: np.ndarray, pages: List[int]) -> None:
        """Retain `prompt`'s pages after its prefill completed. `pages`
        is the owning slot's mapped page list in logical order; only the
        pages the prompt actually covers are registered (full pages as
        edges, the ragged last page as a tail). Existing edges keep their
        ORIGINAL page id (first writer wins — a duplicate page stays
        slot-private and is freed with its slot); every newly retained
        page gets one trie reference."""
        ps = self.ps
        toks = [int(t) for t in prompt]
        plen = len(toks)
        now = self._tick()
        node = self.root
        nfull = plen // ps
        for i in range(nfull):
            key = tuple(toks[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(pid=pages[i])
                node.children[key] = child
                self.pool.incref(pages[i])
            child.last_use = now
            node = child
        rem = plen - nfull * ps
        if rem > 0:
            key = tuple(toks[nfull * ps:])
            for tail in node.tails:
                if tail.tokens == key:
                    tail.last_use = now
                    return
            if len(node.tails) >= self.max_tails:
                oldest = min(node.tails, key=lambda t: t.last_use)
                node.tails.remove(oldest)
                self.pool.decref(oldest.pid)
            node.tails.append(_Tail(pages[nfull], key, now))
            self.pool.incref(pages[nfull])

    # ----------------------------------------------------------- evict

    def evict_one(self) -> bool:
        """Drop the least-recently-used leaf edge or tail (one trie
        reference); returns False when the trie is empty. A page still
        mapped by a live slot keeps its slot references — eviction only
        makes it unavailable to FUTURE prefix matches."""
        best = None          # (last_use, parent, key_or_tail, is_tail)
        stack = [self.root]
        while stack:
            node = stack.pop()
            for tail in node.tails:
                if best is None or tail.last_use < best[0]:
                    best = (tail.last_use, node, tail, True)
            for key, child in node.children.items():
                if not child.children and not child.tails:
                    if best is None or child.last_use < best[0]:
                        best = (child.last_use, node, key, False)
                else:
                    stack.append(child)
        if best is None:
            return False
        _, parent, item, is_tail = best
        if is_tail:
            parent.tails.remove(item)
            self.pool.decref(item.pid)
        else:
            child = parent.children.pop(item)
            self.pool.decref(child.pid)
            for tail in child.tails:      # orphaned tails free with it
                self.pool.decref(tail.pid)
        return True

    def drop(self) -> int:
        """Release every retained page (engine reset / tests); returns
        the number of references dropped."""
        n = 0
        while self.evict_one():
            n += 1
        return n

    def retained_count(self) -> int:
        n = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            n += len(node.tails)
            for child in node.children.values():
                n += 1
                stack.append(child)
        return n


def _lcp(a, b, cap: int) -> int:
    """Length of the longest common prefix of `a` and `b`, capped."""
    n = min(len(a), len(b), cap)
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i
