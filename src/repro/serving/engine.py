"""Generation engine: jitted prefill + decode with dynamic (wave) batching.

Requests are grouped into fixed-size waves (padded to the wave's max prompt
length); the wave decodes until every member finishes, then the next wave
is formed — iteration-level batching without per-slot position plumbing.
A wave whose decode step exceeds its latency budget is *hedged*: the
scheduler re-dispatches the remaining requests (straggler mitigation; see
scheduler.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model


@dataclass
class GenResult:
    tokens: List[int]
    prompt_len: int
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def ttft_s(self) -> float:
        return self.prefill_s


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 eos_id: int = 2, prefill_chunk: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, b: model.prefill(cfg, p, b))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))

    def _grow_cache(self, cache, b: int):
        """Caches come back sized to the prompt; decode needs max_len."""
        def grow(x):
            if x.ndim in (4, 5) and x.shape[2] < self.max_len:
                pad = self.max_len - x.shape[2]
                z = jnp.zeros(x.shape[:2] + (pad,) + x.shape[3:], x.dtype)
                return jnp.concatenate([x, z], axis=2)
            return x
        if self.cfg.family in ("dense", "moe", "encdec"):
            grown = dict(cache)
            for k in ("k", "v", "k_s", "v_s"):
                if k in grown and not k.startswith("cross"):
                    grown[k] = grow(grown[k])
            return grown
        return cache  # state caches (mamba2/rglru) are fixed-size

    def generate(self, prompts: List[np.ndarray], max_new: int = 32,
                 greedy: bool = True, seed: int = 0) -> List[GenResult]:
        """Length-buckets prompts, runs each bucket as one wave (equal
        lengths keep causal semantics exact without pad masking)."""
        buckets: dict[int, List[int]] = {}
        for i, p in enumerate(prompts):
            buckets.setdefault(len(p), []).append(i)
        results: List[Optional[GenResult]] = [None] * len(prompts)
        for plen, idxs in sorted(buckets.items()):
            wave = [prompts[i] for i in idxs]
            for i, r in zip(idxs, self.generate_wave(wave, max_new,
                                                     greedy, seed)):
                results[i] = r
        return results

    def generate_wave(self, prompts: List[np.ndarray], max_new: int = 32,
                      greedy: bool = True, seed: int = 0) -> List[GenResult]:
        """prompts: list of 1-D int32 token arrays of EQUAL length."""
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        assert all(len(p) == plen for p in prompts), \
            "generate_wave requires equal-length prompts (use generate())"
        toks = np.stack([np.asarray(p, np.int32) for p in prompts])
        batch = {"tokens": jnp.asarray(toks)}
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        cache = self._grow_cache(cache, b)

        outs = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        key = jax.random.PRNGKey(seed)
        t1 = time.perf_counter()
        tok = None
        for step in range(max_new):
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits)[:, None]
            tok_np = np.asarray(tok)[:, 0]
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(tok_np[i]))
                    if tok_np[i] == self.eos_id:
                        done[i] = True
            if done.all():
                break
            pos = jnp.int32(min(plen + step, self.max_len - 1))
            logits, cache = self._decode(self.params, cache, tok, pos)
        t_decode = time.perf_counter() - t1
        return [GenResult(outs[i], len(prompts[i]), t_prefill, t_decode)
                for i in range(b)]
