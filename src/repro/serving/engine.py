"""Generation engines.

`ContinuousEngine` is the request-centric serving core: one global
block-table KV page pool ([L, num_pages, page_size, G, dh] — int8 values
+ per-page scale planes for `kv_quant` configs) with a per-slot int32
page-table row mapping each slot's logical positions onto pool pages,
`submit()`/`step()` lifecycle, admission of a queued prompt into any slot
the step after its occupant hits EOS, and prefill of admitted prompts
chunked into the running decode loop so a long prompt never stalls other
slots for more than one chunk. Pages are refcounted (serving/pager.py):
a prompt whose prefix is already cached maps the shared pages READ-ONLY
into its table row and skips their prefill chunks entirely; a partially
matching page is COPY-ON-WRITE forked (one page copy) and prefill
resumes at the first divergent token. At prefix share 0 the gathered
logical buffer is element-identical to the old slot-contiguous cache, so
paged output stays bit-identical to the wave path. Both greedy and
sampled requests run here: each sampled request draws from its own PRNG
stream `fold_in(PRNGKey(seed), request_id)` advanced by a per-request
draw counter, so its tokens are bit-identical regardless of co-residents
(DESIGN.md §10).

`Engine` keeps the legacy wave surface: `generate()` is now a thin
compatibility wrapper that routes requests through a shared
`ContinuousEngine` whenever the config supports the paged path
(`model.supports_paged`: the dense and moe text families, including
sliding-window and int8-KV — greedy token output is identical to the
wave path, see tests/test_serving.py and tests/test_paged_families.py),
and falls back to fixed length-bucketed waves (`generate_wave`) for the
families without paged KV (M-RoPE, encdec, recurrent state).
`generate(..., continuous=False)` forces the legacy wave path, which
remains the parity baseline every serving bench compares against; wave
sampling draws from the same per-request `fold_in(PRNGKey(seed), rid)`
streams as the paged path (one shared split-per-step key historically
made wave draws depend on batch composition), so sampled output is also
path-identical.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model
from repro.serving.pager import PagePool, PoolStats, PrefixCache
from repro.serving.trace import TraceSink

# monotone engine-instance counter: the `src` tag on trace records, so
# replicas sharing one TraceSink never collide on request ids
_ENGINE_SEQ = [0]


@dataclass
class GenResult:
    """One finished generation: decoded token ids (including the EOS, if
    hit), the prompt length, and measured prefill / decode wall time
    attributed to this request."""
    tokens: List[int]
    prompt_len: int
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def ttft_s(self) -> float:
        """Time to first token == the measured prefill time (the first
        token is drawn from the prefill logits)."""
        return self.prefill_s


@dataclass
class EngineEvent:
    """One request-visible state change from a `ContinuousEngine.step()`:
    kind is "admitted" (slot assigned, prefill starting), "token" (one new
    token id in `token`), "done" (`result` carries the GenResult), or
    "shed" (terminal refusal — `reason` says why, e.g. "oversize" for a
    request that cannot fit its page budget; no tokens were produced and
    none will be)."""
    rid: int
    kind: str
    token: Optional[int] = None
    result: Optional[GenResult] = None
    reason: Optional[str] = None


@dataclass
class _Request:
    """Engine-internal per-request state: prompt, prefill/decode
    progress, the occupied slot and mapped pages, timing, and the
    sampling mode/stream."""
    rid: int
    prompt: np.ndarray
    max_new: int
    submitted_s: float
    tokens: List[int] = field(default_factory=list)
    filled: int = 0                  # prefill progress (incl. matched skip)
    matched: int = 0                 # prefix tokens reused from the cache
    slot: int = -1
    pages: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    greedy: bool = True
    # sampled requests only: this request's own PRNG stream root,
    # fold_in(PRNGKey(seed), rid); draw t folds in t = len(tokens)
    key: Optional[object] = None


@jax.jit
def _sample_rows(logits, keys, ts, greedy):
    """One next-token draw per row, all rows in one jitted call.

    logits [B, V]; keys [B, 2] uint32 per-request stream roots; ts [B]
    per-request draw counters; greedy [B] bool. Greedy rows take argmax,
    sampled rows draw categorical under fold_in(key, t) — exactly the
    draw the engine's scalar path computes, row by row (logits upcast to
    f32 first, matching the host-side draw), so batching the draws
    changes nothing bitwise while collapsing the per-slot Python loop
    into a single device call that transfers B ints instead of the full
    [B, V] logits."""
    def one(row, key, t, g):
        row = row.astype(jnp.float32)
        samp = jax.random.categorical(jax.random.fold_in(key, t), row)
        return jnp.where(g, jnp.argmax(row), samp).astype(jnp.int32)
    return jax.vmap(one)(logits, keys, ts, greedy)


class ContinuousEngine:
    """Continuous (slot-level) batching over a block-table paged KV pool.

    The cache is one global page pool [L, num_pages, page_size, G, dh]
    (int8 values with [L, num_pages, page_size, G] scale planes for
    `kv_quant` configs); each slot maps an ordered list of pages through
    its [W] page-table row, so a slot's logical position p lives at pool
    page `table[p // page_size]`, in-page offset `p % page_size`. Decode
    steps run all slots at once through `model.decode_step_paged`;
    admission prefill runs one `prefill_chunk` slice of one prompt per
    slot per step through `model.prefill_chunk_paged`, interleaved with
    decode, so the running requests keep streaming while a new prompt
    fills its pages. A slot freed by EOS (or max_new) admits the next
    queued request on the following step.

    Prefix reuse (non-sliding-window configs): completed prompts register
    their pages in a token-keyed trie (serving/pager.py). Admission
    matches the longest cached prefix, maps its full pages read-only
    (refcounted — zero copies), copy-on-write forks at most one partially
    matching page, and starts prefill at the first unmatched token; the
    skipped chunks are the TTFT win `benchmarks/bench_serving.py
    --prefix` measures. Shared pages are never written: every store lands
    at logical position >= the request's matched length, which sits in
    slot-private pages. Sliding-window configs keep per-slot ring pages
    (cursor `pos % ring_len`) with sharing disabled — a ring's contents
    depend on its own wrap history, so its pages are never
    prefix-reusable.

    Oversize admission: a prompt needing more than the slot's table width
    in pages (prompt + max_new tokens) is refused with a terminal "shed"
    event (reason "oversize") — never silently truncated; anything
    smaller can borrow transiently free pool pages and waits in queue
    while they are held by live slots.

    Sampling: `submit(..., greedy=False, seed=s)` gives the request its
    own PRNG stream `fold_in(PRNGKey(s), rid)`; draw t folds in the
    number of tokens already emitted. Because paged decode rows are
    independent and the stream depends only on (seed, rid), a request's
    sampled tokens are bit-identical whatever else is co-resident.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, eos_id: int = 2,
                 prefill_chunk: int = 32, page_size: int = 32,
                 oversize_pages: int = 2,
                 trace: Optional[TraceSink] = None):
        """Allocate the page pool (`slots` table-widths of `page_size`
        pages; sliding-window configs get `min(window, chunk-rounded
        max_len)` ring positions per slot) and jit the paged decode /
        chunk-prefill executables. `oversize_pages` widens every table
        row beyond the ceil(max_len / page_size) baseline so a request
        slightly over budget can still be admitted from transiently free
        pages instead of shed. Raises ValueError for configs without
        slot-paged support (`model.supports_paged`)."""
        if not model.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name}: family/config without slot-paged KV support "
                "(use Engine's wave path)")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_chunk = prefill_chunk
        self.page_size = page_size
        self.oversize_pages = oversize_pages
        # observability: every request-visible state change is mirrored
        # into the sink (serving/trace.py); tracing is pure host-side
        # bookkeeping and never touches device state, so tokens are
        # bit-identical with or without a sink attached
        self.trace = trace
        self.trace_src = f"e{_ENGINE_SEQ[0]}"
        _ENGINE_SEQ[0] += 1
        ps = page_size
        # absolute-position scratch length for chunked prefill: rounded
        # UP to whole chunks so a final ragged chunk's dynamic slice
        # never clamps backwards over earlier positions
        self.abs_len = -(-max_len // prefill_chunk) * prefill_chunk
        if cfg.sliding_window:
            # per-slot ring over the window (same modulus the wave path
            # bakes into its rolled layout); prefix sharing disabled
            self.ring_len = min(cfg.sliding_window, self.abs_len)
            self.table_width = -(-self.ring_len // ps)
        else:
            self.ring_len = 0
            self.table_width = -(-max_len // ps) + oversize_pages
        self.num_pages = self.slots * self.table_width
        self.cache = model.init_page_pool(cfg, self.num_pages, ps,
                                          dtype=model.compute_dtype(cfg))
        self.pool = PagePool(self.num_pages)
        self.prefix: Optional[PrefixCache] = (
            None if self.ring_len else PrefixCache(self.pool, ps))
        # host page table + lazily refreshed device mirror
        self._tbl = np.zeros((slots, self.table_width), np.int32)
        self._tbl_dev = None
        self._decode = jax.jit(
            lambda p, c, t, pos, act, tbl: model.decode_step_paged(
                cfg, p, c, t, pos, act, tbl, page_size=ps,
                ring_len=self.ring_len),
            donate_argnums=(1,))
        self._chunk = jax.jit(
            lambda p, c, t, row, off, lim: model.prefill_chunk_paged(
                cfg, p, c, t, row, off, lim, page_size=ps,
                ring_len=self.ring_len, abs_len=self.abs_len),
            donate_argnums=(1,))

        def _copy_page(c, src, dst):
            out = dict(c)
            for k in out:
                out[k] = out[k].at[:, dst].set(out[k][:, src])
            return out
        self._copy = jax.jit(_copy_page, donate_argnums=(0,))
        # host-side slot state
        self.pos = np.zeros(slots, np.int32)
        self.last_tok = np.zeros(slots, np.int32)
        self.active = np.zeros(slots, bool)      # decoding (prefill done)
        self._occupant: List[Optional[_Request]] = [None] * slots
        self.queue: Deque[_Request] = deque()
        self._inflight: Dict[int, _Request] = {}
        self._next_rid = 0
        # utilisation / pager counters (decode steps only)
        self.steps = 0
        self.active_slot_steps = 0
        self.cancelled = 0
        self.shed = 0
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0

    def clone(self, *, slots: Optional[int] = None) -> "ContinuousEngine":
        """An independent replica: same params/config, its own page pool
        and slot state (the SlotScheduler's unit of failover)."""
        return ContinuousEngine(
            self.cfg, self.params, slots=slots or self.slots,
            max_len=self.max_len, eos_id=self.eos_id,
            prefill_chunk=self.prefill_chunk, page_size=self.page_size,
            oversize_pages=self.oversize_pages, trace=self.trace)

    def _table_dev(self):
        if self._tbl_dev is None:
            self._tbl_dev = jnp.asarray(self._tbl)
        return self._tbl_dev

    # ------------------------------------------------------------ tracing

    def _emit(self, name: str, rid: int = -1, *, comp: str = "engine",
              ph: str = "I", **attrs) -> None:
        """One trace record from this engine (no-op without a sink)."""
        if self.trace is not None:
            self.trace.emit(comp, name, rid, src=self.trace_src, ph=ph,
                            **attrs)

    def _trace_page_stats(self) -> None:
        """Snapshot pool accounting into the trace: tools/trace_check.py
        reconciles the last snapshot of a drained engine against the
        only-the-trie-holds-refs invariant."""
        if self.trace is not None:
            st = self.page_stats()
            self.trace.emit("pager", "page_stats", src=self.trace_src,
                            total=st.total, free=st.free,
                            mapped_refs=st.mapped_refs,
                            retained=st.retained,
                            inflight=len(self._inflight))

    # ------------------------------------------------------------- intake

    def submit(self, prompt: np.ndarray, max_new: int = 32,
               rid: Optional[int] = None, *, greedy: bool = True,
               seed: int = 0) -> int:
        """Queue one request; returns its rid. A prompt whose pages
        (prompt + max_new tokens) exceed the slot table width is shed
        with a terminal "shed" event at admission — never silently
        truncated. `greedy=False` samples from this request's own PRNG
        stream `fold_in(PRNGKey(seed), rid)` — pass an explicit `rid` to
        make a sampled request's draws reproducible across engines/runs
        regardless of what else is co-resident."""
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        p = np.asarray(prompt, np.int32).reshape(-1)
        req = _Request(rid, p, max_new, time.perf_counter(),
                       greedy=greedy)
        if not greedy:
            req.key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
        self.queue.append(req)
        self._inflight[rid] = req
        self._emit("queued", rid, prompt_len=len(p), max_new=max_new,
                   greedy=greedy)
        return rid

    def _draw(self, req: _Request, row: np.ndarray) -> int:
        """Next token for `req` from its logits row [V]. Greedy: argmax.
        Sampled: categorical under fold_in(req.key, t) where t is the
        number of tokens already emitted — the draw depends only on
        (seed, rid, t, row), never on co-residents."""
        if req.greedy:
            return int(np.argmax(row))
        key = jax.random.fold_in(req.key, len(req.tokens))
        return int(jax.random.categorical(key, jnp.asarray(row)))

    @property
    def pending(self) -> int:
        """Requests still in flight (queued, prefilling or decoding)."""
        return len(self._inflight)

    def free_slots(self) -> int:
        """Slots with no occupant (neither decoding nor admitting)."""
        return sum(1 for r in self._occupant if r is None)

    def available_slots(self) -> int:
        """Admission capacity: free slots minus already-queued requests
        (what a scheduler should look at, not raw free_slots)."""
        return self.free_slots() - len(self.queue)

    def page_stats(self) -> PoolStats:
        """Pool occupancy snapshot: total/free pages, the sum of live
        references (slot mappings + prefix-cache retentions), and how
        many retentions the prefix cache holds."""
        retained = self.prefix.retained_count() if self.prefix else 0
        return PoolStats(self.pool.num_pages, self.pool.free_count,
                         int(self.pool.refs.sum()), retained)

    def drop_prefix_cache(self) -> int:
        """Release every prefix-cache page retention (pages still mapped
        by live slots survive until those slots free them); returns the
        number of entries dropped."""
        return self.prefix.drop() if self.prefix else 0

    def cancel(self, rid: int) -> bool:
        """Abandon one in-flight request (deadline expiry, hedged copy
        superseded, scheduler failover): its slot and page references are
        freed immediately — the next `step()` can admit a queued prompt
        into them — and no further events are emitted for the rid.
        Returns False when the rid is unknown or already finished."""
        req = self._inflight.pop(rid, None)
        if req is None:
            return False
        try:
            self.queue.remove(req)
        except ValueError:
            pass
        self._release_pages(req)
        s = req.slot
        if s >= 0 and self._occupant[s] is req:
            self._occupant[s] = None
            self.active[s] = False
        self.cancelled += 1
        self._emit("cancelled", rid, slot=s, n_tokens=len(req.tokens))
        self._trace_page_stats()
        return True

    # ------------------------------------------------------------- stepping

    def _release_pages(self, req: _Request) -> None:
        """Drop this request's page references (shared prefix pages
        survive while the trie or other slots still hold them) and clear
        its table row."""
        for pid in req.pages:
            self.pool.decref(pid)
        req.pages = []
        if req.slot >= 0:
            self._tbl[req.slot, :] = 0
            self._tbl_dev = None

    def _finish(self, req: _Request, events: List[EngineEvent]) -> None:
        """Free the request's slot + pages and emit its terminal "done"
        event."""
        s = req.slot
        self.active[s] = False
        self._occupant[s] = None
        self._inflight.pop(req.rid, None)
        self._release_pages(req)
        events.append(EngineEvent(req.rid, "done", result=GenResult(
            req.tokens, len(req.prompt), req.prefill_s, req.decode_s)))
        self._emit("done", req.rid, n_tokens=len(req.tokens),
                   prefill_s=req.prefill_s, decode_s=req.decode_s)
        self._trace_page_stats()

    def _emit_token(self, req: _Request, tok: int,
                    events: List[EngineEvent]) -> None:
        """Record one emitted token; finish the request on EOS/max_new."""
        req.tokens.append(tok)
        events.append(EngineEvent(req.rid, "token", token=tok))
        self._emit("first_token" if len(req.tokens) == 1 else "token",
                   req.rid, token=tok)
        if tok == self.eos_id or len(req.tokens) >= req.max_new:
            self._finish(req, events)

    def _map_request(self, req: _Request, s: int) -> str:
        """Try to map `req`'s pages into slot `s`'s table row. Returns
        "ok" (mapped; prefill resumes at the matched prefix length),
        "shed" (can never fit: more pages than the table width, or the
        pool can't cover it even with the engine otherwise idle and the
        prefix cache fully evicted), or "wait" (transient shortage —
        pages will free when a live slot finishes)."""
        plen = len(req.prompt)
        ps = self.page_size
        if plen == 0:
            return "shed"
        if self.ring_len:
            # rings wrap, so only the prefill scratch bounds the prompt;
            # every slot maps a full table width of private pages
            if plen > self.abs_len:
                return "shed"
            full: List[int] = []
            cow = None
            matched = 0
            need_total = self.table_width
        else:
            need_total = -(-(plen + req.max_new) // ps)
            if need_total > self.table_width:
                return "shed"
            m = self.prefix.match(req.prompt)
            full, cow, matched = m.full, m.cow, m.matched
        # hold the matched pages across eviction/alloc: evicting a leaf
        # we are about to share must not free it back into the pool
        for pid in full:
            self.pool.incref(pid)
        if cow:
            self.pool.incref(cow[0])
        fresh = self.pool.alloc(need_total - len(full))
        while fresh is None and self.prefix and self.prefix.evict_one():
            fresh = self.pool.alloc(need_total - len(full))
        if fresh is None:
            for pid in full:
                self.pool.decref(pid)
            if cow:
                self.pool.decref(cow[0])
            # live slots will free pages; with the engine idle and the
            # trie fully evicted the pool cannot ever cover this request
            if any(r is not None for r in self._occupant):
                return "wait"
            return "shed"
        t0 = time.perf_counter()
        if cow:
            # fork the partially matching page: one page copy, then the
            # resumed prefill overwrites everything past the match point
            self.cache = self._copy(self.cache, jnp.int32(cow[0]),
                                    jnp.int32(fresh[0]))
            self.pool.decref(cow[0])
            self._emit("cow_fork", req.rid, comp="pager", src_page=cow[0],
                       dst_page=fresh[0], copy_len=cow[1])
        req.pages = full + fresh
        req.matched = req.filled = matched
        req.prefill_s += time.perf_counter() - t0
        self._tbl[s, :len(req.pages)] = req.pages
        self._tbl[s, len(req.pages):] = 0
        self._tbl_dev = None
        if matched:
            self.prefix_hits += 1
            self.prefix_tokens_reused += matched
            self._emit("prefix_hit", req.rid, comp="pager",
                       matched=matched, full_pages=len(full))
        return "ok"

    def _admit(self, events: List[EngineEvent]) -> None:
        """Assign queued requests to free slots (prefill starts on the
        same step, via `_prefill_step`). Oversize requests shed loudly;
        a transient page shortage leaves the queue intact until live
        slots free their pages."""
        for s in range(self.slots):
            while self._occupant[s] is None and self.queue:
                req = self.queue.popleft()
                st = self._map_request(req, s)
                if st == "wait":
                    self.queue.appendleft(req)
                    return
                if st == "shed":
                    self._inflight.pop(req.rid, None)
                    self.shed += 1
                    events.append(EngineEvent(req.rid, "shed",
                                              reason="oversize"))
                    self._emit("shed", req.rid, reason="oversize",
                               prompt_len=len(req.prompt))
                    self._trace_page_stats()
                    continue
                req.slot = s
                self._occupant[s] = req
                self.active[s] = False
                events.append(EngineEvent(req.rid, "admitted"))
                self._emit("admitted", req.rid, slot=s,
                           matched=req.matched, pages=len(req.pages))

    def _prefill_step(self, events: List[EngineEvent]) -> None:
        """Advance every admitting slot by one prompt chunk. A request
        resuming past a matched prefix takes a short first chunk up to
        the next chunk boundary, so all later chunks land on the same
        grid a cold prefill uses — that alignment (plus identical shared
        page contents) is what keeps a prefix hit bit-identical to a
        cold run."""
        c = self.prefill_chunk
        for s in range(self.slots):
            req = self._occupant[s]
            if req is None or self.active[s]:
                continue
            t0 = time.perf_counter()
            end = min(len(req.prompt), (req.filled // c + 1) * c)
            chunk = req.prompt[req.filled:end]
            real = len(chunk)
            if real < c:
                chunk = np.concatenate([chunk, np.zeros(c - real, np.int32)])
            self._emit("prefill_chunk", req.rid, ph="B", slot=s,
                       start=req.filled, n=real)
            logits, self.cache = self._chunk(
                self.params, self.cache, jnp.asarray(chunk[None]),
                jnp.asarray(self._tbl[s]), jnp.int32(req.filled),
                jnp.int32(req.filled + real))
            req.filled += real
            self._emit("prefill_chunk", req.rid, ph="E")
            if req.filled >= len(req.prompt):
                plen = len(req.prompt)
                if self.prefix is not None:
                    self.prefix.register(req.prompt,
                                         req.pages[:-(-plen // self.page_size)])
                row = np.asarray(logits, np.float32)[0, real - 1]
                tok = self._draw(req, row)
                self.pos[s] = plen
                self.last_tok[s] = tok
                self.active[s] = True
                req.prefill_s += time.perf_counter() - t0
                self._emit_token(req, tok, events)
            else:
                req.prefill_s += time.perf_counter() - t0

    def _decode_step(self, events: List[EngineEvent]) -> None:
        """One `decode_step_paged` over every active slot, then one
        batched `_sample_rows` draw (greedy argmax rows and per-request
        PRNG-stream rows in the same jitted call — only [slots] ints ever
        reach the host)."""
        if not self.active.any():
            return
        t0 = time.perf_counter()
        self._emit("decode_step", ph="B", active=int(self.active.sum()))
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok[:, None]),
            jnp.asarray(self.pos), jnp.asarray(self.active),
            self._table_dev())
        keys = np.zeros((self.slots, 2), np.uint32)
        ts = np.zeros(self.slots, np.int32)
        gr = np.ones(self.slots, bool)
        for s in range(self.slots):
            req = self._occupant[s]
            if self.active[s] and not req.greedy:
                keys[s] = np.asarray(req.key)
                ts[s] = len(req.tokens)
                gr[s] = False
        nxt = np.asarray(_sample_rows(logits, jnp.asarray(keys),
                                      jnp.asarray(ts), jnp.asarray(gr)))
        dt = time.perf_counter() - t0
        self._emit("decode_step", ph="E")
        self.steps += 1
        self.active_slot_steps += int(self.active.sum())
        for s in range(self.slots):
            if not self.active[s]:
                continue
            req = self._occupant[s]
            req.decode_s += dt
            self.pos[s] += 1
            tok = int(nxt[s])
            self.last_tok[s] = tok
            self._emit_token(req, tok, events)

    def step(self) -> List[EngineEvent]:
        """One engine step: admit queued prompts into freed slots, advance
        each admitting slot by one prefill chunk, then run one decode step
        over all active slots. Returns the request events it produced."""
        events: List[EngineEvent] = []
        self._admit(events)
        self._prefill_step(events)
        self._decode_step(events)
        return events

    def utilisation(self) -> float:
        """Mean fraction of slots doing useful decode work per step."""
        return self.active_slot_steps / max(self.steps * self.slots, 1)

    # ----------------------------------------------------------- draining

    def warmup(self) -> None:
        """Compile the chunk-prefill and paged-decode executables off the
        measured path (shapes are fixed, so one tiny request covers it)."""
        self.generate([np.arange(2, dtype=np.int32)], max_new=2)
        self.steps = self.active_slot_steps = 0

    def generate(self, prompts: List[np.ndarray], max_new: int = 32,
                 greedy: bool = True, seed: int = 0) -> List[GenResult]:
        """Batch convenience: submit everything, step until drained.
        `greedy=False` samples each request from its own
        fold_in(PRNGKey(seed), rid) stream; rids are pinned to the batch
        index so the same (prompts, seed) call draws the same tokens no
        matter what the engine served before. Raises RuntimeError if a
        request is shed (oversize) — callers of the batch API expect
        every prompt to produce tokens."""
        assert not self._inflight, "generate() on a busy engine"
        rids = [self.submit(p, max_new, rid=i, greedy=greedy, seed=seed)
                for i, p in enumerate(prompts)]
        results: Dict[int, GenResult] = {}
        while self._inflight:
            for ev in self.step():
                if ev.kind == "done":
                    results[ev.rid] = ev.result
                elif ev.kind == "shed":
                    raise RuntimeError(
                        f"request {ev.rid} shed: {ev.reason} "
                        f"(prompt + max_new exceed the page budget)")
        return [results[r] for r in rids]


class Engine:
    """Serving engine over one model: `generate()` auto-routes through a
    shared slot-paged `ContinuousEngine` for paged-capable configs and
    falls back to the legacy length-bucketed wave path
    (`generate_wave`) for the rest (M-RoPE, encdec, recurrent state) or
    when forced with `continuous=False`."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 eos_id: int = 2, prefill_chunk: Optional[int] = None,
                 slots: int = 4, page_size: int = 32):
        """`max_len`: KV budget per request (prompt + generation);
        `slots`: default concurrent-request count of the shared
        ContinuousEngine; `prefill_chunk`: tokens per admission prefill
        chunk; `page_size`: positions per KV pool page (both continuous
        path only)."""
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self.slots = slots
        self.prefill_chunk = prefill_chunk or 32
        self.page_size = page_size
        self._prefill = jax.jit(
            lambda p, b: model.prefill(cfg, p, b))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))
        self._cont: Dict[int, ContinuousEngine] = {}

    def continuous(self, slots: Optional[int] = None) -> ContinuousEngine:
        """The shared slot-paged engine over the same params/KV budget
        (one per slot count — the decode jit keys on it)."""
        n = slots or self.slots
        if n not in self._cont:
            self._cont[n] = ContinuousEngine(
                self.cfg, self.params, slots=n, max_len=self.max_len,
                eos_id=self.eos_id, prefill_chunk=self.prefill_chunk,
                page_size=self.page_size)
        return self._cont[n]

    def _grow_cache(self, cache, b: int):
        """Caches come back sized to the prompt; decode needs max_len —
        capped at the sliding window for SWA configs: growing a ring past
        its window would change the `pos % len` cursor modulus that the
        prefill roll already baked into the layout."""
        target = self.max_len
        if self.cfg.family in ("dense", "moe") and self.cfg.sliding_window:
            target = min(target, self.cfg.sliding_window)

        def grow(x):
            if x.ndim in (4, 5) and x.shape[2] < target:
                pad = target - x.shape[2]
                z = jnp.zeros(x.shape[:2] + (pad,) + x.shape[3:], x.dtype)
                return jnp.concatenate([x, z], axis=2)
            return x
        if self.cfg.family in ("dense", "moe", "encdec"):
            grown = dict(cache)
            for k in ("k", "v", "k_s", "v_s"):
                if k in grown and not k.startswith("cross"):
                    grown[k] = grow(grown[k])
            return grown
        return cache  # state caches (mamba2/rglru) are fixed-size

    def generate(self, prompts: List[np.ndarray], max_new: int = 32,
                 greedy: bool = True, seed: int = 0,
                 continuous: Optional[bool] = None) -> List[GenResult]:
        """Compatibility wrapper. `continuous=None` auto-routes requests
        through the slot-paged ContinuousEngine when the config supports
        it. Both paths draw each request's sampled tokens from its own
        fold_in(PRNGKey(seed), rid) stream with rid pinned to the prompt
        index, so greedy AND sampled output are token-identical between
        the paged path and the legacy length-bucketed waves
        (`continuous=False`, kept as the pre-paged parity baseline)."""
        if continuous is None:
            continuous = model.supports_paged(self.cfg)
        if continuous:
            return self.continuous().generate(prompts, max_new=max_new,
                                              greedy=greedy, seed=seed)
        buckets: dict[int, List[int]] = {}
        for i, p in enumerate(prompts):
            buckets.setdefault(len(p), []).append(i)
        results: List[Optional[GenResult]] = [None] * len(prompts)
        for plen, idxs in sorted(buckets.items()):
            wave = [prompts[i] for i in idxs]
            for i, r in zip(idxs, self.generate_wave(wave, max_new,
                                                     greedy, seed,
                                                     rids=idxs)):
                results[i] = r
        return results

    def generate_wave(self, prompts: List[np.ndarray], max_new: int = 32,
                      greedy: bool = True, seed: int = 0,
                      rids: Optional[List[int]] = None) -> List[GenResult]:
        """prompts: list of 1-D int32 token arrays of EQUAL length.

        Sampled draws come from per-request streams
        fold_in(fold_in(PRNGKey(seed), rid), step) — the same computation
        the continuous engine's `_sample_rows` performs — so a request's
        tokens depend only on (seed, rid, its own logits), never on the
        wave's composition. `rids` defaults to the batch index."""
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        assert all(len(p) == plen for p in prompts), \
            "generate_wave requires equal-length prompts (use generate())"
        toks = np.stack([np.asarray(p, np.int32) for p in prompts])
        batch = {"tokens": jnp.asarray(toks)}
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        cache = self._grow_cache(cache, b)

        outs = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        if not greedy:
            if rids is None:
                rids = list(range(b))
            root = jax.random.PRNGKey(seed)
            keys = jnp.asarray(np.stack([
                np.asarray(jax.random.fold_in(root, r)) for r in rids]))
            gflags = jnp.zeros(b, bool)
        t1 = time.perf_counter()
        for step in range(max_new):
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            else:
                tok = _sample_rows(logits, keys,
                                   jnp.full((b,), step, jnp.int32),
                                   gflags)[:, None]
            tok_np = np.asarray(tok)[:, 0]
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(tok_np[i]))
                    if tok_np[i] == self.eos_id:
                        done[i] = True
            if done.all():
                break
            pos = jnp.int32(min(plen + step, self.max_len - 1))
            logits, cache = self._decode(self.params, cache, tok, pos)
        t_decode = time.perf_counter() - t1
        return [GenResult(outs[i], len(prompts[i]), t_prefill, t_decode)
                for i in range(b)]
