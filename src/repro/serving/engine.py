"""Generation engines.

`ContinuousEngine` is the request-centric serving core: a slot-paged KV
cache (fixed [slots, max_len] pages — [slots, window] rings for
sliding-window configs, int8 values + per-slot scales for `kv_quant`
configs — with per-slot position/kv_len vectors fed to decode_attention),
`submit()`/`step()` lifecycle, admission of a queued prompt into any slot
the step after its occupant hits EOS, and prefill of admitted prompts
chunked into the running decode loop so a long prompt never stalls other
slots for more than one chunk. Both greedy and sampled requests run here:
each sampled request draws from its own PRNG stream
`fold_in(PRNGKey(seed), request_id)` advanced by a per-request draw
counter, so its tokens are bit-identical regardless of co-residents
(DESIGN.md §10).

`Engine` keeps the legacy wave surface: `generate()` is now a thin
compatibility wrapper that routes requests through a shared
`ContinuousEngine` whenever the config supports the paged path
(`model.supports_paged`: the dense and moe text families, including
sliding-window and int8-KV — greedy token output is identical to the
wave path, see tests/test_serving.py and tests/test_paged_families.py),
and falls back to fixed length-bucketed waves (`generate_wave`) for the
families without paged KV (M-RoPE, encdec, recurrent state).
`generate(..., continuous=False)` forces the legacy wave path, which
remains the parity baseline every serving bench compares against.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model


@dataclass
class GenResult:
    """One finished generation: decoded token ids (including the EOS, if
    hit), the prompt length after any page truncation, and measured
    prefill / decode wall time attributed to this request."""
    tokens: List[int]
    prompt_len: int
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def ttft_s(self) -> float:
        """Time to first token == the measured prefill time (the first
        token is drawn from the prefill logits)."""
        return self.prefill_s


@dataclass
class EngineEvent:
    """One request-visible state change from a `ContinuousEngine.step()`:
    kind is "admitted" (slot assigned, prefill starting), "token" (one new
    token id in `token`), or "done" (`result` carries the GenResult)."""
    rid: int
    kind: str
    token: Optional[int] = None
    result: Optional[GenResult] = None


@dataclass
class _Request:
    """Engine-internal per-request state: prompt, prefill/decode
    progress, the occupied slot, timing, and the sampling mode/stream."""
    rid: int
    prompt: np.ndarray
    max_new: int
    submitted_s: float
    tokens: List[int] = field(default_factory=list)
    filled: int = 0                  # prefill progress (tokens in the page)
    slot: int = -1
    prefill_s: float = 0.0
    decode_s: float = 0.0
    greedy: bool = True
    # sampled requests only: this request's own PRNG stream root,
    # fold_in(PRNGKey(seed), rid); draw t folds in t = len(tokens)
    key: Optional[object] = None


class ContinuousEngine:
    """Continuous (slot-level) batching over a paged KV cache.

    The cache is one fixed [L, slots, max_len, G, dh] allocation (the
    seq dim shrinks to `window` for sliding-window configs — each slot
    keeps a [window] ring with its own write cursor `pos % window`; for
    `kv_quant` configs the values are int8 with per-slot [L, slots, S, G]
    scales); each slot is an independent page with its own `pos` (kv
    length). Decode steps run all slots at once through
    `model.decode_step_paged`; admission prefill runs one `prefill_chunk`
    slice of one prompt per slot per step through
    `model.prefill_chunk_paged`, interleaved with decode, so the running
    requests keep streaming while a new prompt fills its page. A slot
    freed by EOS (or max_new / page exhaustion) admits the next queued
    request on the following step.

    Sampling: `submit(..., greedy=False, seed=s)` gives the request its
    own PRNG stream `fold_in(PRNGKey(s), rid)`; draw t folds in the
    number of tokens already emitted. Because paged decode rows are
    independent and the stream depends only on (seed, rid), a request's
    sampled tokens are bit-identical whatever else is co-resident.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, eos_id: int = 2,
                 prefill_chunk: int = 32):
        """Allocate the paged cache (`slots` pages of `max_len` positions,
        rounded up to whole prefill chunks; `min(max_len, window)` ring
        positions for sliding-window configs) and jit the paged decode /
        chunk-prefill executables. Raises ValueError for configs without
        slot-paged support (`model.supports_paged`)."""
        if not model.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name}: family/config without slot-paged KV support "
                "(use Engine's wave path)")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_chunk = prefill_chunk
        # pages are allocated rounded UP to a whole number of prefill
        # chunks: dynamic_update_slice CLAMPS an out-of-bounds start, so a
        # final chunk crossing the page end would silently shift backwards
        # over earlier prompt positions; with the padded allocation every
        # chunk write fits, and the tail positions (>= max_len) are never
        # attended because kv_len masking tops out at max_len
        self._page_len = -(-max_len // prefill_chunk) * prefill_chunk
        self.cache = model.init_cache(cfg, slots, self._page_len,
                                      dtype=model.compute_dtype(cfg))
        self._decode = jax.jit(
            lambda p, c, t, pos, act: model.decode_step_paged(
                cfg, p, c, t, pos, act),
            donate_argnums=(1,))
        self._chunk = jax.jit(
            lambda p, c, t, slot, off, lim: model.prefill_chunk_paged(
                cfg, p, c, t, slot, off, lim, page_len=self._page_len),
            donate_argnums=(1,))
        # host-side slot state
        self.pos = np.zeros(slots, np.int32)
        self.last_tok = np.zeros(slots, np.int32)
        self.active = np.zeros(slots, bool)      # decoding (prefill done)
        self._occupant: List[Optional[_Request]] = [None] * slots
        self.queue: Deque[_Request] = deque()
        self._inflight: Dict[int, _Request] = {}
        self._next_rid = 0
        # utilisation counters (decode steps only)
        self.steps = 0
        self.active_slot_steps = 0
        self.cancelled = 0

    def clone(self, *, slots: Optional[int] = None) -> "ContinuousEngine":
        """An independent replica: same params/config, its own paged cache
        and slot state (the SlotScheduler's unit of failover)."""
        return ContinuousEngine(
            self.cfg, self.params, slots=slots or self.slots,
            max_len=self.max_len, eos_id=self.eos_id,
            prefill_chunk=self.prefill_chunk)

    # ------------------------------------------------------------- intake

    def submit(self, prompt: np.ndarray, max_new: int = 32,
               rid: Optional[int] = None, *, greedy: bool = True,
               seed: int = 0) -> int:
        """Queue one request; returns its rid. The prompt is truncated to
        the last max_len - max_new tokens so the page can always hold the
        whole generation. `greedy=False` samples from this request's own
        PRNG stream `fold_in(PRNGKey(seed), rid)` — pass an explicit
        `rid` to make a sampled request's draws reproducible across
        engines/runs regardless of what else is co-resident."""
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        p = np.asarray(prompt, np.int32).reshape(-1)
        keep = max(self.max_len - max_new, 1)
        req = _Request(rid, p[-keep:], max_new, time.perf_counter(),
                       greedy=greedy)
        if not greedy:
            req.key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
        self.queue.append(req)
        self._inflight[rid] = req
        return rid

    def _draw(self, req: _Request, row: np.ndarray) -> int:
        """Next token for `req` from its logits row [V]. Greedy: argmax.
        Sampled: categorical under fold_in(req.key, t) where t is the
        number of tokens already emitted — the draw depends only on
        (seed, rid, t, row), never on co-residents."""
        if req.greedy:
            return int(np.argmax(row))
        key = jax.random.fold_in(req.key, len(req.tokens))
        return int(jax.random.categorical(key, jnp.asarray(row)))

    @property
    def pending(self) -> int:
        """Requests still in flight (queued, prefilling or decoding)."""
        return len(self._inflight)

    def free_slots(self) -> int:
        """Slots with no occupant (neither decoding nor admitting)."""
        return sum(1 for r in self._occupant if r is None)

    def available_slots(self) -> int:
        """Admission capacity: free slots minus already-queued requests
        (what a scheduler should look at, not raw free_slots)."""
        return self.free_slots() - len(self.queue)

    def cancel(self, rid: int) -> bool:
        """Abandon one in-flight request (deadline expiry, hedged copy
        superseded, scheduler failover): its slot is freed immediately —
        the next `step()` can admit a queued prompt into it — and no
        further events are emitted for the rid. Returns False when the
        rid is unknown or already finished."""
        req = self._inflight.pop(rid, None)
        if req is None:
            return False
        try:
            self.queue.remove(req)
        except ValueError:
            pass
        s = req.slot
        if s >= 0 and self._occupant[s] is req:
            self._occupant[s] = None
            self.active[s] = False
        self.cancelled += 1
        return True

    # ------------------------------------------------------------- stepping

    def _finish(self, req: _Request, events: List[EngineEvent]) -> None:
        """Free the request's slot and emit its terminal "done" event."""
        s = req.slot
        self.active[s] = False
        self._occupant[s] = None
        self._inflight.pop(req.rid, None)
        events.append(EngineEvent(req.rid, "done", result=GenResult(
            req.tokens, len(req.prompt), req.prefill_s, req.decode_s)))

    def _emit_token(self, req: _Request, tok: int,
                    events: List[EngineEvent]) -> None:
        """Record one emitted token; finish the request on EOS/max_new."""
        req.tokens.append(tok)
        events.append(EngineEvent(req.rid, "token", token=tok))
        if tok == self.eos_id or len(req.tokens) >= req.max_new:
            self._finish(req, events)

    def _admit(self, events: List[EngineEvent]) -> None:
        """Assign queued requests to free slots (prefill starts on the
        same step, via `_prefill_step`)."""
        for s in range(self.slots):
            if self._occupant[s] is None and self.queue:
                req = self.queue.popleft()
                req.slot, req.filled = s, 0
                self._occupant[s] = req
                self.active[s] = False
                events.append(EngineEvent(req.rid, "admitted"))

    def _prefill_step(self, events: List[EngineEvent]) -> None:
        """Advance every admitting slot by one prompt chunk."""
        c = self.prefill_chunk
        for s in range(self.slots):
            req = self._occupant[s]
            if req is None or self.active[s]:
                continue
            t0 = time.perf_counter()
            chunk = req.prompt[req.filled:req.filled + c]
            real = len(chunk)
            if real < c:
                chunk = np.concatenate([chunk, np.zeros(c - real, np.int32)])
            logits, self.cache = self._chunk(
                self.params, self.cache, jnp.asarray(chunk[None]),
                jnp.int32(s), jnp.int32(req.filled),
                jnp.int32(req.filled + real))
            req.filled += real
            if req.filled >= len(req.prompt):
                row = np.asarray(logits, np.float32)[0, real - 1]
                tok = self._draw(req, row)
                self.pos[s] = len(req.prompt)
                self.last_tok[s] = tok
                self.active[s] = True
                req.prefill_s += time.perf_counter() - t0
                self._emit_token(req, tok, events)
            else:
                req.prefill_s += time.perf_counter() - t0

    def _decode_step(self, events: List[EngineEvent]) -> None:
        """One `decode_step_paged` over every active slot, then one token
        draw per slot from its own row (greedy argmax or the request's
        private PRNG stream — see `_draw`)."""
        if not self.active.any():
            return
        t0 = time.perf_counter()
        posv = np.minimum(self.pos, self.max_len - 1)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok[:, None]),
            jnp.asarray(posv), jnp.asarray(self.active))
        # all-greedy steps transfer only [slots] argmax ints; the full
        # [slots, V] logits come to host only when a sampled occupant
        # needs its row for a categorical draw
        sampled = any(self.active[s] and not self._occupant[s].greedy
                      for s in range(self.slots))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        logits_np = np.asarray(logits, np.float32) if sampled else None
        dt = time.perf_counter() - t0
        self.steps += 1
        self.active_slot_steps += int(self.active.sum())
        for s in range(self.slots):
            if not self.active[s]:
                continue
            req = self._occupant[s]
            req.decode_s += dt
            self.pos[s] += 1
            tok = int(nxt[s]) if req.greedy else self._draw(
                req, logits_np[s])
            self.last_tok[s] = tok
            self._emit_token(req, tok, events)

    def step(self) -> List[EngineEvent]:
        """One engine step: admit queued prompts into freed slots, advance
        each admitting slot by one prefill chunk, then run one decode step
        over all active slots. Returns the request events it produced."""
        events: List[EngineEvent] = []
        self._admit(events)
        self._prefill_step(events)
        self._decode_step(events)
        return events

    def utilisation(self) -> float:
        """Mean fraction of slots doing useful decode work per step."""
        return self.active_slot_steps / max(self.steps * self.slots, 1)

    # ----------------------------------------------------------- draining

    def warmup(self) -> None:
        """Compile the chunk-prefill and paged-decode executables off the
        measured path (shapes are fixed, so one tiny request covers it)."""
        self.generate([np.arange(2, dtype=np.int32)], max_new=2)
        self.steps = self.active_slot_steps = 0

    def generate(self, prompts: List[np.ndarray], max_new: int = 32,
                 greedy: bool = True, seed: int = 0) -> List[GenResult]:
        """Batch convenience: submit everything, step until drained.
        `greedy=False` samples each request from its own
        fold_in(PRNGKey(seed), rid) stream; rids are pinned to the batch
        index so the same (prompts, seed) call draws the same tokens no
        matter what the engine served before."""
        assert not self._inflight, "generate() on a busy engine"
        rids = [self.submit(p, max_new, rid=i, greedy=greedy, seed=seed)
                for i, p in enumerate(prompts)]
        results: Dict[int, GenResult] = {}
        while self._inflight:
            for ev in self.step():
                if ev.kind == "done":
                    results[ev.rid] = ev.result
        return [results[r] for r in rids]


class Engine:
    """Serving engine over one model: `generate()` auto-routes through a
    shared slot-paged `ContinuousEngine` for paged-capable configs and
    falls back to the legacy length-bucketed wave path
    (`generate_wave`) for the rest (M-RoPE, encdec, recurrent state) or
    when forced with `continuous=False`."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 eos_id: int = 2, prefill_chunk: Optional[int] = None,
                 slots: int = 4):
        """`max_len`: page/cache budget per request (prompt + generation);
        `slots`: default concurrent-request count of the shared
        ContinuousEngine; `prefill_chunk`: tokens per admission prefill
        chunk (continuous path only)."""
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self.slots = slots
        self.prefill_chunk = prefill_chunk or 32
        self._prefill = jax.jit(
            lambda p, b: model.prefill(cfg, p, b))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))
        self._cont: Dict[int, ContinuousEngine] = {}

    def continuous(self, slots: Optional[int] = None) -> ContinuousEngine:
        """The shared slot-paged engine over the same params/KV budget
        (one per slot count — the decode jit keys on it)."""
        n = slots or self.slots
        if n not in self._cont:
            self._cont[n] = ContinuousEngine(
                self.cfg, self.params, slots=n, max_len=self.max_len,
                eos_id=self.eos_id, prefill_chunk=self.prefill_chunk)
        return self._cont[n]

    def _grow_cache(self, cache, b: int):
        """Caches come back sized to the prompt; decode needs max_len —
        capped at the sliding window for SWA configs: growing a ring past
        its window would change the `pos % len` cursor modulus that the
        prefill roll already baked into the layout."""
        target = self.max_len
        if self.cfg.family in ("dense", "moe") and self.cfg.sliding_window:
            target = min(target, self.cfg.sliding_window)

        def grow(x):
            if x.ndim in (4, 5) and x.shape[2] < target:
                pad = target - x.shape[2]
                z = jnp.zeros(x.shape[:2] + (pad,) + x.shape[3:], x.dtype)
                return jnp.concatenate([x, z], axis=2)
            return x
        if self.cfg.family in ("dense", "moe", "encdec"):
            grown = dict(cache)
            for k in ("k", "v", "k_s", "v_s"):
                if k in grown and not k.startswith("cross"):
                    grown[k] = grow(grown[k])
            return grown
        return cache  # state caches (mamba2/rglru) are fixed-size

    def generate(self, prompts: List[np.ndarray], max_new: int = 32,
                 greedy: bool = True, seed: int = 0,
                 continuous: Optional[bool] = None) -> List[GenResult]:
        """Compatibility wrapper. `continuous=None` auto-routes requests
        through the slot-paged ContinuousEngine when the config supports
        it (greedy output is token-identical to the wave path; sampled
        requests draw from per-request fold_in(PRNGKey(seed), rid)
        streams, so their tokens don't depend on what else is in the
        batch). `False` forces the legacy length-bucketed waves (equal
        lengths keep causal semantics exact without pad masking; wave
        sampling advances one shared key, so its draws DO depend on the
        batch composition — kept only as the pre-paged baseline)."""
        if continuous is None:
            continuous = model.supports_paged(self.cfg)
        if continuous:
            return self.continuous().generate(prompts, max_new=max_new,
                                              greedy=greedy, seed=seed)
        buckets: dict[int, List[int]] = {}
        for i, p in enumerate(prompts):
            buckets.setdefault(len(p), []).append(i)
        results: List[Optional[GenResult]] = [None] * len(prompts)
        for plen, idxs in sorted(buckets.items()):
            wave = [prompts[i] for i in idxs]
            for i, r in zip(idxs, self.generate_wave(wave, max_new,
                                                     greedy, seed)):
                results[i] = r
        return results

    def generate_wave(self, prompts: List[np.ndarray], max_new: int = 32,
                      greedy: bool = True, seed: int = 0) -> List[GenResult]:
        """prompts: list of 1-D int32 token arrays of EQUAL length."""
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        assert all(len(p) == plen for p in prompts), \
            "generate_wave requires equal-length prompts (use generate())"
        toks = np.stack([np.asarray(p, np.int32) for p in prompts])
        batch = {"tokens": jnp.asarray(toks)}
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        cache = self._grow_cache(cache, b)

        outs = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        key = jax.random.PRNGKey(seed)
        t1 = time.perf_counter()
        tok = None
        for step in range(max_new):
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits)[:, None]
            tok_np = np.asarray(tok)[:, 0]
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(tok_np[i]))
                    if tok_np[i] == self.eos_id:
                        done[i] = True
            if done.all():
                break
            pos = jnp.int32(min(plen + step, self.max_len - 1))
            logits, cache = self._decode(self.params, cache, tok, pos)
        t_decode = time.perf_counter() - t1
        return [GenResult(outs[i], len(prompts[i]), t_prefill, t_decode)
                for i in range(b)]
