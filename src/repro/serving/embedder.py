"""Embedding backends for the RAG pipelines.

ModelEmbedder: the gte-small-style bidirectional encoder (mean-pooled,
unit-norm), jitted, batched — the paper's embedding model.
HashEmbedder: deterministic hashed bag-of-words + fixed random projection,
unit-norm — fast CPU proxy with real lexical-overlap semantics, used by
tests and benchmarks where model quality is not the subject.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List

import numpy as np

from repro.data.tokenizer import HashTokenizer

if TYPE_CHECKING:
    from repro.config import ModelConfig


class HashEmbedder:
    def __init__(self, dim: int = 384, vocab: int = 32768, seed: int = 0):
        self.dim = dim
        self.vocab = vocab
        self.tok = HashTokenizer(vocab)
        rng = np.random.default_rng(seed)
        self.proj = rng.normal(0, 1 / np.sqrt(dim),
                               (vocab, dim)).astype(np.float32)
        self.idf = np.ones(vocab, np.float32)
        self.fitted = False

    def fit(self, texts: List[str]) -> "HashEmbedder":
        df = np.zeros(self.vocab, np.float32)
        for t in texts:
            for i in set(self.tok.encode(t)):
                df[i] += 1
        n = max(len(texts), 1)
        self.idf = np.log((n + 1) / (df + 1)) + 1.0
        self.fitted = True
        return self

    def __call__(self, texts: List[str]) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            ids = self.tok.encode(t)
            if ids:
                ids = np.asarray(ids)
                v = (self.proj[ids] * self.idf[ids][:, None]).sum(0)
                n = np.linalg.norm(v)
                out[i] = v / n if n > 0 else v
        return out


class ModelEmbedder:
    def __init__(self, cfg: "ModelConfig", params, tokenizer: HashTokenizer,
                 max_len: int = 64):
        # model stack imported lazily: HashEmbedder consumers (SCR tests,
        # benchmarks) must not pay for — or break on — the full model deps
        import jax

        from repro.models import model
        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        self.max_len = max_len
        self._encode = jax.jit(lambda p, b: model.encode(cfg, p, b))

    @property
    def dim(self) -> int:
        return self.cfg.d_model

    def __call__(self, texts: List[str]) -> np.ndarray:
        import jax.numpy as jnp
        toks = self.tok.encode_batch(texts, self.max_len)
        mask = (toks != self.tok.pad_id).astype(np.float32)
        out = self._encode(self.params, {"tokens": jnp.asarray(toks),
                                         "mask": jnp.asarray(mask)})
        return np.asarray(out)
