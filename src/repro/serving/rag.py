"""The four RAG pipelines the paper compares (Figure 1, Table 5):

  Naive-RAG    : vector search -> full docs -> sLM.
  Advanced-RAG : vector search (wider) -> re-ranker -> full docs -> sLM.
  EdgeRAG      : IVF-DISK index + embedding cache -> full docs -> sLM.
  MobileRAG    : EcoVector -> SCR (condense + reorder) -> sLM.

Each `answer()` returns the final prompt, timing breakdown, token counts,
and the paper-model TTFT/energy estimates (Table 6 speeds; §3.4.3 power),
so Table-5-style comparisons run offline without a phone.
"""
from __future__ import annotations

import os
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.analytical import HW, energy_mj
from repro.core.baselines import IVFDisk
from repro.core.ecovector import EcoVector
from repro.core.scr import (SCRConfig, SCRResult, apply_scr, apply_scr_batch,
                            build_prompt)
from repro.core.window_index import WindowIndex

# Table 6: measured on Galaxy S24
SLM_SPEEDS = {
    "qwen25_0_5b": {"prompt_tps": 90.0, "gen_tps": 14.5, "batt_pct_1k": 0.10},
    "qwen25_1_5b": {"prompt_tps": 50.0, "gen_tps": 10.0, "batt_pct_1k": 0.30},
    "deepseek_r1_1_5b": {"prompt_tps": 35.0, "gen_tps": 9.0,
                         "batt_pct_1k": 0.36},
}
BATTERY_J = 4000e-3 * 3600 * 3.8  # 4000 mAh at 3.8 V -> ~54.7 kJ


@dataclass
class RAGAnswer:
    prompt: str
    doc_ids: List[int]
    retrieval_s: float
    post_s: float                   # re-rank / SCR time
    prompt_tokens: int
    ttft_model_s: float             # retrieval + post + prompt eval (model)
    energy_model_j: float
    scr: Optional[SCRResult] = None
    generated: Optional[str] = None
    # real-generation fields, filled by answer(..., generate=True): token
    # ids decoded by serving.Engine on the reduced on-device sLM, and the
    # MEASURED prefill+first-token time (vs the Table-6 ttft_model_s model)
    gen_tokens: Optional[List[int]] = None
    ttft_measured_s: Optional[float] = None


def _tok_count(text: str) -> int:
    return len(text.split())


class RAGBase:
    name = "base"
    # Retrieval through the index's fused batched device path
    # (EcoVector.search_device_batched) when available. False = host
    # search; True = always device; None = auto (device on TPU only — the
    # interpret-mode Pallas path on other backends is correctness-grade,
    # not a serving fast path). MobileRAG defaults to auto.
    device_retrieval: Optional[bool] = False

    def __init__(self, docs: Sequence[str], embed: Callable, *,
                 top_k: int = 3, slm: str = "qwen25_0_5b", index=None,
                 generator: Optional[Callable] = None,
                 device_retrieval: Optional[bool] = None,
                 gen_arch: str = "qwen25_0_5b",
                 device_budget_bytes: Optional[float] = None,
                 _skip_corpus_embed: bool = False):
        self.docs = list(docs)
        self.embed = embed
        self.top_k = top_k
        # IVF probe width for every retrieval; the SLO controller's
        # degrade ladder (serving/session.py) lowers it under deadline
        # pressure and restores it after the chunk
        self.n_probe = 4
        # device-memory budget for the retrieval index (DESIGN.md §14):
        # None = all-resident; an int is bytes; a float in (0, 1] is a
        # fraction of the all-resident pack. Builds a TieredEcoVector.
        self.device_budget_bytes = device_budget_bytes
        self.slm = SLM_SPEEDS[slm]
        self.generator = generator
        # degradation-ladder state: on an index-search exception the
        # pipeline answers from the last good retrieval (or the corpus
        # head) instead of raising — counted, never silent
        self.retrieval_fallbacks = 0
        self._last_good_ids: Optional[List[List[int]]] = None
        # arch for answer(..., generate=True); the Table-6 `slm` keys are
        # speed models only — real generation always runs a config that
        # exists in repro.configs (reduced to CPU smoke size)
        self.gen_arch = gen_arch
        self._slm_engine = None
        if device_retrieval is not None:
            self.device_retrieval = device_retrieval
        if hasattr(embed, "fit") and not getattr(embed, "fitted", True):
            embed.fit(self.docs)
        t0 = time.perf_counter()
        # a pipeline restored from a durable snapshot skips the corpus
        # embed entirely — the whole point of persisting retrieval state
        self.doc_vecs = (None if (_skip_corpus_embed and index is not None)
                         else np.asarray(embed(self.docs), np.float32))
        self.index = index or self._build_index()
        if (self.device_budget_bytes is not None
                and hasattr(self.index, "set_device_budget")):
            self.index.set_device_budget(
                self._resolve_device_budget(self.index))
        self.build_s = time.perf_counter() - t0

    def _resolve_device_budget(self, index) -> int:
        b = self.device_budget_bytes
        if 0 < b <= 1.0:             # fraction of the all-resident pack
            return int(b * index.all_resident_bytes())
        return int(b)

    def _build_index(self):
        n_clusters = max(4, len(self.docs) // 64)
        if self.device_budget_bytes is not None:
            from repro.core.tiered import TieredEcoVector
            return TieredEcoVector(
                self.doc_vecs.shape[1],
                n_clusters=n_clusters).build(self.doc_vecs)
        ev = EcoVector(self.doc_vecs.shape[1], n_clusters=n_clusters)
        return ev.build(self.doc_vecs)

    def _use_device_retrieval(self) -> bool:
        if self.device_retrieval is None:
            import jax
            return jax.default_backend() == "tpu"
        return self.device_retrieval

    def _retrieve_batch(self, qvs: np.ndarray, k: int) -> List[List[int]]:
        """Retrieve for a [B, d] batch of query vectors in one call when
        the index has a batched device path, else per-query host search.
        An index exception degrades instead of failing the request: the
        last good retrieval's ids (or the corpus head) are reused and
        `retrieval_fallbacks` counts the decision."""
        qvs = np.atleast_2d(np.asarray(qvs, np.float32))
        try:
            if self._use_device_retrieval() and hasattr(
                    self.index, "search_device_batched"):
                ids_b, _ = self.index.search_device_batched(
                    qvs, k=k, n_probe=self.n_probe)
            else:
                ids_b = [self.index.search(qv, k=k, n_probe=self.n_probe)[0]
                         for qv in qvs]
        except Exception:
            self.retrieval_fallbacks += 1
            return self._fallback_ids(len(qvs), k)
        clean = [[int(i) for i in row if 0 <= int(i) < len(self.docs)]
                 for row in ids_b]
        self._last_good_ids = clean
        return clean

    def _fallback_ids(self, n: int, k: int) -> List[List[int]]:
        """Stale-but-serviceable doc ids when the index is down: cycle
        the last successful batch's rows, else the first k documents."""
        if self._last_good_ids:
            rows = self._last_good_ids
            return [list(rows[i % len(rows)]) for i in range(n)]
        return [list(range(min(k, len(self.docs)))) for _ in range(n)]

    def _retrieve(self, qv, k):
        return self._retrieve_batch(qv[None], k)[0]

    def _make_prompt(self, query: str, docs: List[str],
                     order: List[int]) -> str:
        ctx = "\n\n".join(f"[Doc {order[i] + 1}] {d}"
                          for i, d in enumerate(docs))
        return f"Context:\n{ctx}\n\nQuestion: {query}\nAnswer:"

    def _finalize(self, query, prompt, doc_ids, t_ret, t_post,
                  scr=None) -> RAGAnswer:
        ptok = _tok_count(prompt)
        t_eval = ptok / self.slm["prompt_tps"]
        ttft = t_ret + t_post + t_eval
        # energy: retrieval+post as CPU time (paper §3.4.3) + LM cost from
        # the battery-impact table
        e_cpu = energy_mj((t_ret + t_post) * 1e3, 0.0) * 1e-3
        e_lm = ptok / 1000.0 * self.slm["batt_pct_1k"] / 100.0 * BATTERY_J
        gen = None
        if self.generator is not None:
            gen = self.generator(prompt)
        return RAGAnswer(prompt, doc_ids, t_ret, t_post, ptok, ttft,
                         e_cpu + e_lm, scr, gen)

    # Pipelines with simple retrieve->post flows set `_finish(query, ids,
    # t_ret, qv=...)` and inherit the shared answer/answer_batch templates
    # below (`qv` is the already-embedded query vector, so post stages
    # never pay a second embedder forward).
    _finish = None

    # --------------------------------------------- real on-device decoding

    def _ensure_slm(self):
        if self._slm_engine is None:
            from repro.serving.slm import ReducedSLM
            self._slm_engine = ReducedSLM(self.gen_arch)
        return self._slm_engine

    def _attach_generation(self, answers: List[RAGAnswer],
                           max_new: int = 16) -> List[RAGAnswer]:
        """Run the final prompts through the real Engine decode loop (one
        fixed-shape wave for the whole list) and record the decoded token
        ids + measured prefill TTFT on each answer."""
        slm = self._ensure_slm()
        gens = slm.generate([a.prompt for a in answers], max_new=max_new)
        for a, g in zip(answers, gens):
            a.gen_tokens = g.tokens
            a.generated = g.text
            a.ttft_measured_s = g.ttft_s
        return answers

    def answer(self, query: str, *, generate: bool = False,
               max_new: int = 16) -> RAGAnswer:
        """One query end to end. With `generate=True` the answer carries
        REAL decoded tokens from serving.Engine (retrieval -> post -> LM
        generate on device), not just the analytical TTFT estimate."""
        if self._finish is None:
            raise NotImplementedError
        t0 = time.perf_counter()
        qv = np.asarray(self.embed([query]))[0]
        ids = self._retrieve(qv, self.top_k)
        t_ret = time.perf_counter() - t0
        ans = self._finish(query, ids, t_ret, qv=qv)
        if generate:
            self._attach_generation([ans], max_new=max_new)
        return ans

    def answer_batch(self, queries: Sequence[str], *,
                     generate: bool = False,
                     max_new: int = 16) -> List[RAGAnswer]:
        """Batched serving entry point: one embed + one (device-)batched
        retrieval for the whole query set, then per-query post-processing.
        Pipelines without a `_finish` hook fall back to per-query answers.
        `generate=True` routes through a RagSession over the continuous
        engine: retrieval/SCR for the next chunk of queries overlaps
        decode of the previous ones (DESIGN.md §9)."""
        queries = list(queries)
        if generate and queries:
            return self._answer_batch_generate(queries, max_new)
        if self._finish is None:
            return [self.answer(q) for q in queries]
        t0 = time.perf_counter()
        qvs = np.asarray(self.embed(queries), np.float32)
        ids_b = self._retrieve_batch(qvs, self.top_k)
        t_ret = (time.perf_counter() - t0) / max(len(queries), 1)
        return [self._finish(q, ids, t_ret, qv=qv)
                for q, ids, qv in zip(queries, ids_b, qvs)]

    # -------------------------------------------- request-centric serving

    def session(self, *, max_new: int = 16, slots: int = 4,
                retrieve_chunk: int = 4, greedy: bool = True,
                seed: int = 0, max_pending: Optional[int] = None,
                deadline_s: Optional[float] = None,
                trace=None, slo_s: Optional[float] = None):
        """A RagSession over this pipeline: submit/step/stream with
        continuous-batching decode (raises ValueError when `gen_arch`
        has no slot-paged KV path). `greedy=False` samples each request
        from its own co-residency-independent PRNG stream. `max_pending`
        bounds session admission (degrade past half, shed at the bound);
        `deadline_s` is the default per-request deadline. `trace` is a
        shared TraceSink (docs/OBSERVABILITY.md); `slo_s` turns on
        SLO-aware admission planned from the live trace window."""
        from repro.serving.session import RagSession
        return RagSession(self, max_new=max_new, slots=slots,
                          retrieve_chunk=retrieve_chunk, greedy=greedy,
                          seed=seed, max_pending=max_pending,
                          deadline_s=deadline_s, trace=trace, slo_s=slo_s)

    def stream(self, queries: Sequence[str] = (), *, max_new: int = 16,
               slots: int = 4, retrieve_chunk: int = 4):
        """Event generator (submitted/retrieved/condensed/token/done) for
        a batch of queries through a fresh RagSession."""
        return self.session(max_new=max_new, slots=slots,
                            retrieve_chunk=retrieve_chunk).stream(queries)

    def _answer_batch_generate(self, queries: List[str],
                               max_new: int) -> List[RAGAnswer]:
        """generate=True body: a RagSession pipelines retrieval/SCR chunks
        into the continuous decode loop. Falls back to condense-everything
        + one legacy Engine wave for archs without paged KV support."""
        try:
            sess = self.session(max_new=max_new)
        except ValueError:
            out = self.answer_batch(queries, generate=False)
            return self._attach_generation(out, max_new=max_new)
        return sess.run(queries)


class NaiveRAG(RAGBase):
    name = "Naive-RAG"

    def _finish(self, query: str, ids: List[int], t_ret: float,
                qv=None) -> RAGAnswer:
        prompt = self._make_prompt(query, [self.docs[i] for i in ids], ids)
        return self._finalize(query, prompt, ids, t_ret, 0.0)


class AdvancedRAG(RAGBase):
    """Re-Ranker: re-scores a wider candidate set with a second pass
    (max sentence similarity — the lightweight stand-in for the re-rank
    model, which adds the post-retrieval latency the paper measures)."""
    name = "Advanced-RAG"

    def answer(self, query: str, *, generate: bool = False,
               max_new: int = 16) -> RAGAnswer:
        t0 = time.perf_counter()
        qv = np.asarray(self.embed([query]))[0]
        ids = self._retrieve(qv, self.top_k * 3)
        t_ret = time.perf_counter() - t0
        t1 = time.perf_counter()
        from repro.core.scr import split_sentences
        scores = []
        for i in ids:
            sents = split_sentences(self.docs[i]) or [self.docs[i]]
            sv = np.asarray(self.embed(sents))
            scores.append(float(np.max(sv @ qv)))
        order = np.argsort(scores)[::-1][: self.top_k]
        ids = [ids[i] for i in order]
        t_post = time.perf_counter() - t1
        prompt = self._make_prompt(query, [self.docs[i] for i in ids], ids)
        ans = self._finalize(query, prompt, ids, t_ret, t_post)
        if generate:
            self._attach_generation([ans], max_new=max_new)
        return ans


class EdgeRAG(RAGBase):
    """IVF-DISK retrieval + embedding cache (the paper's EdgeRAG baseline).

    The query-embedding cache is a bounded LRU (`qcache_cap` entries) so a
    long-running query stream cannot grow it without limit; hit/miss
    counters feed the serving benchmarks."""
    name = "EdgeRAG"
    qcache_cap = 256

    def _build_index(self):
        idx = IVFDisk(self.doc_vecs.shape[1],
                      n_clusters=max(4, len(self.docs) // 64))
        idx.build(self.doc_vecs)
        self._qcache: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self.qcache_hits = 0
        self.qcache_misses = 0
        return idx

    def _embed_query_cached(self, query: str) -> np.ndarray:
        qv = self._qcache.get(query)
        if qv is not None:
            self._qcache.move_to_end(query)     # LRU promotion
            self.qcache_hits += 1
            return qv
        qv = np.asarray(self.embed([query]))[0]
        self.qcache_misses += 1
        self._qcache[query] = qv
        while len(self._qcache) > self.qcache_cap:
            self._qcache.popitem(last=False)    # evict LRU head
        return qv

    def answer(self, query: str, *, generate: bool = False,
               max_new: int = 16) -> RAGAnswer:
        t0 = time.perf_counter()
        qv = self._embed_query_cached(query)
        ids = self._retrieve(qv, self.top_k)
        t_ret = time.perf_counter() - t0
        prompt = self._make_prompt(query, [self.docs[i] for i in ids], ids)
        ans = self._finalize(query, prompt, ids, t_ret, 0.0)
        if generate:
            self._attach_generation([ans], max_new=max_new)
        return ans


class MobileRAG(RAGBase):
    """EcoVector + SCR (the paper's method). Retrieval runs on the fused
    batched EcoVector device path (route + scan in one jitted call); SCR
    runs against the corpus-resident window index (every document's
    windows split/embedded once at construction, DESIGN.md §6) with the
    fused `scr_select` kernel picking best windows on device —
    per-query post-retrieval work is one query embed, one kernel call,
    and host string assembly. `use_window_index=False` keeps the legacy
    re-embed-every-window-per-query path for before/after benchmarks."""
    name = "MobileRAG"
    device_retrieval = None          # auto: fused device path on TPU

    def __init__(self, docs: Sequence[str], embed: Callable, *,
                 scr: SCRConfig = SCRConfig(),
                 use_window_index: bool = True,
                 retrieval_state: Optional[str] = None, **kw):
        """`retrieval_state` points at a durable snapshot directory
        (DESIGN.md §12): when it holds a committed generation, EcoVector
        and the window index are restored from disk (WAL replayed, zero
        re-embedding); otherwise the pipeline builds normally and commits
        its first generation there. Subsequent index mutations are
        journaled; `save_retrieval()` compacts them into a new
        generation."""
        self.retrieval_state = retrieval_state
        loaded_index = None
        loaded_wi = None
        if retrieval_state is not None:
            loader = EcoVector.load
            if kw.get("device_budget_bytes") is not None:
                # budgeted pipeline: restore the tiered index so tier
                # assignment and the cold pack come back from the snapshot
                from repro.core.tiered import TieredEcoVector
                loader = TieredEcoVector.load
            loaded_index = self._load_state_part(
                loader, os.path.join(retrieval_state, "ecovector"))
            if use_window_index:
                loaded_wi = self._load_state_part(
                    lambda root: WindowIndex.load(embed, root),
                    os.path.join(retrieval_state, "windows"))
        if loaded_index is not None:
            super().__init__(docs, embed, index=loaded_index,
                             _skip_corpus_embed=True, **kw)
        else:
            super().__init__(docs, embed, **kw)
        self.scr_cfg = scr
        self.window_index = loaded_wi
        self.scr_build_s = 0.0
        self.scr_fallbacks = 0       # SCR stage raised -> full-doc prompt
        if use_window_index and self.window_index is None:
            t0 = time.perf_counter()
            self.window_index = WindowIndex(self.embed, scr).build(self.docs)
            self.scr_build_s = time.perf_counter() - t0
        if self.window_index is not None:
            self._sync_window_index()   # docs beyond the snapshot
        if retrieval_state is not None and (loaded_index is None
                                            or loaded_wi is None):
            self.save_retrieval()       # establish / complete the snapshot

    @staticmethod
    def _load_state_part(loader, root: str):
        """One component's restore: absent state means build-from-scratch
        (first run); corrupt state is a loud warning, then rebuild — a
        rotten snapshot must never brick pipeline construction."""
        from repro.core import store as _store
        try:
            return loader(root)
        except FileNotFoundError:
            return None
        except (_store.StoreError, OSError) as e:
            import warnings
            warnings.warn(f"retrieval state under {root} failed "
                          f"validation ({e}); rebuilding from source",
                          stacklevel=3)
            return None

    def save_retrieval(self, root: Optional[str] = None) -> None:
        """Commit the current retrieval state (EcoVector generation +
        window-index generation) under `root`/`retrieval_state`, folding
        any journaled mutations into the new snapshots."""
        root = root or self.retrieval_state
        if root is None:
            raise ValueError("no retrieval_state directory configured")
        self.retrieval_state = root
        if hasattr(self.index, "save"):
            self.index.save(os.path.join(root, "ecovector"))
        if self.window_index is not None:
            self.window_index.save(os.path.join(root, "windows"))

    def _sync_window_index(self):
        """Pick up documents appended to `self.docs` since the index was
        built (the retrieval-index update path): each new doc is one
        incremental `add` — only its block gets embedded and packed."""
        w = self.window_index
        while len(w) < len(self.docs):
            w.add(self.docs[len(w)])

    def _finish(self, query: str, ids: List[int], t_ret: float,
                qv=None) -> RAGAnswer:
        t1 = time.perf_counter()
        res = None
        try:
            if self.window_index is not None:
                self._sync_window_index()
                qvs = (None if qv is None
                       else np.asarray(qv, np.float32)[None])
                res = apply_scr_batch([query], [ids], self.window_index,
                                      self.embed, qvs=qvs)[0]
            else:
                res = apply_scr(query, [self.docs[i] for i in ids],
                                self.embed, self.scr_cfg)
        except Exception:
            # degradation ladder: SCR down -> serve the full retrieved
            # docs (NaiveRAG-shaped prompt) rather than fail the request
            self.scr_fallbacks += 1
        t_post = time.perf_counter() - t1
        if res is None:
            prompt = self._make_prompt(query, [self.docs[i] for i in ids],
                                       ids)
            return self._finalize(query, prompt, ids, t_ret, t_post)
        prompt = build_prompt(query, res)
        ids = [ids[i] for i in res.order]
        return self._finalize(query, prompt, ids, t_ret, t_post, scr=res)

    def answer_batch(self, queries: Sequence[str], *,
                     generate: bool = False,
                     max_new: int = 16) -> List[RAGAnswer]:
        """Fully batched MobileRAG: ONE query embed feeds both the fused
        EcoVector retrieval and the fused SCR select; everything after the
        two device calls is host-side string assembly. `generate=True`
        routes through the RagSession (whose retrieval chunks re-enter
        this fused path with generate=False) so SCR for the next chunk
        overlaps continuous decode of the previous one."""
        queries = list(queries)
        if self.window_index is None or not queries:
            return super().answer_batch(queries, generate=generate,
                                        max_new=max_new)
        if generate:
            return self._answer_batch_generate(queries, max_new)
        self._sync_window_index()
        t0 = time.perf_counter()
        qvs = np.asarray(self.embed(queries), np.float32)
        ids_b = self._retrieve_batch(qvs, self.top_k)
        t_ret = (time.perf_counter() - t0) / len(queries)
        t1 = time.perf_counter()
        try:
            results = apply_scr_batch(queries, ids_b, self.window_index,
                                      self.embed, qvs=qvs)
        except Exception:
            # SCR stage down for the whole batch: degrade every query to
            # its full retrieved docs instead of raising
            self.scr_fallbacks += 1
            t_post = (time.perf_counter() - t1) / len(queries)
            return [self._finalize(
                        q, self._make_prompt(q, [self.docs[i] for i in ids],
                                             ids), ids, t_ret, t_post)
                    for q, ids in zip(queries, ids_b)]
        t_post = (time.perf_counter() - t1) / len(queries)
        out = []
        for q, ids, res in zip(queries, ids_b, results):
            prompt = build_prompt(q, res)
            out.append(self._finalize(q, prompt,
                                      [ids[i] for i in res.order],
                                      t_ret, t_post, scr=res))
        return out


PIPELINES = {
    "naive": NaiveRAG,
    "advanced": AdvancedRAG,
    "edge": EdgeRAG,
    "mobile": MobileRAG,
}


def answer_in_context(example, ans: RAGAnswer) -> bool:
    """The planted answer sentence survived retrieval *and* (for
    MobileRAG) SCR condensation — the single accuracy predicate shared by
    every Table-5 consumer."""
    return example.answer.lower() in ans.prompt.lower()


def accuracy(pipe: RAGBase, examples, max_q: Optional[int] = None) -> float:
    """Answer-in-final-context accuracy: the retrieval-quality proxy for
    Table 5 accuracy (no on-device sLM here). Runs through `answer_batch`
    so Table-5 accuracy uses the fused batched retrieval/SCR path (one
    embed + one device retrieval + one SCR select for the whole set)."""
    exs = list(examples[:max_q])
    if not exs:
        return 0.0
    answers = pipe.answer_batch([ex.question for ex in exs])
    ok = sum(bool(answer_in_context(ex, a)) for ex, a in zip(exs, answers))
    return ok / len(exs)
