"""Request-centric RAG serving sessions.

A `RagSession` runs the full MobileRAG request lifecycle as an event
stream over a `ContinuousEngine`:

    submitted -> retrieved -> condensed -> token ... token -> done

`submit(query)` queues a request and returns its id; every `step()`
(1) retrieves + SCR-condenses up to `retrieve_chunk` queued queries in one
fused batch through the pipeline's `answer_batch`, hands the condensed
prompts to the engine, and (2) advances the engine one continuous-batching
step — so retrieval/SCR for query N+1 runs while query N's slots are still
decoding, instead of the whole batch blocking on the slowest member.
`stream(queries)` wraps submit+step into a generator of `RagEvent`s;
`run(queries)` drains to completed `RAGAnswer`s in submit order.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, Iterator, List, Optional

from collections import deque

from repro.serving.engine import ContinuousEngine

# request lifecycle states, in order
STATES = ("submitted", "retrieved", "condensed", "decoding", "done")


@dataclass
class RagRequest:
    """One query's lifecycle record inside a RagSession (state machine
    over `STATES`; `answer` carries the RAGAnswer once condensed and is
    completed in place when decode finishes)."""
    req_id: int
    query: str
    max_new: int
    state: str = "submitted"
    submitted_s: float = field(default_factory=time.perf_counter)
    done_s: Optional[float] = None
    answer: Optional[object] = None       # RAGAnswer once condensed

    @property
    def latency_s(self) -> Optional[float]:
        """submit -> done wall time (None while still in flight)."""
        return None if self.done_s is None else self.done_s - self.submitted_s


@dataclass
class RagEvent:
    """One request-visible state change. kind: "submitted" | "retrieved"
    (payload: doc id list) | "condensed" (payload: prompt token count) |
    "token" (payload: token id) | "done" (payload: completed RAGAnswer)."""
    req_id: int
    kind: str
    payload: object = None
    t: float = field(default_factory=time.perf_counter)


class RagSession:
    """Streaming session over one RAG pipeline + one ContinuousEngine."""

    def __init__(self, pipe, *, max_new: int = 16, slots: int = 4,
                 retrieve_chunk: int = 4, greedy: bool = True,
                 seed: int = 0):
        """`pipe`: a RAG pipeline with `_ensure_slm`/`answer_batch`.
        `greedy=False` samples every request from its own
        fold_in(PRNGKey(seed), engine-rid) stream (ContinuousEngine
        semantics: draws are independent of co-resident requests).
        Raises ValueError when the pipeline's generation arch has no
        slot-paged KV path (`model.supports_paged`)."""
        self.pipe = pipe
        self.max_new = max_new
        self.retrieve_chunk = retrieve_chunk
        self.greedy = greedy
        self.seed = seed
        slm = pipe._ensure_slm()
        self.engine: ContinuousEngine = slm.continuous(slots)  # may raise
        self._slm = slm
        self.requests: Dict[int, RagRequest] = {}
        self._queued: Deque[int] = deque()
        self._decoding: Dict[int, RagRequest] = {}   # engine rid -> request
        self._next_id = 0
        if not self.engine.pending:
            # compile the chunk-prefill/decode executables off the measured
            # path so the first request's ttft reports execution, not jit
            self.engine.warmup()

    # ------------------------------------------------------------- intake

    def submit(self, query: str, max_new: Optional[int] = None) -> int:
        """Queue one query; returns its request id. Retrieval/condense
        happens in a later `step()` (chunked, so it overlaps decode)."""
        rid = self._next_id
        self._next_id += 1
        req = RagRequest(rid, query, max_new or self.max_new)
        self.requests[rid] = req
        self._queued.append(rid)
        return rid

    @property
    def pending(self) -> int:
        """Requests not yet done (queued for retrieval or decoding)."""
        return len(self._queued) + len(self._decoding)

    # ----------------------------------------------------------- stepping

    def _retrieve_step(self, events: List[RagEvent]) -> None:
        """Retrieve + condense the next chunk of queued queries (one fused
        answer_batch call) and admit their prompts to the engine."""
        take = [self._queued.popleft()
                for _ in range(min(self.retrieve_chunk, len(self._queued)))]
        if not take:
            return
        reqs = [self.requests[r] for r in take]
        answers = self.pipe.answer_batch([r.query for r in reqs])
        for req, ans in zip(reqs, answers):
            req.answer = ans
            req.state = "condensed"
            events.append(RagEvent(req.req_id, "retrieved",
                                   list(ans.doc_ids)))
            events.append(RagEvent(req.req_id, "condensed",
                                   ans.prompt_tokens))
            prompt = self._slm.encode_prompt(ans.prompt, bucket=False)
            erid = self.engine.submit(prompt, req.max_new,
                                      greedy=self.greedy, seed=self.seed)
            self._decoding[erid] = req
            req.state = "decoding"

    def _engine_step(self, events: List[RagEvent]) -> None:
        """Advance the ContinuousEngine one step and translate its
        token/done events onto the session's requests."""
        tok = self._slm.tokenizer
        for ev in self.engine.step():
            req = self._decoding.get(ev.rid)
            if req is None:
                continue
            if ev.kind == "token":
                events.append(RagEvent(req.req_id, "token", ev.token))
            elif ev.kind == "done":
                del self._decoding[ev.rid]
                ans = req.answer
                ans.gen_tokens = list(ev.result.tokens)
                ans.generated = tok.decode(
                    [t for t in ev.result.tokens if t != tok.eos_id])
                ans.ttft_measured_s = ev.result.prefill_s
                req.state = "done"
                req.done_s = time.perf_counter()
                events.append(RagEvent(req.req_id, "done", ans))

    def step(self) -> List[RagEvent]:
        """Advance the session: one retrieval/condense chunk + one engine
        step. Returns the events produced (possibly empty when idle)."""
        events: List[RagEvent] = []
        self._retrieve_step(events)
        self._engine_step(events)
        return events

    # ----------------------------------------------------------- draining

    def stream(self, queries: Iterable[str] = ()) -> Iterator[RagEvent]:
        """Submit `queries`, then yield events until the session drains.
        More queries may be submitted concurrently from the consuming
        loop — the generator keeps stepping while anything is pending."""
        for q in queries:
            yield RagEvent(self.submit(q), "submitted")
        while self.pending:
            yield from self.step()

    def run(self, queries: Iterable[str]) -> List[object]:
        """Drain `queries` to completed RAGAnswers, in submit order."""
        rids = [self.submit(q) for q in queries]
        while self.pending:
            self.step()
        return [self.requests[r].answer for r in rids]
