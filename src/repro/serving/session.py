"""Request-centric RAG serving sessions.

A `RagSession` runs the full MobileRAG request lifecycle as an event
stream over a `ContinuousEngine`:

    submitted -> retrieved -> condensed -> token ... token -> done

`submit(query)` queues a request and returns its id; every `step()`
(1) retrieves + SCR-condenses up to `retrieve_chunk` queued queries in one
fused batch through the pipeline's `answer_batch`, hands the condensed
prompts to the engine, and (2) advances the engine one continuous-batching
step — so retrieval/SCR for query N+1 runs while query N's slots are still
decoding, instead of the whole batch blocking on the slowest member.
`stream(queries)` wraps submit+step into a generator of `RagEvent`s;
`run(queries)` drains to completed `RAGAnswer`s in submit order.

Robustness (the serve-under-fire contract): requests may carry a
`deadline_s` — an expired request is cancelled (its engine slot freed via
`ContinuousEngine.cancel`) and emits a terminal "shed" event; admission
can be bounded with `max_pending`, and under overload the session degrades
gracefully — smaller retrieval chunks and clamped `max_new` — before it
sheds; a retrieval/embedder exception inside a chunk is retried once
per-query in isolation, and a request that still fails emits a terminal
"failed" event instead of killing the stream. Every shed / degrade /
failure increments a `SessionCounters` field, so every submitted request
ends in exactly one terminal state: done, shed, or failed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, Iterator, List, Optional

from collections import deque

from repro.serving.engine import ContinuousEngine
from repro.serving.trace import SLOController, TraceSink

# request lifecycle states; "done" / "shed" / "failed" are terminal
STATES = ("submitted", "retrieved", "condensed", "decoding",
          "done", "shed", "failed")

_SESSION_SEQ = [0]


@dataclass
class RagRequest:
    """One query's lifecycle record inside a RagSession (state machine
    over `STATES`; `answer` carries the RAGAnswer once condensed and is
    completed in place when decode finishes). `expires_s` is the absolute
    deadline (None = unbounded); `retried` marks the one isolated
    retrieval retry a failing request is entitled to."""
    req_id: int
    query: str
    max_new: int
    state: str = "submitted"
    submitted_s: float = field(default_factory=time.perf_counter)
    expires_s: Optional[float] = None
    done_s: Optional[float] = None
    answer: Optional[object] = None       # RAGAnswer once condensed
    retried: bool = False

    @property
    def latency_s(self) -> Optional[float]:
        """submit -> done wall time (None while still in flight)."""
        return None if self.done_s is None else self.done_s - self.submitted_s


@dataclass
class RagEvent:
    """One request-visible state change. kind: "submitted" | "retrieved"
    (payload: doc id list) | "condensed" (payload: prompt token count) |
    "token" (payload: token id) | "done" (payload: completed RAGAnswer) |
    "shed" (payload: reason — deadline/overload/oversize; terminal) | "failed"
    (payload: repr of the stage error; terminal)."""
    req_id: int
    kind: str
    payload: object = None
    t: float = field(default_factory=time.perf_counter)


@dataclass
class SessionCounters:
    """Every shed/degrade/failure decision the session takes."""
    submitted: int = 0
    completed: int = 0
    shed_deadline: int = 0
    shed_overload: int = 0
    shed_oversize: int = 0
    shed_slo: int = 0
    degraded: int = 0
    degraded_slo: int = 0
    retrieval_retries: int = 0
    failed: int = 0


class RagSession:
    """Streaming session over one RAG pipeline + one ContinuousEngine."""

    def __init__(self, pipe, *, max_new: int = 16, slots: int = 4,
                 retrieve_chunk: int = 4, greedy: bool = True,
                 seed: int = 0, max_pending: Optional[int] = None,
                 deadline_s: Optional[float] = None,
                 trace: Optional[TraceSink] = None,
                 slo_s: Optional[float] = None):
        """`pipe`: a RAG pipeline with `_ensure_slm`/`answer_batch`.
        `greedy=False` samples every request from its own
        fold_in(PRNGKey(seed), engine-rid) stream (ContinuousEngine
        semantics: draws are independent of co-resident requests).
        `max_pending` bounds admission: past HALF the bound the session
        degrades (halved retrieve_chunk and max_new); at the bound new
        submissions are shed. `deadline_s` is the default per-request
        deadline. `trace` attaches a shared TraceSink to the session AND
        its engine (comp="session"/"engine"); `slo_s` is the default SLO
        budget per request — with a sink attached, each request is planned
        through `SLOController` (degrade before shed) against the tighter
        of its deadline and its SLO budget. Raises ValueError when the
        pipeline's generation arch has no slot-paged KV path
        (`model.supports_paged`)."""
        self.pipe = pipe
        self.max_new = max_new
        self.retrieve_chunk = retrieve_chunk
        self.greedy = greedy
        self.seed = seed
        self.max_pending = max_pending
        self.deadline_s = deadline_s
        self.counters = SessionCounters()
        if slo_s is not None and trace is None:
            trace = TraceSink()     # SLO control needs a live window
        self.trace = trace
        self.slo_s = slo_s
        self.trace_src = f"s{_SESSION_SEQ[0]}"
        _SESSION_SEQ[0] += 1
        self._slo = SLOController(trace) if trace is not None else None
        slm = pipe._ensure_slm()
        self.engine: ContinuousEngine = slm.continuous(slots)  # may raise
        if trace is not None:
            self.engine.trace = trace
        self._slm = slm
        self._n_probe0 = getattr(pipe, "n_probe", 4)
        self.requests: Dict[int, RagRequest] = {}
        self._queued: Deque[int] = deque()
        self._decoding: Dict[int, RagRequest] = {}   # engine rid -> request
        self._events_out: List[RagEvent] = []        # submit-time events
        self._next_id = 0
        if not self.engine.pending:
            # compile the chunk-prefill/decode executables off the measured
            # path so the first request's ttft reports execution, not jit
            self.engine.warmup()

    def _emit(self, name: str, rid: int = -1, **attrs) -> None:
        if self.trace is not None:
            self.trace.emit("session", name, rid, src=self.trace_src,
                            **attrs)

    # ------------------------------------------------------------- intake

    @property
    def overloaded(self) -> bool:
        """Past half the admission bound: the degradation ladder engages
        (smaller retrieval chunks, clamped max_new) BEFORE shedding."""
        return (self.max_pending is not None
                and self.pending >= max(1, self.max_pending // 2))

    def submit(self, query: str, max_new: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue one query; returns its request id. Retrieval/condense
        happens in a later `step()` (chunked, so it overlaps decode).
        At `max_pending` the request is shed immediately (terminal "shed"
        event on the next step); above half the bound it is admitted
        degraded (halved max_new)."""
        rid = self._next_id
        self._next_id += 1
        self.counters.submitted += 1
        max_new = max_new or self.max_new
        if deadline_s is None:
            deadline_s = self.deadline_s
        now = time.perf_counter()
        req = RagRequest(rid, query, max_new,
                         expires_s=(None if deadline_s is None
                                    else now + deadline_s))
        self.requests[rid] = req
        self._emit("queued", rid, max_new=req.max_new)
        if self.max_pending is not None and self.pending >= self.max_pending:
            req.state = "shed"
            self.counters.shed_overload += 1
            self._events_out.append(RagEvent(rid, "shed", "overload"))
            self._emit("shed", rid, reason="overload")
            return rid
        if self.overloaded:
            req.max_new = max(1, max_new // 2)
            self.counters.degraded += 1
        self._queued.append(rid)
        return rid

    @property
    def pending(self) -> int:
        """Requests not yet terminal (queued for retrieval or decoding)."""
        return len(self._queued) + len(self._decoding)

    # ----------------------------------------------------------- stepping

    def _shed(self, req: RagRequest, reason: str,
              events: List[RagEvent]) -> None:
        req.state = "shed"
        req.done_s = time.perf_counter()
        if reason == "slo":
            self.counters.shed_slo += 1
        else:
            self.counters.shed_deadline += 1
        events.append(RagEvent(req.req_id, "shed", reason))
        self._emit("shed", req.req_id, reason=reason)

    def _expire_step(self, events: List[RagEvent]) -> None:
        """Shed queued and decoding requests past their deadline; a
        decoding request's engine slot is freed via `cancel` so the next
        step can admit fresh work into it."""
        now = time.perf_counter()
        keep: Deque[int] = deque()
        for rid in self._queued:
            req = self.requests[rid]
            if req.expires_s is not None and now > req.expires_s:
                self._shed(req, "deadline", events)
            else:
                keep.append(rid)
        self._queued = keep
        for erid, req in list(self._decoding.items()):
            if req.expires_s is not None and now > req.expires_s:
                self.engine.cancel(erid)
                del self._decoding[erid]
                self._shed(req, "deadline", events)

    def _condense(self, reqs: List[RagRequest]) -> List[Optional[object]]:
        """One fused answer_batch over the chunk; on failure, each query
        is retried ONCE in isolation so a single poisoned query (embedder
        or index raising on it) cannot take the whole chunk down. Returns
        one answer per request, None where the retry failed too (the
        caller emits the terminal "failed" event)."""
        try:
            return self.pipe.answer_batch([r.query for r in reqs])
        except Exception:
            pass
        answers: List[Optional[object]] = []
        for r in reqs:
            try:
                r.retried = True
                self.counters.retrieval_retries += 1
                answers.append(self.pipe.answer_batch([r.query])[0])
            except Exception as e:
                answers.append(e)
        return answers

    def _budget_s(self, req: RagRequest, now: float) -> Optional[float]:
        """Seconds of budget left: the tighter of the request's deadline
        and its SLO target (None = unbounded)."""
        cands = []
        if req.expires_s is not None:
            cands.append(req.expires_s - now)
        if self.slo_s is not None:
            cands.append(req.submitted_s + self.slo_s - now)
        return min(cands) if cands else None

    def _set_n_probe(self, n: int) -> None:
        """Set the retrieval probe count on the real pipeline: chaos (and
        other) wrappers delegate reads via __getattr__ but a plain setattr
        would land on the wrapper, so walk the `.inner` chain down to the
        object that actually owns the attribute."""
        pipe = self.pipe
        while "n_probe" not in vars(pipe) and \
                getattr(pipe, "inner", None) is not None:
            pipe = pipe.inner
        pipe.n_probe = n

    def _plan_step(self, chunk: int, events: List[RagEvent]) -> tuple:
        """SLO-plan the head of the queue before retrieval: degrade
        (clamp max_new, shrink this chunk, fewer probes) before shedding.
        Returns (chunk, n_probe) for this retrieval round."""
        n_probe = self._n_probe0
        if self._slo is None:
            return chunk, n_probe
        now = time.perf_counter()
        keep: Deque[int] = deque()
        planned = 0
        while self._queued and planned < chunk:
            rid = self._queued.popleft()
            req = self.requests[rid]
            planned += 1
            plan = self._slo.plan(self._budget_s(req, now), req.max_new,
                                  chunk, n_probe)
            if plan.action == "shed":
                self._shed(req, "slo", events)
                continue
            if plan.action == "degrade":
                self.counters.degraded_slo += 1
                self._emit("degraded", rid, max_new=plan.max_new,
                           retrieve_chunk=plan.retrieve_chunk,
                           n_probe=plan.n_probe, est_s=plan.est_s)
                req.max_new = plan.max_new
                chunk = plan.retrieve_chunk
                n_probe = plan.n_probe
            keep.append(rid)
        keep.extend(self._queued)
        self._queued = keep
        return chunk, n_probe

    def _retrieve_step(self, events: List[RagEvent]) -> None:
        """Retrieve + condense the next chunk of queued queries (one fused
        answer_batch call) and admit their prompts to the engine. Under
        overload the chunk shrinks (degradation before shedding); a
        request whose retrieval fails twice emits "failed" and dies alone."""
        chunk = self.retrieve_chunk
        if self.overloaded:
            chunk = max(1, chunk // 2)
        chunk, n_probe = self._plan_step(chunk, events)
        take = [self._queued.popleft()
                for _ in range(min(chunk, len(self._queued)))]
        if not take:
            return
        reqs = [self.requests[r] for r in take]
        if n_probe != self._n_probe0:
            self._set_n_probe(n_probe)
        try:
            if self.trace is not None:
                with self.trace.span("session", "retrieve",
                                     src=self.trace_src, n=len(reqs),
                                     n_probe=n_probe):
                    answers = self._condense(reqs)
            else:
                answers = self._condense(reqs)
        finally:
            if n_probe != self._n_probe0:
                self._set_n_probe(self._n_probe0)
        for req, ans in zip(reqs, answers):
            if ans is None or isinstance(ans, Exception):
                req.state = "failed"
                req.done_s = time.perf_counter()
                self.counters.failed += 1
                events.append(RagEvent(req.req_id, "failed", repr(ans)))
                self._emit("failed", req.req_id, error=repr(ans))
                continue
            req.answer = ans
            req.state = "condensed"
            events.append(RagEvent(req.req_id, "retrieved",
                                   list(ans.doc_ids)))
            events.append(RagEvent(req.req_id, "condensed",
                                   ans.prompt_tokens))
            self._emit("retrieved", req.req_id, docs=len(ans.doc_ids))
            self._emit("condensed", req.req_id,
                       prompt_tokens=ans.prompt_tokens)
            prompt = self._slm.encode_prompt(ans.prompt, bucket=False)
            erid = self.engine.submit(prompt, req.max_new,
                                      greedy=self.greedy, seed=self.seed)
            self._decoding[erid] = req
            req.state = "decoding"

    def _engine_step(self, events: List[RagEvent]) -> None:
        """Advance the ContinuousEngine one step and translate its
        token/done events onto the session's requests."""
        tok = self._slm.tokenizer
        for ev in self.engine.step():
            req = self._decoding.get(ev.rid)
            if req is None:
                continue
            if ev.kind == "token":
                events.append(RagEvent(req.req_id, "token", ev.token))
            elif ev.kind == "shed":
                # engine refused the prompt (oversize: its pages can
                # never fit a slot's table width) — terminal, counted
                del self._decoding[ev.rid]
                req.state = "shed"
                req.done_s = time.perf_counter()
                self.counters.shed_oversize += 1
                events.append(RagEvent(req.req_id, "shed",
                                       ev.reason or "engine"))
                self._emit("shed", req.req_id,
                           reason=ev.reason or "engine")
            elif ev.kind == "done":
                del self._decoding[ev.rid]
                ans = req.answer
                ans.gen_tokens = list(ev.result.tokens)
                ans.generated = tok.decode(
                    [t for t in ev.result.tokens if t != tok.eos_id])
                ans.ttft_measured_s = ev.result.prefill_s
                req.state = "done"
                req.done_s = time.perf_counter()
                self.counters.completed += 1
                events.append(RagEvent(req.req_id, "done", ans))
                self._emit("done", req.req_id,
                           n_tokens=len(ev.result.tokens))

    def step(self) -> List[RagEvent]:
        """Advance the session: flush submit-time events, shed expired
        requests, one retrieval/condense chunk, one engine step. Returns
        the events produced (possibly empty when idle)."""
        events: List[RagEvent] = self._events_out
        self._events_out = []
        self._expire_step(events)
        self._retrieve_step(events)
        self._engine_step(events)
        return events

    # ----------------------------------------------------------- draining

    def stream(self, queries: Iterable[str] = ()) -> Iterator[RagEvent]:
        """Submit `queries`, then yield events until the session drains.
        More queries may be submitted concurrently from the consuming
        loop — the generator keeps stepping while anything is pending."""
        for q in queries:
            yield RagEvent(self.submit(q), "submitted")
        while self.pending or self._events_out:
            yield from self.step()

    def run(self, queries: Iterable[str]) -> List[object]:
        """Drain `queries` to completed RAGAnswers, in submit order (a
        shed or failed request's slot in the list is None)."""
        rids = [self.submit(q) for q in queries]
        while self.pending or self._events_out:
            self.step()
        return [self.requests[r].answer if self.requests[r].state == "done"
                else None for r in rids]
