"""EcoVector: build/search/update, RAM-disk tiering, device-scan parity."""
import os

import numpy as np
import pytest

from repro.core.ecovector import EcoVector


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(8, 24)) * 5
    X = np.concatenate([c + rng.normal(size=(150, 24))
                        for c in centers]).astype(np.float32)
    Q = X[:16] + 0.01 * rng.normal(size=(16, 24)).astype(np.float32)
    return X, Q


def gt(X, q, k=10):
    return set(np.argsort(np.sum((X - q) ** 2, 1))[:k])


@pytest.fixture(scope="module")
def index(data, tmp_path_factory):
    X, _ = data
    d = tmp_path_factory.mktemp("eco")
    return EcoVector(24, n_clusters=16, M=8, ef_construction=40,
                     storage_dir=str(d)).build(X)


def test_recall(index, data):
    X, Q = data
    rec = [len(set(map(int, index.search(q, 10, n_probe=4)[0]))
               & gt(X, q)) / 10 for q in Q]
    assert np.mean(rec) > 0.85


def test_cluster_graphs_live_on_disk(index):
    files = [f for f in os.listdir(index.storage_dir)
             if f.startswith("cluster_")]
    assert len(files) == index.n_clusters
    assert index.disk_bytes() > 0
    # RAM accounting excludes the spilled lists (bar one loaded list)
    assert index.ram_bytes() < index.disk_bytes() + index.ram_bytes()


def test_partial_loading_counts(index, data):
    _, Q = data
    index.stats.disk_loads = 0
    index.search(Q[0], 10, n_probe=3)
    assert index.stats.disk_loads == 3  # exactly n_probe lists touched


def test_device_scan_matches_host(index, data):
    X, Q = data
    ids_h = [set(map(int, index.search(q, 10, n_probe=4, ef_search=64)[0]))
             for q in Q]
    ids_d, _ = index.search_device(Q, k=10, n_probe=4)
    # dense device scan is exhaustive within probed clusters, so it is a
    # superset-quality result: compare against brute force instead
    rec = [len(set(map(int, ids_d[i])) & gt(X, Q[i])) / 10
           for i in range(len(Q))]
    assert np.mean(rec) > 0.9


def test_insert_then_found(index, data):
    X, _ = data
    v = X[3] + 0.002
    index.insert(99_999, v)
    ids, _ = index.search(v, 5, n_probe=2)
    assert 99_999 in set(map(int, ids))


def test_delete_then_gone(index, data):
    X, _ = data
    index.insert(88_888, X[7] + 0.001)
    index.delete(88_888)
    ids, _ = index.search(X[7], 10, n_probe=4)
    assert 88_888 not in set(map(int, ids))


def test_update_only_touches_one_cluster(index, data):
    X, _ = data
    before = index.stats.disk_loads
    index.insert(77_777, X[11] + 0.001)
    # one load for the owning cluster (centroid graph is in RAM)
    assert index.stats.disk_loads == before + 1
    index.delete(77_777)


# ------------------------------------------------ fresh-index device tests


def small_index(tmp_path, n_clusters=8, cache_clusters=0):
    rng = np.random.default_rng(1)
    centers = rng.normal(size=(6, 16)) * 6
    X = np.concatenate([c + rng.normal(size=(60, 16))
                        for c in centers]).astype(np.float32)
    idx = EcoVector(16, n_clusters=n_clusters, M=8, ef_construction=40,
                    storage_dir=str(tmp_path),
                    cache_clusters=cache_clusters).build(X)
    return idx, X


def test_search_device_batched_parity_with_host(index, data):
    """Batched fused device search matches host search recall@10 within
    0.02 (it is exhaustive within probed clusters, so typically better)."""
    X, Q = data
    rec_h = np.mean([len(set(map(int, index.search(q, 10, n_probe=4,
                                                   ef_search=64)[0]))
                         & gt(X, q)) / 10 for q in Q])
    ids_d, _ = index.search_device_batched(Q, k=10, n_probe=4)
    rec_d = np.mean([len(set(map(int, ids_d[i])) & gt(X, Q[i])) / 10
                     for i in range(len(Q))])
    assert rec_d >= rec_h - 0.02


def test_incremental_repack_only_touches_owner(tmp_path):
    """insert() + device query must rewrite only the owning cluster's
    block — no full [NC, CAP, d] rebuild, no O(N) disk re-read."""
    idx, X = small_index(tmp_path)
    idx.search_device_batched(X[:2], k=5, n_probe=4)   # builds the pack
    assert idx.stats.pack_full_builds == 1
    loads0 = idx.stats.disk_loads
    repacks0 = idx.stats.pack_cluster_repacks
    idx.insert(50_000, X[0] + 0.01)
    ids, _ = idx.search_device_batched(X[0] + 0.01, k=5, n_probe=4)
    assert 50_000 in set(map(int, ids[0]))
    assert idx.stats.pack_full_builds == 1             # still the first one
    assert idx.stats.pack_cluster_repacks == repacks0 + 1
    # insert pays the only load; the repack reuses the in-hand graph
    assert idx.stats.disk_loads - loads0 == 1
    idx.delete(50_000)
    ids, _ = idx.search_device_batched(X[0] + 0.01, k=5, n_probe=4)
    assert 50_000 not in set(map(int, ids[0]))
    assert idx.stats.pack_full_builds == 1
    assert idx.stats.pack_cluster_repacks == repacks0 + 2


def test_pack_grows_on_overflow(tmp_path):
    """Flooding one cluster past CAP grows the pack geometrically instead
    of truncating; everything stays searchable."""
    idx, X = small_index(tmp_path)
    idx.device_pack()
    cap0 = idx._device_pack[3]
    target = X[5] + 0.5
    rng = np.random.default_rng(2)
    for j in range(cap0 + 10):
        idx.insert(60_000 + j, target + 0.3 * rng.normal(size=16))
    probe_v = target
    ids, _ = idx.search_device_batched(probe_v, k=10,
                                       n_probe=idx.n_clusters)
    assert idx.stats.pack_grows >= 1
    assert idx._device_pack[3] > cap0
    assert idx.stats.truncated_vectors == 0
    assert any(int(i) >= 60_000 for i in ids[0])


def test_device_pack_forced_cap_warns_and_counts(tmp_path):
    idx, X = small_index(tmp_path)
    with pytest.warns(UserWarning, match="truncates"):
        idx.device_pack(cap=4)
    assert idx.stats.truncated_vectors > 0


def test_forced_cap_is_stable_budget_and_liftable(tmp_path):
    """A forced cap is a hard per-cluster budget: incremental repacks keep
    honoring it (loudly, never oscillating back to auto cap), and
    force_full=True without cap lifts it and restores every vector."""
    idx, X = small_index(tmp_path)
    with pytest.warns(UserWarning, match="truncates"):
        idx.device_pack(cap=4)
    idx.insert(70_000, X[0] + 0.01)
    with pytest.warns(UserWarning, match="truncates"):
        data, lens, slot_ids, cap = idx.device_pack(cap=4)
    assert cap == 4                             # budget kept, no oscillation
    assert idx.stats.pack_full_builds == 1      # in-place repack, not rebuild
    assert (lens <= 4).all()
    # escape hatch: auto-cap full rebuild restores everything
    data, lens, slot_ids, cap = idx.device_pack(force_full=True)
    assert int(lens.sum()) == len(idx.assign)
    ids, _ = idx.search_device_batched(X[10], k=10, n_probe=idx.n_clusters)
    assert 10 in set(map(int, ids[0]))


def test_cluster_cache_is_lru(tmp_path):
    """Cache hits promote (move-to-end); eviction drops the LRU entry."""
    idx, _ = small_index(tmp_path, cache_clusters=2)
    idx.stats.disk_loads = 0
    idx._load_cluster(0)
    idx._load_cluster(1)
    idx._load_cluster(0)      # promote 0 over 1
    idx._load_cluster(2)      # evicts 1 (LRU), keeps 0
    n = idx.stats.disk_loads
    idx._load_cluster(0)
    assert idx.stats.disk_loads == n        # hit: 0 survived eviction
    idx._load_cluster(1)
    assert idx.stats.disk_loads == n + 1    # miss: 1 was the LRU victim


def test_forced_cap_registers_without_rebuild(tmp_path):
    """device_pack(cap=X) where X happens to equal the current auto cap
    must still register X as a hard budget (no silent growth past it)."""
    idx, X = small_index(tmp_path)
    _, _, _, auto_cap = idx.device_pack()
    idx.device_pack(cap=auto_cap)          # same size, now an explicit budget
    grows0 = idx.stats.pack_grows
    rng = np.random.default_rng(5)
    target = X[0]
    with pytest.warns(UserWarning, match="truncates"):
        for j in range(auto_cap + 5):
            idx.insert(80_000 + j, target + 0.2 * rng.normal(size=16))
        _, _, _, cap = idx.device_pack()
    assert cap == auto_cap                 # budget held
    assert idx.stats.pack_grows == grows0  # never grew past it


def test_truncated_vectors_tracks_current_state(tmp_path):
    """stats.truncated_vectors reflects rows currently missing from the
    pack — repeated repacks of the same over-budget cluster must not
    inflate it."""
    idx, X = small_index(tmp_path)
    with pytest.warns(UserWarning, match="truncates"):
        idx.device_pack(cap=4)
    t0 = idx.stats.truncated_vectors
    assert t0 == len(idx.assign) - 4 * idx.n_clusters
    with pytest.warns(UserWarning, match="truncates"):
        for j in range(3):
            idx.insert(90_000 + j, X[0] + 0.01 * j)
            idx.device_pack()
    # 3 net new vectors dropped (same cluster repacked thrice)
    assert idx.stats.truncated_vectors == t0 + 3
    idx.device_pack(force_full=True)
    assert idx.stats.truncated_vectors == 0


def test_search_stats_count_per_query_delta(tmp_path):
    """distance_ops must count per-query work, not the pickled graphs'
    lifetime counters (which include construction-time distances)."""
    idx, X = small_index(tmp_path)
    construction = sum(idx._load_cluster(c).n_dist
                       for c in range(idx.n_clusters))
    idx.stats.distance_ops = 0
    idx.search(X[0], 10, n_probe=idx.n_clusters)
    assert 0 < idx.stats.distance_ops < construction
