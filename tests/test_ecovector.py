"""EcoVector: build/search/update, RAM-disk tiering, device-scan parity."""
import os

import numpy as np
import pytest

from repro.core.ecovector import EcoVector


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(8, 24)) * 5
    X = np.concatenate([c + rng.normal(size=(150, 24))
                        for c in centers]).astype(np.float32)
    Q = X[:16] + 0.01 * rng.normal(size=(16, 24)).astype(np.float32)
    return X, Q


def gt(X, q, k=10):
    return set(np.argsort(np.sum((X - q) ** 2, 1))[:k])


@pytest.fixture(scope="module")
def index(data, tmp_path_factory):
    X, _ = data
    d = tmp_path_factory.mktemp("eco")
    return EcoVector(24, n_clusters=16, M=8, ef_construction=40,
                     storage_dir=str(d)).build(X)


def test_recall(index, data):
    X, Q = data
    rec = [len(set(map(int, index.search(q, 10, n_probe=4)[0]))
               & gt(X, q)) / 10 for q in Q]
    assert np.mean(rec) > 0.85


def test_cluster_graphs_live_on_disk(index):
    files = [f for f in os.listdir(index.storage_dir)
             if f.startswith("cluster_")]
    assert len(files) == index.n_clusters
    assert index.disk_bytes() > 0
    # RAM accounting excludes the spilled lists (bar one loaded list)
    assert index.ram_bytes() < index.disk_bytes() + index.ram_bytes()


def test_partial_loading_counts(index, data):
    _, Q = data
    index.stats.disk_loads = 0
    index.search(Q[0], 10, n_probe=3)
    assert index.stats.disk_loads == 3  # exactly n_probe lists touched


def test_device_scan_matches_host(index, data):
    X, Q = data
    ids_h = [set(map(int, index.search(q, 10, n_probe=4, ef_search=64)[0]))
             for q in Q]
    ids_d, _ = index.search_device(Q, k=10, n_probe=4)
    # dense device scan is exhaustive within probed clusters, so it is a
    # superset-quality result: compare against brute force instead
    rec = [len(set(map(int, ids_d[i])) & gt(X, Q[i])) / 10
           for i in range(len(Q))]
    assert np.mean(rec) > 0.9


def test_insert_then_found(index, data):
    X, _ = data
    v = X[3] + 0.002
    index.insert(99_999, v)
    ids, _ = index.search(v, 5, n_probe=2)
    assert 99_999 in set(map(int, ids))


def test_delete_then_gone(index, data):
    X, _ = data
    index.insert(88_888, X[7] + 0.001)
    index.delete(88_888)
    ids, _ = index.search(X[7], 10, n_probe=4)
    assert 88_888 not in set(map(int, ids))


def test_update_only_touches_one_cluster(index, data):
    X, _ = data
    before = index.stats.disk_loads
    index.insert(77_777, X[11] + 0.001)
    # one load for the owning cluster (centroid graph is in RAM)
    assert index.stats.disk_loads == before + 1
    index.delete(77_777)
