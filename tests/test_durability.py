"""End-to-end durability: index save/load bit-identity under churn,
WAL replay, crash sweeps (in-process and kill -9 subprocess), byte-flip
quarantine with degraded search, and pipeline-level snapshot restore."""
import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

from repro.core import store, store_faults
from repro.core.baselines import IVFDisk
from repro.core.ecovector import EcoVector
from repro.core.hnsw import HNSW
from repro.core.scr import SCRConfig
from repro.core.window_index import WindowIndex
from repro.serving.embedder import HashEmbedder

DIM = 16


@pytest.fixture(autouse=True)
def _clean_hooks():
    store.set_crash_hook(None)
    store.reset_fs_ops()
    yield
    store.set_crash_hook(None)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    centers = rng.normal(scale=4.0, size=(8, DIM))
    X = (centers.repeat(40, axis=0)
         + rng.normal(size=(320, DIM))).astype(np.float32)
    Q = X[rng.choice(len(X), 16)] + 0.05 * rng.normal(
        size=(16, DIM)).astype(np.float32)
    return X, Q.astype(np.float32)


def _ev(X, **kw):
    kw.setdefault("n_clusters", 8)
    kw.setdefault("M", 8)
    kw.setdefault("ef_construction", 32)
    return EcoVector(DIM, **kw).build(X)


def _same_search(a, b, Q, k=10, n_probe=8):
    for q in Q:
        ia, da = a.search(q, k, n_probe=n_probe)
        ib, db = b.search(q, k, n_probe=n_probe)
        np.testing.assert_array_equal(ia, ib)
        np.testing.assert_array_equal(da, db)


# --------------------------------------------------- save/load roundtrip

def test_ecovector_save_load_bit_identical(tmp_path, data):
    X, Q = data
    ev = _ev(X)
    g = ev.save(str(tmp_path / "j"))
    assert g == 0
    ev2 = EcoVector.load(str(tmp_path / "j"))
    assert ev2.assign == ev.assign
    _same_search(ev, ev2, Q)
    # the fused device path agrees too (interpret-mode kernel off-TPU)
    ia, _ = ev.search_device_batched(Q[:4], k=5, n_probe=8,
                                     use_pallas=False)
    ib, _ = ev2.search_device_batched(Q[:4], k=5, n_probe=8,
                                      use_pallas=False)
    np.testing.assert_array_equal(ia, ib)


def test_ecovector_churn_cycles(tmp_path, data):
    """build -> save -> load stays bit-identical across repeated
    insert/update/remove cycles with a compaction each round."""
    X, Q = data
    rng = np.random.default_rng(3)
    ev = _ev(X)
    root = str(tmp_path / "j")
    ev.save(root)
    base = 10 ** 6
    for cycle in range(3):
        for i in range(6):
            vid = base + 6 * cycle + i
            ev.insert(vid, rng.normal(size=DIM).astype(np.float32))
        ev.delete(base + 6 * cycle)                      # remove
        upd = base + 6 * cycle + 1                       # update = del+ins
        ev.delete(upd)
        ev.insert(upd, rng.normal(size=DIM).astype(np.float32))
        g = ev.save()                                    # compact
        assert g == cycle + 1
        ev2 = EcoVector.load(root)
        assert ev2.assign == ev.assign
        assert ev2.stats.wal_replayed == 0               # all folded
        _same_search(ev, ev2, Q)


def test_ecovector_wal_replay(tmp_path, data):
    X, Q = data
    rng = np.random.default_rng(4)
    ev = _ev(X)
    root = str(tmp_path / "j")
    ev.save(root)
    for i in range(5):
        ev.insert(10 ** 6 + i, rng.normal(size=DIM).astype(np.float32))
    ev.delete(10 ** 6 + 2)
    ev2 = EcoVector.load(root)                           # no second save
    assert ev2.stats.wal_replayed == 6
    assert ev2.assign == ev.assign
    _same_search(ev, ev2, Q)


# ------------------------------------------------------- crash injection

def test_ecovector_save_crash_sweep(tmp_path, data):
    """kill at EVERY fs op during save(): the journal always reloads to
    a complete index (previous generation) or reports none committed."""
    X, Q = data
    ev = _ev(X)
    total = store_faults.count_fs_ops(
        lambda: ev.save(str(tmp_path / "probe")))
    assert total >= 5
    for at in range(1, total + 1):
        root = str(tmp_path / f"r{at}")
        ev._journal = None                   # fresh journal per sweep
        with store_faults.CrashPlan(at) as plan:
            try:
                ev.save(root)
            except store_faults.InjectedCrash:
                pass
        try:
            ev2 = EcoVector.load(root)
        except FileNotFoundError:
            assert plan.fired                # nothing committed yet
            continue
        assert ev2.assign == ev.assign
        ids, _ = ev2.search(Q[0], 5, n_probe=8)
        assert len(ids) == 5
    ev._journal = None


def test_wal_crash_never_loses_acknowledged_ops(tmp_path, data):
    """Crash at every fs op inside a journaled mutation burst: every op
    that RETURNED before the crash is present after reload."""
    X, Q = data
    rng = np.random.default_rng(5)
    base_root = str(tmp_path / "base")
    ev0 = _ev(X)
    ev0.save(base_root)
    vecs = rng.normal(size=(6, DIM)).astype(np.float32)

    ops = [("delete", 10 ** 6 + 1) if i == 4 else ("insert", 10 ** 6 + i)
           for i in range(len(vecs))]

    def burst(ev, acked):
        for i, (op, vid) in enumerate(ops):
            if op == "delete":
                ev.delete(vid)
            else:
                ev.insert(vid, vecs[i])
            acked.append((op, vid))

    total = store_faults.count_fs_ops(lambda: burst(ev0, []))
    for at in range(1, total + 1, 2):
        root = str(tmp_path / f"r{at}")
        shutil.copytree(base_root, root)
        ev = EcoVector.load(root)
        acked = []
        with store_faults.CrashPlan(at):
            try:
                burst(ev, acked)
            except store_faults.InjectedCrash:
                pass
        ev2 = EcoVector.load(root)
        # expected membership from ACKED ops; the single in-flight op
        # (crash mid-append) was never acknowledged — it may or may not
        # have reached the WAL, so its vid is exempt either way
        expect = {}
        for op, vid in acked:
            expect[vid] = (op == "insert")
        inflight = ops[len(acked)][1] if len(acked) < len(ops) else None
        for vid, present in expect.items():
            if vid == inflight:
                continue
            assert (vid in ev2.assign) == present, (at, vid, present)


def _run_driver(root, stage, crash_at=None, timeout=300):
    env = dict(os.environ, PYTHONPATH="src")
    if crash_at is not None:
        env["REPRO_STORE_CRASH_AT"] = str(crash_at)
    cmd = [sys.executable, "-m", "repro.core.store_faults",
           "--root", str(root), "--stage", stage]
    return subprocess.run(cmd, env=env, cwd=".", capture_output=True,
                          text=True, timeout=timeout)


def _driver_ops(wal_ops=12, base=10 ** 6):
    """The driver's deterministic mutation sequence (mirror of
    store_faults._driver_workload)."""
    return [("delete", base + i - 1) if i % 3 == 2 else
            ("insert", base + i) for i in range(wal_ops)]


def _check_acked_survive(root):
    """Replay the parent-visible ack log against the reloaded index:
    ground truth for 'zero acknowledged writes lost'. The one op in
    flight at the kill (durable in the WAL but never acknowledged) is
    exempt — surviving unacked ops are allowed, losing acked ones is
    not."""
    ack_path = os.path.join(root, "acked.txt")
    acked = []
    compacted = False
    if os.path.exists(ack_path):
        with open(ack_path) as f:
            for line in f.read().splitlines():
                parts = line.split()
                if parts[0] in ("insert", "delete"):
                    acked.append((parts[0], int(parts[1])))
                elif parts[0] == "compacted":
                    compacted = True
    ops = _driver_ops()
    assert acked == ops[:len(acked)]
    inflight = ops[len(acked)][1] if len(acked) < len(ops) else None
    live = {}
    for op, vid in acked:
        live[vid] = (op == "insert")
    try:
        ev = EcoVector.load(os.path.join(root, "journal"))
    except FileNotFoundError:
        # killed before the first generation committed: legal only if
        # nothing was ever acknowledged
        assert not acked and not compacted
        return
    for vid, present in live.items():
        if vid == inflight:
            continue
        assert (vid in ev.assign) == present, (vid, present)
    if compacted:
        assert store.Journal(os.path.join(root, "journal")).latest() >= 1
    ids, _ = ev.search(np.zeros(DIM, np.float32), 5, n_probe=8)
    assert len(ids) == 5


@pytest.mark.slow
@pytest.mark.parametrize("stage,crash_at", [
    # driver fs-op phases: 1-27 build spills + state, 28-32 first
    # generation commit, 33-92 WAL'd mutations (5 ops each), 93-100
    # compaction commit
    ("wal", 10), ("wal", 28), ("wal", 34), ("wal", 52), ("wal", 91),
    ("compact", 94), ("compact", 97), ("compact", 99),
])
def test_kill9_subprocess_recovery(tmp_path, stage, crash_at):
    """Real os._exit at the crash_at-th fs op of the driver workload
    (mid-save, mid-WAL-append, or mid-compaction): the parent reloads
    the journal and finds every acknowledged mutation."""
    p = _run_driver(tmp_path, stage, crash_at=crash_at)
    assert p.returncode in (42, 0), p.stdout + p.stderr
    _check_acked_survive(str(tmp_path))


@pytest.mark.slow
def test_kill9_uninjected_run_completes(tmp_path):
    p = _run_driver(tmp_path, "compact")
    assert p.returncode == 0, p.stdout + p.stderr
    _check_acked_survive(str(tmp_path))
    assert store.Journal(str(tmp_path / "journal")).latest() == 1


# --------------------------------------------------- corruption at query

def test_byte_flip_quarantine_search_degrades(data):
    """A bit-flipped cluster file is detected on first touch, the
    cluster quarantined, and every query still returns k results."""
    X, Q = data
    ev = _ev(X)
    ev.device_pack()                          # salvage source
    victim = 2
    store_faults.flip_byte(ev._path(victim), 100)
    with pytest.warns(UserWarning, match="quarantin"):
        for q in Q:
            ids, _ = ev.search(q, 10, n_probe=8)
            assert len(ids) == 10
    assert ev.stats.corrupt_reads == 1        # detected exactly once
    assert ev.stats.quarantined == 1
    assert victim in ev._quarantined
    assert os.path.exists(ev._path(victim) + ".quarantined")
    # host and device agree on the degraded state
    ia, _ = ev.search_device_batched(Q[:4], k=5, n_probe=8,
                                     use_pallas=False)
    for r, q in zip(ia, Q[:4]):
        ib, _ = ev.search(q, 5, n_probe=8)
        np.testing.assert_array_equal(np.asarray(r), ib)
    # rebuild from the salvaged pack block restores the cluster
    n = ev.rebuild_cluster(victim)
    assert n > 0
    assert ev.stats.rebuilt == 1 and ev.stats.quarantined == 0
    assert not os.path.exists(ev._path(victim) + ".quarantined")
    ids, _ = ev.search(Q[0], 10, n_probe=8)
    assert len(ids) == 10


def test_truncated_spill_file_is_clear_error(data):
    """Satellite: _load_cluster on a truncated spill file raises the
    dedicated corruption error, never a pickle internals blowup."""
    X, _ = data
    ev = _ev(X)
    p = ev._path(0)
    store_faults.truncate_file(p, os.path.getsize(p) // 2)
    with pytest.raises(store.CorruptSegmentError, match="truncated"):
        ev._load_cluster(0)


def test_mutations_on_quarantined_cluster(data):
    """insert routed to a quarantined cluster triggers rebuild-from-
    salvage; delete of a vanished id is a no-op, not a crash."""
    X, _ = data
    ev = _ev(X)
    ev.device_pack()
    victim = int(ev.assign[0])
    store_faults.flip_byte(ev._path(victim), 120)
    with pytest.warns(UserWarning):
        assert ev._load_cluster_checked(victim) is None
    members = [vid for vid, c in list(ev.assign.items())]
    assert 0 not in ev.assign                 # pruned with its cluster
    ev.delete(0)                              # tolerated
    ev.insert(0, X[0])                        # routes back -> rebuild
    assert 0 in ev.assign
    assert ev.stats.rebuilt == 1 and ev.stats.quarantined == 0
    ids, _ = ev.search(X[0], 5, n_probe=8)
    assert 0 in ids


def test_save_refuses_to_launder_corruption(tmp_path, data):
    """A cluster that rots BEFORE save is quarantined during the
    verify-on-copy pass — the committed generation only contains files
    that check out, and it loads cleanly."""
    X, Q = data
    ev = _ev(X)
    ev.device_pack()
    store_faults.flip_byte(ev._path(3), 90)
    with pytest.warns(UserWarning):
        ev.save(str(tmp_path / "j"))
    reps = store.scrub_path(str(tmp_path / "j"))
    assert all(r["ok"] for r in reps)
    ev2 = EcoVector.load(str(tmp_path / "j"))
    assert 3 in ev2._quarantined
    for q in Q:
        assert len(ev2.search(q, 10, n_probe=8)[0]) == 10


# ------------------------------------------------- other index families

def test_hnsw_save_load(tmp_path, data):
    X, Q = data
    g = HNSW(DIM, M=8, ef_construction=40, seed=0)
    for i, v in enumerate(X[:120]):
        g.insert(int(i), v)
    p = str(tmp_path / "g.bin")
    g.save(p)
    g2 = HNSW.load(p)
    for q in Q:
        np.testing.assert_array_equal(g.search(q, 10, ef_search=64)[0],
                                      g2.search(q, 10, ef_search=64)[0])
    store_faults.flip_byte(p, 64)
    with pytest.raises(store.CorruptSegmentError):
        HNSW.load(p)


def test_ivfdisk_store_is_atomic_and_validated(data):
    X, Q = data
    idx = IVFDisk(DIM, n_clusters=8).build(X)
    before = idx.search(Q[0], 10, n_probe=8)[0]
    # crash mid-overwrite of a list: the previous list survives intact
    c = 0
    payload = idx._load_list(c)
    total = store_faults.count_fs_ops(lambda: idx._store_list(c, payload))
    with store_faults.CrashPlan(1):
        try:
            idx._store_list(c, (payload[0][:1], payload[1][:1]))
        except store_faults.InjectedCrash:
            pass
    assert total >= 3
    np.testing.assert_array_equal(idx._load_list(c)[0], payload[0])
    np.testing.assert_array_equal(idx.search(Q[0], 10, n_probe=8)[0],
                                  before)
    # bit-rot is detected, not unpickled
    store_faults.flip_byte(idx._lpath(c), 80)
    with pytest.raises(store.CorruptSegmentError):
        idx._load_list(c)


# ------------------------------------------------------- window index

class _CountingEmbed:
    def __init__(self, inner):
        self.inner = inner
        self.calls = 0
        self.texts = 0

    def __call__(self, texts):
        self.calls += 1
        self.texts += len(texts)
        return self.inner(texts)


DOCS = [
    ("Volcanoes are studied by geologists. "
     "Their eruptions follow magma pressure. "
     "Monitoring stations track seismic activity."),
    ("The Tiramisu dessert originated in Italy. "
     "Recipe of the Tiramisu includes cheese and coffee. "
     "Many cafes now offer Tiramisu for pick-up."),
    "One single sentence about astronomy.",
    "",
    ("Quantum computers use qubits. "
     "Error correction is the central challenge."),
]


@pytest.fixture(scope="module")
def embed():
    return HashEmbedder(dim=64).fit([d for d in DOCS if d])


def test_window_index_save_load_no_reembed(tmp_path, embed):
    wi = WindowIndex(embed, SCRConfig(3, 2, 1)).build(DOCS)
    data0, lens0 = wi.pack()
    root = str(tmp_path / "w")
    wi.save(root)
    counter = _CountingEmbed(embed)
    wi2 = WindowIndex.load(counter, root)
    assert counter.calls == 0                 # restore embeds nothing
    data2, lens2 = wi2.pack()
    assert counter.calls == 0                 # pack is clean too
    np.testing.assert_array_equal(data0, data2)
    np.testing.assert_array_equal(lens0, lens2)
    assert wi2.texts == wi.texts
    assert wi2.spans == wi.spans


def test_window_index_wal_and_compaction(tmp_path, embed):
    wi = WindowIndex(embed, SCRConfig(3, 2, 1)).build(DOCS)
    root = str(tmp_path / "w")
    wi.save(root)
    di = wi.add("Fresh document about deep sea vents. They host life.")
    wi.update(2, "Astronomy text, now revised with telescopes.")
    wi.remove(4)
    wi2 = WindowIndex.load(embed, root)       # replays the three ops
    assert wi2.stats.wal_replayed == 3
    assert wi2.texts == wi.texts
    d1, l1 = wi.pack()
    d2, l2 = wi2.pack()
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_allclose(d1, d2, rtol=0, atol=0)
    g = wi.save()                             # compact
    assert g == 1
    wi3 = WindowIndex.load(embed, root)
    assert wi3.stats.wal_replayed == 0
    assert wi3.texts == wi.texts
    assert int(wi3.pack()[1][4]) == 0         # removed doc stays empty
    assert di == len(DOCS)


# --------------------------------------------------- pipeline snapshot

def test_mobilerag_retrieval_state_roundtrip(tmp_path):
    from repro.data.synthetic import make_qa_corpus
    from repro.serving.rag import MobileRAG
    corpus = make_qa_corpus("squad", n_docs=40, n_questions=4, seed=0)
    emb = HashEmbedder(dim=64).fit(corpus.docs)
    state = str(tmp_path / "state")
    c1 = _CountingEmbed(emb)
    pipe = MobileRAG(corpus.docs, c1, top_k=3, retrieval_state=state)
    build_texts = c1.texts
    assert build_texts > 0
    c2 = _CountingEmbed(emb)
    warm = MobileRAG(corpus.docs, c2, top_k=3, retrieval_state=state)
    assert c2.texts == 0                      # construction embeds nothing
    assert warm.doc_vecs is None
    qs = [e.question for e in corpus.examples[:4]]
    for q in qs:
        a, b = pipe.answer(q), warm.answer(q)
        assert a.doc_ids == b.doc_ids
        assert a.prompt == b.prompt
    # per-query work on the warm pipeline is query embeds only
    assert c2.texts == len(qs)


def test_mobilerag_corrupt_state_rebuilds(tmp_path):
    from repro.data.synthetic import make_qa_corpus
    from repro.serving.rag import MobileRAG
    corpus = make_qa_corpus("squad", n_docs=30, n_questions=2, seed=1)
    emb = HashEmbedder(dim=64).fit(corpus.docs)
    state = str(tmp_path / "state")
    MobileRAG(corpus.docs, emb, top_k=3, retrieval_state=state)
    # rot the committed EcoVector state file
    j = store.Journal(os.path.join(state, "ecovector"))
    g = j.latest()
    store_faults.flip_byte(
        os.path.join(j.gen_dir(g), "state.seg"), 200)
    with pytest.warns(UserWarning, match="rebuilding"):
        pipe = MobileRAG(corpus.docs, emb, top_k=3, retrieval_state=state)
    a = pipe.answer(corpus.examples[0].question)
    assert len(a.doc_ids) > 0
    # the rebuild committed a fresh generation: a third construction
    # restores cleanly (no rebuild warning, no corpus embed)
    import warnings as _w
    c3 = _CountingEmbed(emb)
    with _w.catch_warnings():
        _w.filterwarnings("error", message=".*rebuilding.*")
        warm = MobileRAG(corpus.docs, c3, top_k=3, retrieval_state=state)
    assert c3.texts == 0 and warm.doc_vecs is None
