import os

# Tests must see the single real CPU device (the 512-device override is
# *only* for the dry-run, set inside repro.launch.dryrun).
os.environ.pop("XLA_FLAGS", None)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
