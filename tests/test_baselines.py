"""All seven baseline indexes: recall, memory ordering, updates, stats."""
import numpy as np
import pytest

from repro.core.baselines import ALL_BASELINES, make_index


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(6, 24)) * 5
    X = np.concatenate([c + rng.normal(size=(120, 24))
                        for c in centers]).astype(np.float32)
    Q = X[:10] + 0.01 * rng.normal(size=(10, 24)).astype(np.float32)
    return X, Q


def gt(X, q, k=10):
    return set(np.argsort(np.sum((X - q) ** 2, 1))[:k])


KW = {"IVF": {"n_clusters": 12}, "IVFPQ": {"n_clusters": 12, "m_pq": 4},
      "HNSW": {}, "HNSWPQ": {"m_pq": 4}, "IVF-DISK": {"n_clusters": 12},
      "IVFPQ-DISK": {"n_clusters": 12, "m_pq": 4},
      "IVF-HNSW": {"n_clusters": 12}, "EcoVector": {"n_clusters": 12}}


@pytest.mark.parametrize("name", ALL_BASELINES)
def test_recall_reasonable(name, data):
    X, Q = data
    idx = make_index(name, 24, **KW[name]).build(X)
    rec = [len(set(map(int, idx.search(q, k=10, n_probe=6)[0])) & gt(X, q))
           / 10 for q in Q]
    floor = 0.4 if "PQ" in name else 0.8  # quantised variants trade recall
    assert np.mean(rec) >= floor, (name, np.mean(rec))


@pytest.mark.parametrize("name", ["IVF", "IVF-DISK", "EcoVector"])
def test_insert_delete(name, data):
    X, _ = data
    idx = make_index(name, 24, **KW[name]).build(X)
    idx.insert(50_000, X[0] + 0.001)
    ids, _ = idx.search(X[0], k=5, n_probe=6)
    assert 50_000 in set(map(int, ids))
    idx.delete(50_000)
    ids, _ = idx.search(X[0], k=10, n_probe=6)
    assert 50_000 not in set(map(int, ids))


def test_memory_ordering_matches_paper(data):
    """Fig. 6: disk-based variants' RAM << in-RAM variants; EcoVector close
    to IVF-DISK."""
    X, _ = data
    ram = {}
    for name in ALL_BASELINES:
        idx = make_index(name, 24, **KW[name]).build(X)
        ram[name] = idx.ram_bytes()
    assert ram["IVF-DISK"] < ram["IVF"]
    assert ram["EcoVector"] < ram["HNSW"]
    # At this toy scale (720 pts) per-cluster pickle overhead can rival the
    # raw vectors, so allow slack; the strict EcoVector < IVF ordering is
    # asymptotic (test_property.test_analytical_memory_ordering + Fig 6
    # bench at 1M-scale model numbers).
    assert ram["EcoVector"] < 1.5 * ram["IVF"]


def test_disk_variants_report_disk_traffic(data):
    X, Q = data
    for name in ["IVF-DISK", "IVFPQ-DISK", "IVF-HNSW"]:
        idx = make_index(name, 24, **KW[name]).build(X)
        idx.stats.reset()
        idx.search(Q[0], k=5, n_probe=3)
        assert idx.stats.disk_loads == 3
        assert idx.stats.disk_bytes > 0
