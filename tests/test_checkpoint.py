"""Checkpointing + fault tolerance: atomic writes, bitwise resume,
kill -9 recovery via the real training driver."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import checkpoint as ckpt
from repro.dist.fault import RestartManager, StepWatchdog


def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 3, t)
    like = jax.tree.map(jnp.zeros_like, t)
    r = ckpt.restore(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_ignores_partial_writes(tmp_path):
    ckpt.save(str(tmp_path), 1, tree())
    # simulate a crashed write: tmp dir without manifest rename
    os.makedirs(tmp_path / "step_00000009.tmp" / "arrays")
    os.makedirs(tmp_path / "step_00000005")  # no manifest
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_save(tmp_path):
    t = tree()
    th = ckpt.save(str(tmp_path), 2, t, blocking=False)
    th.join()
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_restart_manager_resume(tmp_path):
    rm = RestartManager(str(tmp_path), interval=2, async_save=False)
    state = tree()
    s, start = rm.maybe_restore(state)
    assert start == 0
    rm.on_step(2, state)
    state2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x,
                          state)
    rm.on_step(4, state2)
    restored, start = rm.maybe_restore(jax.tree.map(jnp.zeros_like, state))
    assert start == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state2["params"]["w"]))


@pytest.mark.slow
def test_kill9_resume_end_to_end(tmp_path):
    """Real driver killed mid-run (os._exit) resumes from checkpoint and
    finishes."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "gte_small",
           "--steps", "14", "--batch", "2", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-interval", "5"]
    p1 = subprocess.run(cmd + ["--kill-at", "8"], env=env, cwd=".",
                        capture_output=True, text=True, timeout=500)
    assert p1.returncode == 42, p1.stdout + p1.stderr
    assert ckpt.latest_step(str(tmp_path)) == 5
    p2 = subprocess.run(cmd, env=env, cwd=".", capture_output=True,
                        text=True, timeout=500)
    assert p2.returncode == 0, p2.stdout + p2.stderr
    assert "resuming at step 6" in p2.stdout
    assert "step 13" in p2.stdout


def test_watchdog_flags_stragglers():
    import time
    wd = StepWatchdog(factor=3.0, warmup=2)
    for _ in range(3):
        wd.start()
        time.sleep(0.01)
        wd.stop(0)
    wd.start()
    time.sleep(0.2)
    rep = wd.stop(3)
    assert rep.is_straggler
