"""Import-or-stub for hypothesis: deterministic tests in a module keep
running in environments without the library; only the @given property
tests skip (individually, with a reason).

The stub `given` replaces the test with a zero-arg skipped function so
pytest never tries to resolve the strategy parameters as fixtures.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def given(*a, **k):
        def deco(f):
            @_SKIP
            def stub():
                pass
            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub
        return deco

    def settings(*a, **k):
        return lambda f: f
