"""Block-table KV pager: refcounts, shared prefixes, COW, oversize.

Covers the PR-8 acceptance contract:
  - PagePool / PrefixCache bookkeeping (all-or-nothing alloc, refcount
    round trips, LRU leaf eviction, first-writer-wins registration);
  - no page leaks under admission/EOS/cancel churn (every page returns
    to the free list once the engine drains and the trie is dropped);
  - COW fork correctness: requests sharing a prefix diverge mid-page
    without cross-talk, bit-identical to isolated cold runs;
  - prefix-hit parity: a prompt served over cached prefix pages emits
    BIT-IDENTICAL tokens to a cold engine (greedy and sampled);
  - oversize admission sheds loudly (terminal "shed" event / session
    counter), never truncates silently.
"""
import numpy as np
import pytest
import jax

from repro.configs import get_reduced
from repro.models import model
from repro.serving.engine import ContinuousEngine, Engine
from repro.serving.pager import PagePool, PrefixCache


# ------------------------------------------------------------- pool units


def test_pool_alloc_refcount_roundtrip():
    pool = PagePool(6)
    a = pool.alloc(4)
    assert sorted(a) == [0, 1, 2, 3] and pool.free_count == 2
    assert pool.alloc(3) is None                  # all-or-nothing
    assert pool.free_count == 2
    pool.incref(a[0])
    assert not pool.decref(a[0])                  # still referenced
    assert pool.decref(a[0])                      # now freed
    for p in a[1:]:
        pool.decref(p)
    assert pool.free_count == 6
    assert int(pool.refs.sum()) == 0


def test_prefix_trie_match_register_evict():
    pool = PagePool(8)
    cache = PrefixCache(pool, page_size=4)
    prompt = np.asarray([1, 2, 3, 4, 5, 6, 7, 8, 9, 10], np.int32)
    pages = pool.alloc(3)                         # 2 full + 1 tail page
    cache.register(prompt, pages)
    # trie holds one ref per registered page, on top of the slot's
    assert int(pool.refs[pages[0]]) == 2
    assert cache.retained_count() == 3
    # exact full-page walk + tail lcp, capped at plen-1
    m = cache.match(prompt)
    assert m.full == pages[:2]
    assert m.cow == (pages[2], 1)                 # tail [9,10], cap 9-8=1
    assert m.matched == 9
    # divergence mid-page -> the full walk stops, the divergent edge COWs
    d = np.asarray([1, 2, 3, 4, 5, 99, 7, 8], np.int32)
    md = cache.match(d)
    assert md.full == pages[:1] and md.cow == (pages[1], 1)
    # no common prefix at all
    assert cache.match(np.asarray([42, 43], np.int32)).matched == 0
    # register is first-writer-wins: re-registering the same prompt from
    # duplicate pages keeps the original pids and adds no references
    dup = pool.alloc(3)
    before = pool.refs.copy()
    cache.register(prompt, dup)
    assert (pool.refs == before).all()
    for p in dup:
        pool.decref(p)
    # eviction drops trie refs only; slot refs keep pages alive
    while cache.evict_one():
        pass
    assert cache.retained_count() == 0
    assert int(pool.refs[pages[0]]) == 1
    for p in pages:
        pool.decref(p)
    assert pool.free_count == pool.num_pages


def test_prefix_trie_drop_frees_everything():
    pool = PagePool(4)
    cache = PrefixCache(pool, page_size=2)
    pages = pool.alloc(2)
    cache.register(np.asarray([5, 6, 7], np.int32), pages)
    for p in pages:
        pool.decref(p)                            # slot done
    assert pool.free_count == 2                   # trie still retains
    assert cache.drop() == 2
    assert pool.free_count == 4


# --------------------------------------------------------- engine fixtures


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _drain(ce):
    res = {}
    sheds = {}
    while ce.pending:
        for ev in ce.step():
            if ev.kind == "done":
                res[ev.rid] = ev.result
            elif ev.kind == "shed":
                sheds[ev.rid] = ev.reason
    return res, sheds


def _assert_no_leak(ce):
    st = ce.page_stats()
    assert st.mapped_refs == st.retained, st      # only the trie holds refs
    ce.drop_prefix_cache()
    st = ce.page_stats()
    assert st.free == st.total and st.mapped_refs == 0, st


# ------------------------------------------------------------ leak churn


def test_no_page_leak_under_churn(dense_setup):
    """Admission / EOS / cancel / oversize churn across several waves:
    after the engine drains and the prefix cache is dropped, every pool
    page is back on the free list (refcount leaks would strand pages)."""
    cfg, params = dense_setup
    ce = ContinuousEngine(cfg, params, slots=3, max_len=96)
    rng = np.random.default_rng(0)
    for wave in range(3):
        rids = []
        for i in range(6):
            p = rng.integers(4, 500, 8 + 11 * i % 40).astype(np.int32)
            rids.append(ce.submit(p, max_new=4, greedy=bool(i % 2),
                                  seed=wave))
        # cancel one queued and (after a step) one in-flight request
        ce.cancel(rids[4])
        ce.step()
        ce.cancel(rids[0])
        # oversize: can never fit table_width pages -> shed, not stuck
        big = rng.integers(4, 500, 96 * 3).astype(np.int32)
        over = ce.submit(big, max_new=8)
        res, sheds = _drain(ce)
        assert sheds.get(over) == "oversize"
        assert rids[0] not in res and rids[4] not in res
        for r in rids[1:4] + rids[5:]:
            assert len(res[r].tokens) > 0
    _assert_no_leak(ce)


def test_ring_engine_pages_recycle(dense_setup):
    """Sliding-window rings disable prefix sharing but still
    allocate/free through the pool: drained engine -> empty pool."""
    cfg = get_reduced("h2o_danube_1_8b")
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    ce = ContinuousEngine(cfg, params, slots=2, max_len=96)
    assert ce.ring_len > 0 and ce.prefix is None
    rng = np.random.default_rng(2)
    prompts = [rng.integers(4, 500, n).astype(np.int32)
               for n in (80, 20, 33)]
    ce.generate(prompts, max_new=4)
    _assert_no_leak(ce)


# ----------------------------------------------------------- COW + parity


def test_cow_fork_no_cross_talk(dense_setup):
    """Two prompts sharing a full page + part of the next page: the
    second COW-forks mid-page. Both emit exactly what they emit when run
    cold and alone — the fork copies the shared history, the divergent
    suffix never leaks into the donor's page."""
    cfg, params = dense_setup
    rng = np.random.default_rng(7)
    a = rng.integers(4, 500, 48).astype(np.int32)
    b = np.concatenate([a[:40], rng.integers(4, 500, 8).astype(np.int32)])
    solo = {}
    for name, p in (("a", a), ("b", b)):
        ce = ContinuousEngine(cfg, params, slots=2, max_len=96)
        solo[name] = ce.generate([p], max_new=6)[0].tokens
    ce = ContinuousEngine(cfg, params, slots=2, max_len=96)
    assert ce.generate([a], max_new=6)[0].tokens == solo["a"]
    # page 0 (tokens 0..31) is shared whole; tokens 32..39 COW-fork out
    # of a's registered second page
    assert ce.generate([b], max_new=6)[0].tokens == solo["b"]
    assert ce.prefix_hits == 1
    assert ce.prefix_tokens_reused == 40
    # and the donor prompt still replays bit-identically afterwards
    assert ce.generate([a], max_new=6)[0].tokens == solo["a"]
    _assert_no_leak(ce)


@pytest.mark.parametrize("greedy", [True, False])
def test_prefix_hit_bit_identical_to_cold(dense_setup, greedy):
    """Acceptance: a prompt admitted over cached prefix pages (full-page
    reuse + COW tail + skipped prefill chunks) produces BIT-identical
    tokens to the same prompt on a cold engine — shared page contents
    equal what cold prefill writes, and the resumed chunk grid realigns
    to the cold boundaries."""
    cfg, params = dense_setup
    rng = np.random.default_rng(11)
    seed_prompt = rng.integers(4, 500, 70).astype(np.int32)
    probe = np.concatenate([seed_prompt[:50],
                            rng.integers(4, 500, 13).astype(np.int32)])
    cold = ContinuousEngine(cfg, params, slots=2, max_len=96)
    want = cold.generate([probe], max_new=8, greedy=greedy)[0].tokens
    warm = ContinuousEngine(cfg, params, slots=2, max_len=96)
    warm.generate([seed_prompt], max_new=8, greedy=greedy)
    got = warm.generate([probe], max_new=8, greedy=greedy)[0].tokens
    assert warm.prefix_hits >= 1 and warm.prefix_tokens_reused >= 32
    assert got == want
    # identical resubmission reuses every page but the last token's
    warm2 = warm.generate([probe], max_new=8, greedy=greedy)[0].tokens
    assert warm2 == want
    _assert_no_leak(warm)


def test_identical_prompts_share_pages_concurrently(dense_setup):
    """The same prompt submitted again AFTER its twin completed maps the
    registered pages read-only; all copies agree with a cold run."""
    cfg, params = dense_setup
    rng = np.random.default_rng(13)
    p = rng.integers(4, 500, 50).astype(np.int32)
    cold = ContinuousEngine(cfg, params, slots=4, max_len=96)
    want = cold.generate([p], max_new=5)[0].tokens
    ce = ContinuousEngine(cfg, params, slots=4, max_len=96)
    res = ce.generate([p, p, p], max_new=5)
    assert all(r.tokens == want for r in res)
    _assert_no_leak(ce)


# ------------------------------------------------- tracing interference


def test_tracing_zero_interference_dense_and_prefix(dense_setup):
    """Tracing is observational only: an engine with a TraceSink
    attached produces BIT-identical tokens to an untraced twin across
    greedy, sampled, and prefix-hit (page reuse + COW tail) admissions —
    and the traced run still records the interesting events."""
    from repro.serving.trace import TraceSink
    cfg, params = dense_setup
    rng = np.random.default_rng(29)
    seed_prompt = rng.integers(4, 500, 70).astype(np.int32)
    probe = np.concatenate([seed_prompt[:50],
                            rng.integers(4, 500, 13).astype(np.int32)])

    def run(trace):
        ce = ContinuousEngine(cfg, params, slots=2, max_len=96,
                              trace=trace)
        a = ce.generate([seed_prompt], max_new=8)[0].tokens
        b = ce.generate([probe], max_new=8, greedy=False,
                        seed=5)[0].tokens
        c = ce.generate([probe], max_new=8)[0].tokens   # prefix hit
        assert ce.prefix_hits >= 1
        _assert_no_leak(ce)
        return a, b, c

    sink = TraceSink()
    assert run(sink) == run(None)
    assert sink.query(comp="pager", name="prefix_hit")
    assert len(sink.query(comp="engine", name="done")) == 3


# -------------------------------------------------------------- oversize


def test_oversize_is_shed_not_truncated(dense_setup):
    """A prompt whose pages (prompt + max_new) exceed the table width is
    refused with a terminal "shed" event — the old silent `p[-keep:]`
    truncation is gone — while in-budget co-residents are unaffected.
    The batch API surfaces the refusal as an error."""
    cfg, params = dense_setup
    ce = ContinuousEngine(cfg, params, slots=2, max_len=96)
    cap = ce.table_width * ce.page_size
    rng = np.random.default_rng(17)
    big = rng.integers(4, 500, cap).astype(np.int32)    # + max_new > cap
    ok = rng.integers(4, 500, 20).astype(np.int32)
    r_big = ce.submit(big, max_new=8)
    r_ok = ce.submit(ok, max_new=4)
    res, sheds = _drain(ce)
    assert sheds == {r_big: "oversize"}
    assert len(res[r_ok].tokens) == 4
    # slightly-over-max_len prompts ride the oversize_pages slack instead
    snug = rng.integers(4, 500, ce.max_len + 2).astype(np.int32)
    r = ce.submit(snug, max_new=4)
    res, sheds = _drain(ce)
    assert not sheds and len(res[r].tokens) == 4
    assert res[r].prompt_len == ce.max_len + 2          # untruncated
    with pytest.raises(RuntimeError, match="oversize"):
        ce.generate([big], max_new=8)
    _assert_no_leak(ce)


def test_wave_and_paged_sampling_agree(dense_setup):
    """The legacy wave sampler now draws from the same per-request
    fold_in(PRNGKey(seed), rid) streams as the paged path (it used to
    advance one shared key, making draws depend on batch composition):
    sampled output is bit-identical across continuous=True/False."""
    cfg, params = dense_setup
    eng = Engine(cfg, params, max_len=96, slots=2)
    rng = np.random.default_rng(23)
    prompts = [rng.integers(4, 500, n).astype(np.int32)
               for n in (16, 16, 24)]
    wave = eng.generate(prompts, max_new=6, greedy=False, seed=9,
                        continuous=False)
    cont = eng.generate(prompts, max_new=6, greedy=False, seed=9,
                        continuous=True)
    for i, (w, c) in enumerate(zip(wave, cont)):
        assert w.tokens == c.tokens, f"request {i} diverged"
