"""HNSW structural invariants + Algorithm 1/2 behaviour, including
hypothesis property tests over random insert/delete interleavings."""
import numpy as np

from hypothesis_compat import given, settings, st

from repro.core.hnsw import HNSW


def build(n=100, d=16, seed=0, M=8):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    g = HNSW(d, M=M, ef_construction=40, seed=seed, max_elements=n)
    for i in range(n):
        g.insert(i, X[i])
    return g, X


def check_invariants(g: HNSW):
    cap0, cap = g.M0, g.M
    for level, layer in enumerate(g.neighbors):
        capl = cap0 if level == 0 else cap
        for v, nbrs in layer.items():
            if g.is_deleted.get(v, False):
                continue
            assert len(nbrs) <= capl, f"degree overflow at level {level}"
            assert v not in nbrs, "self-loop"
            for nb in nbrs:
                assert not g.is_deleted.get(nb, True), \
                    f"link to deleted node {nb}"
                assert g.levels.get(nb, -1) >= level, \
                    "link to node below its level"
    if g.entry_point != -1:
        assert not g.is_deleted.get(g.entry_point, True)
        assert g.levels[g.entry_point] >= g.max_level


def test_build_invariants():
    g, _ = build(150)
    check_invariants(g)


def test_search_exactness_on_small_set():
    g, X = build(80)
    for qi in range(10):
        ids, _ = g.search(X[qi], k=1, ef_search=64)
        assert ids[0] == qi  # the point itself is its own 1-NN


def test_recall_at_10():
    g, X = build(300)
    rng = np.random.default_rng(1)
    Q = X[rng.choice(300, 20)] + 0.01 * rng.normal(size=(20, 16)).astype(
        np.float32)
    rec = []
    for q in Q:
        d = np.sum((X - q) ** 2, 1)
        gt = set(np.argsort(d)[:10])
        ids, _ = g.search(q, k=10, ef_search=64)
        rec.append(len(set(map(int, ids)) & gt) / 10)
    assert np.mean(rec) > 0.9


def test_delete_removes_and_reconnects():
    g, X = build(100)
    victim = 5
    g.delete(victim)
    check_invariants(g)
    ids, _ = g.search(X[victim], k=10, ef_search=64)
    assert victim not in ids
    # remaining nodes still searchable with good recall
    for qi in [1, 2, 3]:
        ids, _ = g.search(X[qi], k=1, ef_search=64)
        assert ids[0] == qi


def test_delete_entry_point():
    g, X = build(50)
    ep = g.entry_point
    g.delete(ep)
    check_invariants(g)
    assert g.entry_point != ep
    ids, _ = g.search(X[(ep + 1) % 50], k=1)
    assert len(ids) == 1


def test_delete_all_then_reinsert():
    g, X = build(20)
    for i in range(20):
        g.delete(i)
    assert len(g) == 0
    assert g.entry_point == -1
    g.insert(99, X[0])
    ids, _ = g.search(X[0], k=1)
    assert ids[0] == 99


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 39)),
                min_size=1, max_size=60))
def test_random_insert_delete_interleaving(ops):
    """Any interleaving of inserts/deletes preserves invariants and
    searches return only live nodes."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(40, 8)).astype(np.float32)
    g = HNSW(8, M=4, ef_construction=16, seed=0, max_elements=40)
    live = set()
    for is_insert, vid in ops:
        if is_insert:
            if vid not in live:
                g.insert(vid, X[vid])
                live.add(vid)
        else:
            if vid in live:
                g.delete(vid)
                live.discard(vid)
    check_invariants(g)
    if live:
        ids, _ = g.search(X[next(iter(live))], k=min(5, len(live)),
                          ef_search=32)
        assert set(map(int, ids)) <= live


def test_memory_accounting_grows():
    g, _ = build(50)
    m1 = g.memory_bytes()
    g2, _ = build(200)
    assert g2.memory_bytes() > m1
