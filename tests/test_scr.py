"""SCR (§4) behaviour + hypothesis properties."""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.scr import (SCRConfig, apply_scr, build_prompt,
                            sliding_windows, split_sentences)
from repro.serving.embedder import HashEmbedder


@pytest.fixture(scope="module")
def embed():
    return HashEmbedder(dim=64)


def test_split_sentences():
    s = split_sentences("One. Two! Three? Four.")
    assert s == ["One.", "Two!", "Three?", "Four."]


def test_sliding_windows_cover_all_sentences():
    spans = sliding_windows(["s"] * 7, window=3, overlap=2)
    covered = set()
    for a, b in spans:
        covered.update(range(a, b))
    assert covered == set(range(7))


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 30), w=st.integers(1, 6), o=st.integers(0, 5))
def test_sliding_windows_properties(n, w, o):
    spans = sliding_windows(["x"] * n, window=w, overlap=o)
    assert spans[0][0] == 0 and spans[-1][1] == n
    covered = set()
    for a, b in spans:
        assert 0 <= a < b <= n
        assert b - a <= max(1, min(w, n))
        covered.update(range(a, b))
    assert covered == set(range(n))


DOC_B = ("The Tiramisu dessert originated in Italy. "
         "An interesting historical note about Tiramisu follows. "
         "Recipe of the Tiramisu includes cheese and coffee. "
         "The price of a single slice of Tiramisu can vary. "
         "Many cafes now offer Tiramisu for pick-up.")
DOC_A = ("Volcanoes are studied by geologists. "
         "Their eruptions follow magma pressure. "
         "Monitoring stations track seismic activity.")


def test_scr_selects_recipe_chunk(embed):
    """The paper's worked example: the recipe window must win for a recipe
    query, and context extension must pull in its neighbours."""
    q = "Show me the dessert recipe from recent downloads."
    res = apply_scr(q, [DOC_A, DOC_B], embed,
                    SCRConfig(sliding_window_size=1, overlap_size=0,
                              context_extension_size=1))
    joined = " ".join(res.texts)
    assert "Recipe of the Tiramisu" in joined
    # reorder: Doc B (recipe) must come first
    assert res.order[0] == 1


def test_scr_reduces_tokens(embed):
    q = "Show me the dessert recipe."
    res = apply_scr(q, [DOC_A, DOC_B], embed, SCRConfig(1, 0, 0))
    assert res.tokens_after < res.tokens_before


def test_scr_prompt_contains_query(embed):
    q = "what about volcanoes?"
    res = apply_scr(q, [DOC_A], embed)
    p = build_prompt(q, res)
    assert q in p


@settings(max_examples=25, deadline=None)
@given(ndocs=st.integers(1, 4), w=st.integers(1, 4), o=st.integers(0, 3),
       ext=st.integers(0, 2))
def test_scr_properties(embed, ndocs, w, o, ext):
    rng = np.random.default_rng(ndocs * 100 + w * 10 + o)
    docs = []
    for i in range(ndocs):
        n = int(rng.integers(1, 10))
        docs.append(" ".join(f"Sentence {i}-{j} mentions topic{i}."
                             for j in range(n)))
    res = apply_scr("tell me about topic0", docs, embed,
                    SCRConfig(w, o, ext))
    # output is a permutation of the inputs
    assert sorted(res.order) == list(range(ndocs))
    # condensation never grows the token count
    assert res.tokens_after <= res.tokens_before
    # scores are sorted descending (reordering step)
    assert all(res.scores[i] >= res.scores[i + 1]
               for i in range(len(res.scores) - 1))
    # each condensed doc's text is a contiguous substring of its source
    for out_text, oi in zip(res.texts, res.order):
        assert out_text in docs[oi] or out_text == docs[oi]
