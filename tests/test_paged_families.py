"""Universal paged decode: the continuous engine across the model zoo.

Covers the PR-5 acceptance contract (DESIGN.md §10):
  - paged-vs-wave greedy BIT-parity for sliding-window (ring pages),
    int8-KV (per-slot scales) and MoE configs — plus the swa+int8 combo;
  - ring-page wraparound where kv_len exceeds the window on SOME slots;
  - per-slot sampling: same (seed, request_id) => same tokens under
    1, 2 and 4 co-residents (fold_in PRNG streams);
  - `supports_paged` coverage and default routing of sampled requests
    through the ContinuousEngine.
"""
import dataclasses

import numpy as np
import pytest
import jax

from repro.configs import get_reduced
from repro.models import model
from repro.serving.engine import ContinuousEngine, Engine


def _cfg(kind: str):
    if kind == "swa":
        return get_reduced("h2o_danube_1_8b")
    if kind == "int8":
        return dataclasses.replace(get_reduced("qwen25_0_5b"),
                                   kv_quant=True)
    if kind == "moe":
        return get_reduced("granite_moe_1b_a400m")
    if kind == "swa_int8":
        return dataclasses.replace(get_reduced("h2o_danube_1_8b"),
                                   kv_quant=True)
    raise KeyError(kind)


def _prompts(seed=7, lens=(16, 24, 33, 40, 9)):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 500, n).astype(np.int32) for n in lens]


def test_supports_paged_covers_the_zoo():
    """swa / int8-KV / moe (and combos) are paged-capable; M-RoPE,
    encoders and recurrent-state families stay on the wave path."""
    for kind in ("swa", "int8", "moe", "swa_int8"):
        assert model.supports_paged(_cfg(kind)), kind
    assert model.supports_paged(get_reduced("qwen25_0_5b"))
    for arch in ("qwen2_vl_2b", "gte_small", "mamba2_780m",
                 "recurrentgemma_9b", "whisper_small"):
        assert not model.supports_paged(get_reduced(arch)), arch
    # moe+swa / moe+int8: the paged helpers would cover them, but the
    # wave baseline (continuous=False) implements neither — excluded so
    # the escape hatch can't silently diverge (DESIGN.md §10)
    moe = get_reduced("granite_moe_1b_a400m")
    assert not model.supports_paged(
        dataclasses.replace(moe, kv_quant=True))
    assert not model.supports_paged(
        dataclasses.replace(moe, sliding_window=64))


@pytest.mark.parametrize("kind", ["swa", "int8", "moe", "swa_int8"])
def test_paged_matches_wave_greedy(kind):
    """Acceptance: slot-paged continuous decode produces token-identical
    greedy output to the legacy wave path for every newly-covered family
    (mixed-length requests over fewer slots, so admission churn and
    chunked prefill are both exercised)."""
    cfg = _cfg(kind)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=96, slots=2)
    prompts = _prompts()
    wave = eng.generate(prompts, max_new=6, continuous=False)
    cont = eng.generate(prompts, max_new=6, continuous=True)
    for i, (w, c) in enumerate(zip(wave, cont)):
        assert w.tokens == c.tokens, f"{kind} request {i} diverged"
        assert c.prefill_s > 0


def test_ring_page_wraparound_mixed_slots():
    """kv_len exceeds the sliding window on one slot while its
    co-resident stays inside it: the long slot's ring wraps (cursor
    pos % window evicts in place) without corrupting either request —
    both stay bit-identical to the wave path."""
    cfg = _cfg("swa")
    w = cfg.sliding_window
    assert w == 64
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    eng = Engine(cfg, params, max_len=96, slots=2)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(4, 500, 80).astype(np.int32),   # wraps: 80 > 64
               rng.integers(4, 500, 20).astype(np.int32)]   # stays inside
    wave = eng.generate(prompts, max_new=8, continuous=False)
    cont = eng.generate(prompts, max_new=8, continuous=True)
    for i, (wv, c) in enumerate(zip(wave, cont)):
        assert wv.tokens == c.tokens, f"slot {i} diverged across wraparound"
    # the per-slot ring really is bounded by the window: each table row
    # maps just enough pages to cover `window` positions, not max_len
    ce = eng.continuous(2)
    assert ce.ring_len == w
    assert ce.table_width == -(-w // ce.page_size)
    assert ce.cache["k"].shape[1] == ce.slots * ce.table_width  # pool pages
    assert ce.cache["k"].shape[2] == ce.page_size


@pytest.fixture(scope="module")
def dense_engine():
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, max_len=96)


def _run_sampled(cfg, params, target, co, *, rid=100, seed=5, max_new=8):
    """Target request sampled under `co` co-residents; returns its
    tokens."""
    ce = ContinuousEngine(cfg, params, slots=4, max_len=96)
    tid = ce.submit(target, max_new=max_new, rid=rid, greedy=False,
                    seed=seed)
    for i, p in enumerate(co):
        ce.submit(p, max_new=max_new, rid=i, greedy=False, seed=seed)
    res = {}
    while ce.pending:
        for ev in ce.step():
            if ev.kind == "done":
                res[ev.rid] = ev.result.tokens
    return res[tid]


def test_sampling_reproducible_across_coresident_mixes(dense_engine):
    """Acceptance: same (seed, request_id) => bit-identical sampled
    tokens with 1, 2 and 4 co-residents. The per-request stream
    fold_in(PRNGKey(seed), rid), advanced by the request's own draw
    counter, never touches a shared key."""
    cfg, params = dense_engine.cfg, dense_engine.params
    rng = np.random.default_rng(3)
    target = rng.integers(4, 500, 20).astype(np.int32)
    others = [rng.integers(4, 500, n).astype(np.int32)
              for n in (12, 28, 17, 22)]
    runs = [_run_sampled(cfg, params, target, others[:n])
            for n in (0, 1, 2, 4)]
    assert all(r == runs[0] for r in runs[1:]), runs
    # a different seed (or rid) gives a different stream
    ce = ContinuousEngine(cfg, params, slots=4, max_len=96)
    tid = ce.submit(target, max_new=8, rid=100, greedy=False, seed=6)
    res = {}
    while ce.pending:
        for ev in ce.step():
            if ev.kind == "done":
                res[ev.rid] = ev.result.tokens
    assert res[tid] != runs[0]


def test_sampled_requests_route_through_continuous(dense_engine):
    """The `greedy and supports_paged` gate is gone: generate(greedy=
    False) runs on the ContinuousEngine by default and is reproducible
    run-to-run (per-request streams), unlike the legacy shared-key wave
    sampler which it no longer uses."""
    prompts = _prompts(seed=5, lens=(14, 14, 22))
    a = dense_engine.generate(prompts, max_new=6, greedy=False, seed=3)
    b = dense_engine.generate(prompts, max_new=6, greedy=False, seed=3)
    for x, y in zip(a, b):
        assert x.tokens == y.tokens
    # draws really are sampled, not greedy
    g = dense_engine.generate(prompts, max_new=6)
    assert any(x.tokens != y.tokens for x, y in zip(a, g))


def test_moe_decode_never_drops_tokens():
    """Serving MoE capacity contract: expert buffers are sized T*k at
    inference, so a junk co-resident row can never displace a real
    token's expert slot (the property the parity/reproducibility tests
    above rely on). Verified by running the same request against wildly
    different co-resident token content."""
    cfg = _cfg("moe")
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(9)
    target = rng.integers(4, 500, 18).astype(np.int32)

    def run(co_seed):
        ce = ContinuousEngine(cfg, params, slots=4, max_len=96)
        tid = ce.submit(target, max_new=6, rid=50)
        r2 = np.random.default_rng(co_seed)
        for i in range(3):
            ce.submit(r2.integers(4, 500, 16 + 8 * i).astype(np.int32),
                      max_new=6, rid=i)
        res = {}
        while ce.pending:
            for ev in ce.step():
                if ev.kind == "done":
                    res[ev.rid] = ev.result.tokens
        return res[tid]

    assert run(1) == run(2) == run(3)


@pytest.mark.parametrize("kind", ["swa", "int8", "moe", "swa_int8"])
def test_tracing_zero_interference_families(kind):
    """Tracing must not perturb decode for any paged family: the same
    mixed-length batch produces bit-identical tokens with a TraceSink
    attached and with tracing disabled."""
    from repro.serving.trace import TraceSink
    cfg = _cfg(kind)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(seed=31, lens=(16, 33, 9))

    def run(trace):
        ce = ContinuousEngine(cfg, params, slots=2, max_len=96,
                              trace=trace)
        return [r.tokens for r in ce.generate(prompts, max_new=6)]

    sink = TraceSink()
    assert run(sink) == run(None)
    assert len(sink.query(comp="engine", name="done")) == len(prompts)
    assert len(sink.query(comp="engine", name="first_token")) \
        == len(prompts)
