"""End-to-end behaviour tests for the full MobileRAG system."""
import numpy as np
import pytest

from repro.data.synthetic import make_qa_corpus, nytimes_like, sift_like
from repro.serving.embedder import HashEmbedder
from repro.serving.rag import MobileRAG, NaiveRAG, accuracy


def test_synthetic_datasets_shapes():
    X, Q = sift_like(n=500, nq=10)
    assert X.shape == (500, 128) and Q.shape == (10, 128)
    assert (X >= 0).all()
    X, Q = nytimes_like(n=300, nq=5)
    np.testing.assert_allclose(np.linalg.norm(X, axis=1), 1.0, rtol=1e-4)


def test_qa_corpus_has_planted_answers():
    c = make_qa_corpus("squad", n_docs=50, n_questions=10)
    for ex in c.examples:
        assert any(ex.answer in c.docs[d] for d in ex.doc_ids)
    c = make_qa_corpus("hotpot", n_docs=50, n_questions=10)
    for ex in c.examples:
        assert len(ex.doc_ids) == 2


def test_full_mobilerag_pipeline_end_to_end():
    """Index build -> update -> query -> SCR -> prompt, with the paper's
    headline property: fewer prompt tokens at comparable accuracy."""
    corpus = make_qa_corpus("squad", n_docs=150, n_questions=25, seed=1)
    emb = HashEmbedder(dim=128)
    mobile = MobileRAG(corpus.docs, emb, top_k=3)
    naive = NaiveRAG(corpus.docs, emb, top_k=3)

    acc_m = accuracy(mobile, corpus.examples, max_q=20)
    acc_n = accuracy(naive, corpus.examples, max_q=20)
    toks_m = np.mean([mobile.answer(e.question).prompt_tokens
                      for e in corpus.examples[:15]])
    toks_n = np.mean([naive.answer(e.question).prompt_tokens
                      for e in corpus.examples[:15]])
    assert acc_m >= acc_n - 0.1
    assert toks_m < 0.75 * toks_n
    assert acc_m > 0.3

    # index update path: add a new document, retrieve it
    newdoc = "The zeppelin99 was first described in 1901. It flew far."
    vec = emb([newdoc])[0]
    new_id = len(corpus.docs)
    mobile.docs.append(newdoc)
    mobile.index.insert(new_id, vec)
    a = mobile.answer("What is known about the zeppelin99?")
    assert new_id in a.doc_ids
    assert "1901" in a.prompt


def test_scr_device_scoring_agrees_with_numpy():
    """SCR through the Pallas kernel == SCR through numpy scoring."""
    from repro.core.scr import SCRConfig, apply_scr
    corpus = make_qa_corpus("trivia", n_docs=30, n_questions=5, seed=2)
    emb = HashEmbedder(dim=64)
    emb.fit(corpus.docs)
    q = corpus.examples[0].question
    r1 = apply_scr(q, corpus.docs[:4], emb, SCRConfig(use_pallas=True))
    r2 = apply_scr(q, corpus.docs[:4], emb, SCRConfig(use_pallas=False))
    assert r1.order == r2.order
    assert r1.texts == r2.texts
