"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ecoscan import ecoscan, route_and_scan
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_prefill import flash_prefill


def k(i):
    return jax.random.PRNGKey(i)


@pytest.mark.parametrize("B,d,NC,CAP,P,K", [
    (2, 32, 8, 64, 2, 5),
    (4, 128, 16, 128, 4, 10),
    (1, 64, 5, 96, 5, 8),
])
def test_ecoscan_sweep(B, d, NC, CAP, P, K):
    q = jax.random.normal(k(0), (B, d))
    data = jax.random.normal(k(1), (NC, CAP, d))
    lens = jax.random.randint(k(2), (NC,), CAP // 2, CAP + 1)
    probes = jnp.stack([jax.random.permutation(k(3 + i), NC)[:P]
                        for i in range(B)]).astype(jnp.int32)
    dk, ik = ecoscan(q, data, lens, probes, k=K)
    dr, ir = ref.ecoscan(q, data, lens, probes, K)
    np.testing.assert_allclose(dk, dr, rtol=2e-5, atol=2e-5)
    assert (np.asarray(ik) == np.asarray(ir)).all()


@pytest.mark.parametrize("merge", ["sort", "argmin"])
@pytest.mark.parametrize("probe_tile", [1, 2, 3, 4])
def test_ecoscan_merge_and_tiling_sweep(merge, probe_tile):
    """Both merge strategies and every probe tiling (including tiles that
    don't divide P) must match the reference exactly."""
    B, d, NC, CAP, P, K = 3, 48, 9, 64, 5, 8
    q = jax.random.normal(k(0), (B, d))
    data = jax.random.normal(k(1), (NC, CAP, d))
    lens = jax.random.randint(k(2), (NC,), 1, CAP + 1)
    probes = jnp.stack([jax.random.permutation(k(3 + i), NC)[:P]
                        for i in range(B)]).astype(jnp.int32)
    dk, ik = ecoscan(q, data, lens, probes, k=K, merge=merge,
                     probe_tile=probe_tile)
    dr, ir = ref.ecoscan(q, data, lens, probes, K)
    np.testing.assert_allclose(dk, dr, rtol=2e-5, atol=2e-5)
    assert (np.asarray(ik) == np.asarray(ir)).all()


@pytest.mark.parametrize("merge", ["sort", "argmin"])
def test_ecoscan_exhausted_candidates_emit_sentinels(merge):
    """Fewer than k valid candidates across multiple grid steps must pad
    with id -1, never duplicate an already-selected id (regression for the
    argmin fallback re-picking stale slots)."""
    q = jnp.zeros((1, 16))
    data = jnp.zeros((4, 32, 16))
    lens = jnp.asarray([3, 0, 0, 0], jnp.int32)
    probes = jnp.asarray([[0, 1]], jnp.int32)
    _, ik = ecoscan(q, data, lens, probes, k=6, merge=merge, probe_tile=1)
    row = np.asarray(ik)[0]
    assert sorted(row[:3]) == [0, 1, 2]
    assert (row[3:] == -1).all()


def test_ecoscan_empty_clusters():
    """Probing only empty clusters yields all-sentinel output."""
    q = jax.random.normal(k(0), (2, 16))
    data = jax.random.normal(k(1), (4, 32, 16))
    lens = jnp.asarray([0, 5, 0, 0], jnp.int32)
    probes = jnp.asarray([[0, 2], [2, 3]], jnp.int32)
    dk, ik = ecoscan(q, data, lens, probes, k=4)
    dr, ir = ref.ecoscan(q, data, lens, probes, 4)
    assert (np.asarray(ik) == -1).all()
    assert (np.asarray(ir) == -1).all()
    np.testing.assert_allclose(dk, dr)


def test_ecoscan_all_padded_probes():
    """Probe ids < 0 are padding and contribute no candidates."""
    q = jax.random.normal(k(0), (2, 16))
    data = jax.random.normal(k(1), (4, 32, 16))
    lens = jnp.full((4,), 32, jnp.int32)
    probes = -jnp.ones((2, 3), jnp.int32)
    dk, ik = ecoscan(q, data, lens, probes, k=4)
    dr, ir = ref.ecoscan(q, data, lens, probes, 4)
    assert (np.asarray(ik) == -1).all()
    assert (np.asarray(ir) == -1).all()
    # ...and a mix of real + padded probes matches the real-only result
    probes_mix = jnp.asarray([[1, -1, 2], [0, 3, -1]], jnp.int32)
    probes_real = jnp.asarray([[1, 2], [0, 3]], jnp.int32)
    dm, im = ecoscan(q, data, lens, probes_mix, k=4)
    dr2, ir2 = ecoscan(q, data, lens, probes_real, k=4)
    np.testing.assert_allclose(dm, dr2, rtol=2e-5, atol=2e-5)
    assert (np.asarray(im) == np.asarray(ir2)).all()


def test_ecoscan_duplicate_probes():
    """A cluster probed twice must match the reference (duplicates are
    surfaced identically by kernel and oracle)."""
    q = jax.random.normal(k(0), (2, 16))
    data = jax.random.normal(k(1), (4, 32, 16))
    lens = jnp.full((4,), 32, jnp.int32)
    probes = jnp.asarray([[1, 1, 2], [3, 0, 3]], jnp.int32)
    dk, ik = ecoscan(q, data, lens, probes, k=6)
    dr, ir = ref.ecoscan(q, data, lens, probes, 6)
    np.testing.assert_allclose(dk, dr, rtol=2e-5, atol=2e-5)
    assert (np.asarray(ik) == np.asarray(ir)).all()


@pytest.mark.parametrize("n_probe", [1, 3, 8])
def test_route_and_scan_fused_matches_ref(n_probe):
    """The single-call fused route->scan equals routing + scan done by the
    pure-jnp oracle."""
    B, d, NC, CAP, K = 4, 32, 8, 64, 7
    q = jax.random.normal(k(0), (B, d))
    cent = jax.random.normal(k(1), (NC, d))
    data = jax.random.normal(k(2), (NC, CAP, d))
    lens = jax.random.randint(k(3), (NC,), 1, CAP + 1)
    dk, sk, pk = route_and_scan(q, cent, data, lens, n_probe=n_probe, k=K)
    dr, sr, pr = ref.route_and_scan(q, cent, data, lens, n_probe, K)
    assert (np.asarray(pk) == np.asarray(pr)).all()
    np.testing.assert_allclose(dk, dr, rtol=2e-5, atol=2e-5)
    assert (np.asarray(sk) == np.asarray(sr)).all()


def test_ecoscan_respects_lens():
    """Slots beyond the cluster's valid count must never be returned."""
    q = jnp.zeros((1, 16))
    data = jnp.zeros((2, 32, 16))  # all points identical (dist 0)
    lens = jnp.asarray([4, 0], jnp.int32)
    probes = jnp.asarray([[0, 1]], jnp.int32)
    _, ids = ecoscan(q, data, lens, probes, k=6)
    valid = np.asarray(ids)[0]
    assert set(valid[valid >= 0]) <= {0, 1, 2, 3}


@pytest.mark.parametrize("N,d,NC", [(100, 16, 5), (513, 64, 33),
                                    (1024, 128, 64)])
def test_kmeans_assign_sweep(N, d, NC):
    x = jax.random.normal(k(0), (N, d))
    c = jax.random.normal(k(1), (NC, d))
    a1, d1 = ops.kmeans_assign(x, c)
    a2, d2 = ref.kmeans_assign(x, c)
    assert (np.asarray(a1) == np.asarray(a2)).all()
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,NW,d", [(1, 5, 32), (3, 200, 64), (2, 257, 128)])
def test_scr_score_sweep(B, NW, d):
    w = jax.random.normal(k(0), (B, NW, d))
    q = jax.random.normal(k(1), (B, d))
    np.testing.assert_allclose(ops.scr_score(w, q), ref.scr_score(w, q),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,d,ND,CAPW,K", [
    (1, 32, 6, 8, 3),
    (3, 64, 12, 24, 5),
    (2, 128, 40, 17, 9),
])
def test_scr_select_sweep(B, d, ND, CAPW, K):
    from repro.kernels.scr_select import scr_select
    q = jax.random.normal(k(0), (B, d))
    data = jax.random.normal(k(1), (ND, CAPW, d))
    lens = jax.random.randint(k(2), (ND,), 0, CAPW + 1)
    ids = jax.random.randint(k(3), (B, K), 0, ND).astype(jnp.int32)
    sk, wk = scr_select(q, data, lens, ids)
    sr, wr = ref.scr_select(q, data, lens, ids)
    np.testing.assert_allclose(sk, sr, rtol=2e-5, atol=2e-5)
    assert (np.asarray(wk) == np.asarray(wr)).all()


@pytest.mark.parametrize("doc_tile", [1, 2, 3, 8])
def test_scr_select_doc_tiling_sweep(doc_tile):
    """Every doc tiling (including tiles that don't divide K) must match
    the reference exactly."""
    from repro.kernels.scr_select import scr_select
    B, d, ND, CAPW, K = 3, 48, 9, 16, 5
    q = jax.random.normal(k(0), (B, d))
    data = jax.random.normal(k(1), (ND, CAPW, d))
    lens = jax.random.randint(k(2), (ND,), 0, CAPW + 1)
    ids = jax.random.randint(k(3), (B, K), 0, ND).astype(jnp.int32)
    sk, wk = scr_select(q, data, lens, ids, doc_tile=doc_tile)
    sr, wr = ref.scr_select(q, data, lens, ids)
    np.testing.assert_allclose(sk, sr, rtol=2e-5, atol=2e-5)
    assert (np.asarray(wk) == np.asarray(wr)).all()


def test_scr_select_padded_and_windowless_docs():
    """Padded slots (id -1) and zero-window docs emit the (-NEG, -1)
    sentinel pair; real docs are unaffected by padding neighbours."""
    from repro.kernels.ref import NEG
    q = jax.random.normal(k(0), (2, 16))
    data = jax.random.normal(k(1), (4, 8, 16))
    lens = jnp.asarray([3, 0, 8, 1], jnp.int32)
    ids = jnp.asarray([[0, 1, -1], [2, 3, 1]], jnp.int32)
    s, w = ops.scr_select(q, data, lens, ids)
    s, w = np.asarray(s), np.asarray(w)
    assert w[0, 1] == -1 and w[0, 2] == -1 and w[1, 2] == -1
    assert s[0, 1] == -NEG and s[0, 2] == -NEG
    assert w[0, 0] >= 0 and w[1, 0] >= 0 and w[1, 1] == 0
    # windows beyond lens are never selected
    assert w[0, 0] < 3 and w[1, 1] < 1


def test_scr_select_host_vs_device_agreement():
    """use_pallas=True (kernel) and use_pallas=False (pure-jnp oracle)
    agree on scores and picked windows — the dispatch contract the
    batched SCR path relies on."""
    q = jax.random.normal(k(4), (4, 32))
    data = jax.random.normal(k(5), (10, 12, 32))
    lens = jax.random.randint(k(6), (10,), 0, 13)
    ids = jax.random.randint(k(7), (4, 6), -1, 10).astype(jnp.int32)
    sd, wd = ops.scr_select(q, data, lens, ids, use_pallas=True)
    sh, wh = ops.scr_select(q, data, lens, ids, use_pallas=False)
    np.testing.assert_allclose(sd, sh, rtol=2e-5, atol=2e-5)
    assert (np.asarray(wd) == np.asarray(wh)).all()


def test_scr_select_first_max_tie_break():
    """Duplicate best windows resolve to the lowest window id, matching
    the host Python max() scan."""
    d = 8
    q = jnp.ones((1, d))
    w = jnp.ones((d,))
    data = jnp.stack([jnp.stack([w * 0.5, w, w, w * 0.2])])  # [1, 4, d]
    lens = jnp.asarray([4], jnp.int32)
    ids = jnp.asarray([[0]], jnp.int32)
    _, wins = ops.scr_select(q, data, lens, ids)
    assert int(np.asarray(wins)[0, 0]) == 1


@pytest.mark.parametrize("B,M,N", [(1, 4, 100), (2, 8, 513), (3, 16, 64)])
def test_pq_adc_sweep(B, M, N):
    lut = jax.random.normal(k(0), (B, M, 256))
    codes = jax.random.randint(k(1), (N, M), 0, 256).astype(jnp.uint8)
    np.testing.assert_allclose(ops.pq_adc(lut, codes), ref.pq_adc(lut, codes),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,H,G,dh,S,kvlen", [
    (1, 4, 1, 32, 128, 100),
    (2, 8, 2, 64, 700, 650),
    (2, 16, 16, 64, 512, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, G, dh, S, kvlen, dtype):
    q = jax.random.normal(k(0), (B, H, dh), dtype)
    kk = jax.random.normal(k(1), (B, S, G, dh), dtype)
    vv = jax.random.normal(k(2), (B, S, G, dh), dtype)
    o1 = decode_attention(q, kk, vv, kvlen)
    o2 = ref.decode_attention(q, kk, vv, kvlen)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_per_slot_lengths():
    """Slot-paged batches: kv_len is a per-row [B] vector — every row is
    masked to its OWN length, matching per-row calls of the oracle."""
    B, H, G, dh, S = 4, 8, 2, 32, 128
    q = jax.random.normal(k(0), (B, H, dh))
    kk = jax.random.normal(k(1), (B, S, G, dh))
    vv = jax.random.normal(k(2), (B, S, G, dh))
    lens = jnp.asarray([3, 100, 128, 57], jnp.int32)
    o1 = decode_attention(q, kk, vv, lens)
    for b in range(B):
        row = ref.decode_attention(q[b:b + 1], kk[b:b + 1], vv[b:b + 1],
                                   int(lens[b]))
        np.testing.assert_allclose(np.asarray(o1[b:b + 1], np.float32),
                                   np.asarray(row, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_decode_attention_ring_clamps_per_slot():
    """Ring pages: a slot whose absolute position exceeds the ring size
    attends ALL S filled slots (mask length min(kv_len, S)), while a
    co-resident still inside the ring keeps its shorter mask. Kernel and
    oracle agree, and ring=True differs from the unclamped call only via
    the clamp."""
    B, H, G, dh, S = 2, 4, 1, 32, 64
    q = jax.random.normal(k(3), (B, H, dh))
    kk = jax.random.normal(k(4), (B, S, G, dh))
    vv = jax.random.normal(k(5), (B, S, G, dh))
    lens = jnp.asarray([150, 20], jnp.int32)       # slot 0 wrapped, 1 not
    o1 = decode_attention(q, kk, vv, lens, ring=True)
    o2 = ref.decode_attention(q, kk, vv, lens, ring=True)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=2e-4, atol=2e-4)
    full = ref.decode_attention(q, kk, vv, jnp.asarray([64, 20], jnp.int32))
    np.testing.assert_allclose(np.asarray(o2, np.float32),
                               np.asarray(full, np.float32))


def test_decode_attention_paged_matches_gather_and_ref():
    """Block-table decode: the scalar-prefetched paged kernel walks each
    slot's page-table row directly in the pool, and must match (a) the
    pure-jnp paged oracle and (b) gathering the logical buffer through
    the table and running the plain kernel — including rows that share a
    prefix page and table tail entries past kv_len (masked junk)."""
    B, H, G, dh, P, ps, W = 3, 4, 2, 32, 8, 16, 4
    q = jax.random.normal(k(6), (B, H, dh))
    pool_k = jax.random.normal(k(7), (P, ps, G, dh))
    pool_v = jax.random.normal(k(8), (P, ps, G, dh))
    # rows 0 and 1 share page 2 as their first (prefix) page; tail
    # entries past each row's kv_len point at junk pages
    table = jnp.asarray([[2, 0, 1, 7],
                         [2, 5, 7, 7],
                         [4, 3, 6, 0]], jnp.int32)
    lens = jnp.asarray([3 * ps, ps + 5, 2 * ps - 1], jnp.int32)
    o_kernel = ops.decode_attention_paged(q, pool_k, pool_v, lens, table)
    o_ref = ref.decode_attention_paged(q, pool_k, pool_v, lens, table)
    np.testing.assert_allclose(np.asarray(o_kernel, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=2e-4, atol=2e-4)
    # oracle for the oracle: per-row gather + plain decode_attention
    flat_k = pool_k.reshape(P * ps, G, dh)
    flat_v = pool_v.reshape(P * ps, G, dh)
    j = jnp.arange(W * ps)
    idx = table[:, j // ps] * ps + (j % ps)
    o_gather = ref.decode_attention(q, jnp.take(flat_k, idx, axis=0),
                                    jnp.take(flat_v, idx, axis=0), lens)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_gather, np.float32))


@pytest.mark.parametrize("B,H,S,dh,window", [
    (1, 2, 256, 32, None),
    (1, 2, 300, 64, 64),
    (2, 4, 128, 32, None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(B, H, S, dh, window, dtype):
    q = jax.random.normal(k(0), (B, H, S, dh), dtype)
    kk = jax.random.normal(k(1), (B, H, S, dh), dtype)
    vv = jax.random.normal(k(2), (B, H, S, dh), dtype)
    o1 = flash_prefill(q, kk, vv, window=window)
    o2 = ref.flash_prefill(q, kk, vv, window=window)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


def test_flash_prefill_matches_model_attention():
    """Cross-check the kernel against the model's chunked-scan attention."""
    from repro.models.layers import attention
    B, H, S, dh = 1, 4, 256, 32
    q = jax.random.normal(k(0), (B, S, H, dh))
    kv = jax.random.normal(k(1), (B, S, H, dh))
    vv = jax.random.normal(k(2), (B, S, H, dh))
    o_model = attention(q, kv, vv, causal=True, chunk=64)
    o_kernel = flash_prefill(q.transpose(0, 2, 1, 3),
                             kv.transpose(0, 2, 1, 3),
                             vv.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(o_model, np.float32),
                               np.asarray(o_kernel.transpose(0, 2, 1, 3),
                                          np.float32), rtol=2e-3, atol=2e-3)
