"""Training substrate: optimizer correctness, int8 moments, gradient
compression, loss goes down."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, ShapeConfig, TrainConfig
from repro.configs import get_reduced
from repro.models import model
from repro.train import optimizer as opt
from repro.train import trainer
from repro.train.grad_compress import (compress_decompress,
                                       compress_with_feedback, init_ef_state)


def test_quantize_rows_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 300)).astype(np.float32)
    qt = opt.quantize_rows(jnp.asarray(x))
    x2 = np.asarray(opt.dequantize_rows(qt))
    row_max = np.abs(x).max(axis=1, keepdims=True)
    assert np.all(np.abs(x - x2) <= row_max / 127.0 + 1e-6)


def test_adamw_matches_reference_float32():
    """Our AdamW against a hand-rolled reference on a tiny problem."""
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=0, weight_decay=0.0,
                       grad_clip=0.0)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    grads = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]])}
    state = opt.init_opt_state(params)
    lr_fn = lambda s: jnp.asarray(1e-2)  # noqa: E731
    p2, s2, m = opt.adamw_update(tcfg, params, grads, state, lr_fn)
    # reference
    g = np.asarray(grads["w"])
    mm = 0.1 * g
    vv = 0.05 * g * g
    mhat = mm / (1 - 0.9)
    vhat = vv / (1 - 0.95)
    ref = np.asarray(params["w"]) - 1e-2 * mhat / (np.sqrt(vhat) + tcfg.eps)
    np.testing.assert_allclose(np.asarray(p2["w"]), ref, rtol=1e-5)


@pytest.mark.parametrize("moments", ["float32", "int8"])
def test_training_reduces_loss(moments):
    cfg = get_reduced("qwen25_0_5b")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                    train=TrainConfig(learning_rate=1e-3, warmup_steps=5))
    step_fn, nmb, _ = trainer.make_train_step(run, max_steps=60, seq_sp=False)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    params, _ = trainer.make_states(run, key=jax.random.PRNGKey(0))
    opt_state = opt.init_opt_state(params, moments)
    rng = np.random.default_rng(0)
    data = rng.integers(4, cfg.vocab_size, (8, 33)).astype(np.int32)
    batch = {"tokens": jnp.asarray(data[:, :-1]),
             "labels": jnp.asarray(data[:, 1:])}
    first = None
    # memorize one batch: loss must drop substantially
    import repro.train.trainer as tr
    lr_fn = opt.lr_schedule(run.train, 60)
    losses = []
    for i in range(25):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch), has_aux=True)(params)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        params, opt_state, _ = opt.adamw_update(run.train, params, grads,
                                                opt_state, lr_fn, moments)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_reduced("h2o_danube_1_8b")
    shape = ShapeConfig("t", 16, 8, "train")
    run = RunConfig(model=cfg, shape=shape,
                    train=TrainConfig(grad_clip=0.0, warmup_steps=0))
    params, opt_state = trainer.make_states(run, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(4, 100, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(4, 100, (8, 16)), jnp.int32),
    }
    s1, _, _ = trainer.make_train_step(run, microbatches=1, seq_sp=False)
    s4, _, _ = trainer.make_train_step(run, microbatches=4, seq_sp=False)
    p1, _, m1 = s1(params, opt_state, batch)
    p4, _, m4 = s4(params, opt_state, batch)
    # same gradient (up to accumulation-order fp noise) -> same update
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_grad_compression_roundtrip_and_feedback():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=(8, 64)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    g2 = compress_decompress(g)
    for k in g:
        err = np.abs(np.asarray(g[k]) - np.asarray(g2[k]))
        assert err.max() < np.abs(np.asarray(g[k])).max() / 100
    # error feedback: accumulated compressed sum converges to true sum
    ef = init_ef_state(g)
    tot_true = jax.tree.map(lambda x: x * 0.0, g)
    tot_sent = jax.tree.map(lambda x: x * 0.0, g)
    for _ in range(10):
        sent, ef = compress_with_feedback(g, ef)
        tot_true = jax.tree.map(lambda a, b: a + b, tot_true, g)
        tot_sent = jax.tree.map(lambda a, b: a + b, tot_sent, sent)
    for k in g:
        num = np.abs(np.asarray(tot_true[k]) - np.asarray(tot_sent[k]))
        assert num.max() < np.abs(np.asarray(g[k])).max() / 50


def test_lr_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10)
    lr = opt.lr_schedule(tcfg, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10))
