"""Serving: engine generation, scheduler hedging/failover, RAG pipelines."""
import numpy as np
import pytest
import jax

from repro.configs import get_reduced
from repro.data.synthetic import make_qa_corpus
from repro.models import model
from repro.serving.embedder import HashEmbedder
from repro.serving.engine import Engine
from repro.serving.rag import PIPELINES, MobileRAG, NaiveRAG, accuracy
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, max_len=96)


def test_engine_generates(engine):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, 100, 24).astype(np.int32) for _ in range(3)]
    out = engine.generate(prompts, max_new=5)
    assert len(out) == 3
    for r in out:
        assert 1 <= len(r.tokens) <= 5
        assert r.prefill_s > 0


def test_engine_buckets_unequal_lengths(engine):
    rng = np.random.default_rng(1)
    prompts = [rng.integers(4, 100, n).astype(np.int32)
               for n in (16, 24, 16, 32)]
    out = engine.generate(prompts, max_new=3)
    assert all(r is not None for r in out)
    # determinism within equal inputs
    out2 = engine.generate(prompts, max_new=3)
    assert out[0].tokens == out2[0].tokens


def test_scheduler_hedges_on_failure():
    calls = {"bad": 0, "good": 0}

    def bad(prompts, mx):
        calls["bad"] += 1
        raise RuntimeError("replica down")

    def good(prompts, mx):
        calls["good"] += 1
        return [[1, 2, 3] for _ in prompts]

    s = Scheduler([bad, good], max_wave=2, deadline_s=10, max_strikes=1)
    for i in range(4):
        s.submit(np.arange(8, dtype=np.int32))
    done = s.run()
    assert len(done) == 4
    assert calls["good"] >= 2
    assert not s.state[0].healthy  # bad replica drained
    assert any(c.hedged for c in done)


def test_scheduler_buckets_by_length():
    seen = []

    def rep(prompts, mx):
        seen.append([len(p) for p in prompts])
        return [[1] for _ in prompts]

    s = Scheduler([rep], max_wave=8)
    for n in (8, 8, 16, 8, 16):
        s.submit(np.zeros(n, np.int32))
    s.run()
    for wave in seen:
        assert len(set(wave)) == 1  # equal lengths within a wave


@pytest.fixture(scope="module")
def corpus():
    return make_qa_corpus("squad", n_docs=100, n_questions=20, seed=0)


def test_all_pipelines_answer(corpus):
    emb = HashEmbedder(dim=96)
    for name, cls in PIPELINES.items():
        pipe = cls(corpus.docs, emb, top_k=3)
        a = pipe.answer(corpus.examples[0].question)
        assert a.prompt_tokens > 0
        assert a.ttft_model_s > 0
        assert len(a.doc_ids) > 0


def test_mobilerag_reduces_tokens_at_same_accuracy(corpus):
    emb = HashEmbedder(dim=96)
    naive = NaiveRAG(corpus.docs, emb, top_k=3)
    mobile = MobileRAG(corpus.docs, emb, top_k=3)
    acc_n = accuracy(naive, corpus.examples, max_q=15)
    acc_m = accuracy(mobile, corpus.examples, max_q=15)
    tok_n = np.mean([naive.answer(e.question).prompt_tokens
                     for e in corpus.examples[:10]])
    tok_m = np.mean([mobile.answer(e.question).prompt_tokens
                     for e in corpus.examples[:10]])
    assert tok_m < tok_n * 0.8          # >= 20% token reduction
    assert acc_m >= acc_n - 0.15        # no material accuracy loss


def test_mobilerag_generate_end_to_end(corpus):
    """Acceptance: answer(query, generate=True) returns REAL decoded
    tokens from serving.Engine — retrieval -> SCR -> LM generation
    executes end to end on CPU."""
    emb = HashEmbedder(dim=96)
    mobile = MobileRAG(corpus.docs, emb, top_k=3)
    a = mobile.answer(corpus.examples[0].question, generate=True)
    assert a.gen_tokens and 1 <= len(a.gen_tokens) <= 16
    assert isinstance(a.generated, str)
    assert a.ttft_measured_s > 0
    # batched path decodes every prompt in one Engine wave
    batch = mobile.answer_batch(
        [e.question for e in corpus.examples[:2]], generate=True)
    assert all(x.gen_tokens for x in batch)
    assert all(x.ttft_measured_s > 0 for x in batch)


def test_mobilerag_ttft_beats_naive(corpus):
    emb = HashEmbedder(dim=96)
    naive = NaiveRAG(corpus.docs, emb, top_k=3)
    mobile = MobileRAG(corpus.docs, emb, top_k=3)
    q = corpus.examples[0].question
    assert mobile.answer(q).ttft_model_s < naive.answer(q).ttft_model_s
