"""Serving: engine generation (wave + continuous), scheduler hedging /
failover / slot admission, RAG pipelines and streaming sessions."""
import numpy as np
import pytest
import jax

from repro.configs import get_reduced
from repro.data.synthetic import make_qa_corpus
from repro.models import model
from repro.serving.embedder import HashEmbedder
from repro.serving.engine import ContinuousEngine, Engine
from repro.serving.rag import (PIPELINES, EdgeRAG, MobileRAG, NaiveRAG,
                               accuracy)
from repro.serving.scheduler import Scheduler, SlotScheduler


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, max_len=96)


def test_engine_generates(engine):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, 100, 24).astype(np.int32) for _ in range(3)]
    out = engine.generate(prompts, max_new=5)
    assert len(out) == 3
    for r in out:
        assert 1 <= len(r.tokens) <= 5
        assert r.prefill_s > 0


def test_engine_buckets_unequal_lengths(engine):
    rng = np.random.default_rng(1)
    prompts = [rng.integers(4, 100, n).astype(np.int32)
               for n in (16, 24, 16, 32)]
    out = engine.generate(prompts, max_new=3)
    assert all(r is not None for r in out)
    # determinism within equal inputs
    out2 = engine.generate(prompts, max_new=3)
    assert out[0].tokens == out2[0].tokens


def test_continuous_matches_wave_greedy(engine):
    """Acceptance: under mixed-length concurrent requests (more requests
    than slots, so admission churn happens mid-stream) the slot-paged
    continuous engine produces token-identical greedy outputs to the
    legacy wave path, for every request."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(4, 500, n).astype(np.int32)
               for n in (16, 24, 16, 33, 40, 9, 24)]
    wave = engine.generate(prompts, max_new=8, continuous=False)
    cont = engine.generate(prompts, max_new=8, continuous=True)
    for i, (w, c) in enumerate(zip(wave, cont)):
        assert w.tokens == c.tokens, f"request {i} diverged"
        assert c.prefill_s > 0
    ce = engine.continuous()
    assert ce.steps > 0 and 0 < ce.utilisation() <= 1.0


def test_continuous_parity_misaligned_page(engine):
    """max_len NOT a multiple of prefill_chunk: the final prompt chunk
    would cross the page end, and dynamic_update_slice CLAMPS rather than
    drops — the page must be allocated rounded up to whole chunks or the
    last chunk silently shifts back over earlier positions."""
    eng = Engine(engine.cfg, engine.params, max_len=100, slots=2)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(4, 500, n).astype(np.int32) for n in (97, 65)]
    wave = eng.generate(prompts, max_new=3, continuous=False)
    cont = eng.generate(prompts, max_new=3, continuous=True)
    for w, c in zip(wave, cont):
        assert w.tokens == c.tokens


def test_continuous_engine_step_lifecycle(engine):
    """submit/step surface: admitted -> token(s) -> done, slot freed on
    EOS/max_new admits the next queued prompt on a later step."""
    ce = ContinuousEngine(engine.cfg, engine.params, slots=2, max_len=96)
    rng = np.random.default_rng(3)
    rids = [ce.submit(rng.integers(4, 500, n).astype(np.int32), max_new=4)
            for n in (12, 20, 8)]             # 3 requests, 2 slots
    seen = {r: [] for r in rids}
    results = {}
    while ce.pending:
        for ev in ce.step():
            seen[ev.rid].append(ev.kind)
            if ev.kind == "done":
                results[ev.rid] = ev.result
    for r in rids:
        assert seen[r][0] == "admitted"
        assert seen[r][-1] == "done"
        assert 1 <= len(results[r].tokens) <= 4
    # the third request can only have been admitted after a slot freed
    assert ce.free_slots() == 2


def test_continuous_rejects_unpaged_family():
    cfg = get_reduced("mamba2_780m")
    with pytest.raises(ValueError):
        ContinuousEngine(cfg, None, slots=2, max_len=32)
    assert not model.supports_paged(cfg)


def test_scheduler_hedges_on_failure():
    calls = {"bad": 0, "good": 0}

    def bad(prompts, mx):
        calls["bad"] += 1
        raise RuntimeError("replica down")

    def good(prompts, mx):
        calls["good"] += 1
        return [[1, 2, 3] for _ in prompts]

    s = Scheduler([bad, good], max_wave=2, deadline_s=10, max_strikes=1)
    for i in range(4):
        s.submit(np.arange(8, dtype=np.int32))
    done = s.run()
    assert len(done) == 4
    assert calls["good"] >= 2
    assert not s.state[0].healthy  # bad replica drained
    assert any(c.hedged for c in done)


def test_scheduler_buckets_by_length():
    seen = []

    def rep(prompts, mx):
        seen.append([len(p) for p in prompts])
        return [[1] for _ in prompts]

    s = Scheduler([rep], max_wave=8)
    for n in (8, 8, 16, 8, 16):
        s.submit(np.zeros(n, np.int32))
    s.run()
    for wave in seen:
        assert len(set(wave)) == 1  # equal lengths within a wave


def test_slot_scheduler_spreads_and_fails_over(engine):
    e1 = ContinuousEngine(engine.cfg, engine.params, slots=2, max_len=96)
    e2 = ContinuousEngine(engine.cfg, engine.params, slots=2, max_len=96)
    s = SlotScheduler([e1, e2])
    rng = np.random.default_rng(0)
    for n in (12, 20, 16, 8, 24, 12):
        s.submit(rng.integers(4, 500, n).astype(np.int32), max_new=4)
    done = s.run()
    assert len(done) == 6
    assert {c.replica for c in done} == {0, 1}   # slot admission spreads

    class Broken:
        def submit(self, p, m):
            return 0

        def available_slots(self):
            return 2

        def step(self):
            raise RuntimeError("replica down")

    s2 = SlotScheduler([Broken(), e1], max_strikes=1)
    for n in (10, 14):
        s2.submit(rng.integers(4, 500, n).astype(np.int32), max_new=3)
    done2 = s2.run()
    assert len(done2) == 2 and all(c.replica == 1 for c in done2)
    assert not s2.state[0].healthy               # broken replica drained


@pytest.fixture(scope="module")
def corpus():
    return make_qa_corpus("squad", n_docs=100, n_questions=20, seed=0)


def test_all_pipelines_answer(corpus):
    emb = HashEmbedder(dim=96)
    for name, cls in PIPELINES.items():
        pipe = cls(corpus.docs, emb, top_k=3)
        a = pipe.answer(corpus.examples[0].question)
        assert a.prompt_tokens > 0
        assert a.ttft_model_s > 0
        assert len(a.doc_ids) > 0


def test_mobilerag_reduces_tokens_at_same_accuracy(corpus):
    emb = HashEmbedder(dim=96)
    naive = NaiveRAG(corpus.docs, emb, top_k=3)
    mobile = MobileRAG(corpus.docs, emb, top_k=3)
    acc_n = accuracy(naive, corpus.examples, max_q=15)
    acc_m = accuracy(mobile, corpus.examples, max_q=15)
    tok_n = np.mean([naive.answer(e.question).prompt_tokens
                     for e in corpus.examples[:10]])
    tok_m = np.mean([mobile.answer(e.question).prompt_tokens
                     for e in corpus.examples[:10]])
    assert tok_m < tok_n * 0.8          # >= 20% token reduction
    assert acc_m >= acc_n - 0.15        # no material accuracy loss


def test_mobilerag_generate_end_to_end(corpus):
    """Acceptance: answer(query, generate=True) returns REAL decoded
    tokens from serving.Engine — retrieval -> SCR -> LM generation
    executes end to end on CPU."""
    emb = HashEmbedder(dim=96)
    mobile = MobileRAG(corpus.docs, emb, top_k=3)
    a = mobile.answer(corpus.examples[0].question, generate=True)
    assert a.gen_tokens and 1 <= len(a.gen_tokens) <= 16
    assert isinstance(a.generated, str)
    assert a.ttft_measured_s > 0
    # batched path decodes every prompt in one Engine wave
    batch = mobile.answer_batch(
        [e.question for e in corpus.examples[:2]], generate=True)
    assert all(x.gen_tokens for x in batch)
    assert all(x.ttft_measured_s > 0 for x in batch)


def test_rag_session_event_lifecycle(corpus):
    """RagSession streams the full request lifecycle in order: submitted
    -> retrieved -> condensed -> token(s) -> done, and the completed
    answers carry real decoded tokens."""
    emb = HashEmbedder(dim=96)
    mobile = MobileRAG(corpus.docs, emb, top_k=3)
    qs = [e.question for e in corpus.examples[:4]]
    kinds = {}
    answers = {}
    for ev in mobile.stream(qs, max_new=5, slots=2, retrieve_chunk=2):
        kinds.setdefault(ev.req_id, []).append(ev.kind)
        if ev.kind == "done":
            answers[ev.req_id] = ev.payload
    assert set(kinds) == {0, 1, 2, 3}
    for rid, ks in kinds.items():
        assert ks[0] == "submitted"
        assert ks[1:3] == ["retrieved", "condensed"]
        assert ks[-1] == "done"
        assert ks[3:-1] and all(k == "token" for k in ks[3:-1])
        a = answers[rid]
        assert a.gen_tokens and a.ttft_measured_s > 0
        assert isinstance(a.generated, str)


def test_session_overlaps_retrieval_with_decode(corpus):
    """With retrieve_chunk < len(queries), later queries are still
    un-retrieved while earlier ones already decode — the pipelining the
    session exists for. (Each step retrieves ONE query and advances the
    engine one step; request 0's prompt prefills in a few 32-token
    chunks, so its first token precedes the tail queries' retrieval.)"""
    emb = HashEmbedder(dim=96)
    mobile = MobileRAG(corpus.docs, emb, top_k=2)
    sess = mobile.session(max_new=6, slots=2, retrieve_chunk=1)
    last = 7
    events = []
    for e in corpus.examples[:last + 1]:
        sess.submit(e.question)
    while sess.pending:
        events.extend(sess.step())
    order = [(e.req_id, e.kind) for e in events]
    first_token_req0 = order.index((0, "token"))
    retrieved_last = order.index((last, "retrieved"))
    assert first_token_req0 < retrieved_last


def test_edge_rag_qcache_lru_bounded(corpus):
    emb = HashEmbedder(dim=96)
    edge = EdgeRAG(corpus.docs, emb, top_k=3)
    edge.qcache_cap = 3
    stream = ["a?", "b?", "c?", "a?", "d?", "e?", "a?"]
    for q in stream:
        edge.answer(q)
    assert len(edge._qcache) <= 3                 # bounded under churn
    assert edge.qcache_hits >= 1                  # repeat query hit
    assert edge.qcache_hits + edge.qcache_misses == len(stream)


def test_mobilerag_ttft_beats_naive(corpus):
    emb = HashEmbedder(dim=96)
    naive = NaiveRAG(corpus.docs, emb, top_k=3)
    mobile = MobileRAG(corpus.docs, emb, top_k=3)
    q = corpus.examples[0].question
    assert mobile.answer(q).ttft_model_s < naive.answer(q).ttft_model_s
