"""Per-request span tracing + SLO admission (PR-10 acceptance).

Covers the trace layer as a correctness ORACLE, not just logging:
  - TraceSink semantics: monotone timestamps under clock skew, ring
    eviction accounting, span pairing, JSONL export/load round trip;
  - tools/trace_check.py catches every class of lifecycle violation it
    claims to (order, orphans, double terminals, unclosed spans, page
    leaks, silent fault drops) and passes real engine/session runs —
    including ring-truncated exports and recycled rids;
  - property-based workloads (ragged lengths, seeds, cancels) through
    the checker, with deterministic fallbacks per hypothesis_compat;
  - SLOController: degrade-before-shed ladder from live p95 stage
    costs, never shedding blind, wired through RagSession admission.
"""
import importlib.util
import pathlib

import numpy as np
import pytest
import jax

from hypothesis_compat import given, settings, st

from repro.configs import get_reduced
from repro.models import model
from repro.serving.engine import ContinuousEngine
from repro.serving.trace import SLOController, TraceSink, load_jsonl

_TC = pathlib.Path(__file__).resolve().parent.parent / "tools" \
    / "trace_check.py"
_spec = importlib.util.spec_from_file_location("trace_check", _TC)
trace_check = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_check)


# ------------------------------------------------------------- sink units


def test_sink_timestamps_monotone_under_clock_skew():
    """A clock that jumps backwards must not produce an unordered
    stream: emit() clamps ts to the high-water mark."""
    ticks = iter([5.0, 4.0, 4.5, 6.0])
    sink = TraceSink(clock=lambda: next(ticks))
    for i in range(4):
        sink.emit("bench", "tick", i)
    ts = [r.ts for r in sink.records()]
    assert ts == [5.0, 5.0, 5.0, 6.0]
    assert not trace_check.check_records(sink.records(), complete=False)


def test_sink_ring_eviction_counts():
    sink = TraceSink(capacity=4)
    for i in range(7):
        sink.emit("engine", "token", i)
    assert len(sink) == 4 and sink.evicted == 3
    assert [r.rid for r in sink.records()] == [3, 4, 5, 6]
    assert sink.records()[0].seq == 3     # truncation is detectable


def test_sink_query_durations_percentile():
    clock = {"t": 0.0}
    sink = TraceSink(clock=lambda: clock["t"])
    for i, dur in enumerate((0.01, 0.02, 0.03)):
        sink.emit("engine", "decode_step", ph="B")
        clock["t"] += dur
        sink.emit("engine", "decode_step", ph="E")
        sink.emit("session", "queued", i)
    assert len(sink.query(comp="session")) == 3
    assert len(sink.query(comp="engine", name="decode_step")) == 6
    ds = sink.durations("engine", "decode_step")
    assert np.allclose(ds, [0.01, 0.02, 0.03])
    assert np.isclose(sink.percentile("engine", "decode_step", 50), 0.02)
    assert np.isclose(sink.percentile("engine", "decode_step", 95,
                                      window=2), 0.03)
    assert sink.percentile("engine", "prefill_chunk", default=7.0) == 7.0


def test_jsonl_roundtrip(tmp_path):
    sink = TraceSink()
    sink.emit("engine", "queued", 0, src="e0", prompt_len=8)
    with sink.span("engine", "prefill_chunk", 0, src="e0", n=4):
        pass
    path = tmp_path / "t.jsonl"
    assert sink.export_jsonl(path) == 3
    back = load_jsonl(path)
    assert [r.to_dict() for r in back] \
        == [r.to_dict() for r in sink.records()]
    assert back[0].attrs["prompt_len"] == 8


# ------------------------------------------------- checker catches badness


def _r(seq, comp, name, rid=-1, ph="I", src="e0", **attrs):
    return {"seq": seq, "ts": float(seq), "comp": comp, "src": src,
            "rid": rid, "name": name, "ph": ph, "attrs": attrs}


def _good_chain(rid=0, seq0=0):
    return [
        _r(seq0 + 0, "engine", "queued", rid),
        _r(seq0 + 1, "engine", "admitted", rid),
        _r(seq0 + 2, "engine", "prefill_chunk", rid, ph="B"),
        _r(seq0 + 3, "engine", "prefill_chunk", rid, ph="E"),
        _r(seq0 + 4, "engine", "first_token", rid),
        _r(seq0 + 5, "engine", "token", rid),
        _r(seq0 + 6, "engine", "done", rid),
    ]


def test_checker_accepts_good_chain_and_recycled_rid():
    recs = _good_chain(0) + _good_chain(0, seq0=7)   # rid reuse is legal
    assert trace_check.check_records(recs) == []


@pytest.mark.parametrize("mutate, needle", [
    # token stream before the first_token marker
    (lambda c: [c[0], c[1], _r(9, "engine", "token", 0),
                c[4], c[6]], "before"),
    # admitted twice terminal twice
    (lambda c: c + [_r(9, "engine", "done", 0)], "after terminal"),
    # lifecycle continues past a cancel
    (lambda c: c[:5] + [_r(9, "engine", "cancelled", 0),
                        _r(10, "engine", "token", 0)], "after terminal"),
    # first event is not queued (and the stream is NOT truncated:
    # seqs renumbered from 0 so the head can't be a ring eviction)
    (lambda c: [dict(r, seq=i, ts=float(i))
                for i, r in enumerate(c[1:])], "expected 'queued'"),
    # no terminal at all in a complete trace
    (lambda c: c[:5], "no terminal"),
    # E without a B
    (lambda c: [c[0], c[1], c[3], c[4], c[6]], "E without open B"),
    # B never closed
    (lambda c: [c[0], c[1], c[2], c[4], c[6]], "never closed"),
    # seq order broken
    (lambda c: [c[0], dict(c[1], seq=0)], "seq not increasing"),
    # time goes backwards
    (lambda c: [c[0], dict(c[1], ts=-1.0)], "ts went backwards"),
])
def test_checker_flags_lifecycle_violations(mutate, needle):
    viol = trace_check.check_records(mutate(_good_chain()))
    assert viol and any(needle in v for v in viol), viol


def test_checker_flags_pager_and_replica_violations():
    leak = [_r(0, "pager", "page_stats", total=8, free=2, mapped_refs=5,
               retained=3, inflight=0)]
    viol = trace_check.check_records(leak)
    assert any("leak" in v for v in viol), viol
    # same stats while requests are still in flight: fine
    busy = [dict(leak[0], attrs=dict(leak[0]["attrs"], inflight=2))]
    assert not trace_check.check_records(busy)
    bad_stats = [_r(0, "pager", "page_stats", total=8, free=9,
                    mapped_refs=2, retained=3, inflight=1)]
    assert len(trace_check.check_records(bad_stats)) == 2
    recover = [_r(0, "sched", "recover", src="q0", replica=1)]
    assert any("without" in v for v in
               trace_check.check_records(recover))
    ok = [_r(0, "sched", "drain", src="q0", replica=1),
          _r(1, "sched", "recover", src="q0", replica=1)]
    assert not trace_check.check_records(ok)


def test_checker_flags_silently_dropped_crash():
    recs = _good_chain()[:5] + [
        _r(9, "chaos", "injected", kind="replica_crash", inflight=1),
        _r(10, "engine", "done", 0),
    ]
    viol = trace_check.check_records(recs)
    assert any("no 'cancelled'" in v for v in viol), viol
    # the same crash followed by the cancel chain is well-formed
    recs[-1] = _r(10, "engine", "cancelled", 0)
    assert not trace_check.check_records(recs)


def test_checker_grandfathers_ring_truncation():
    """An export whose head was evicted (first seq > 0) must not flag
    requests whose beginnings fell off the buffer."""
    mid = [_r(50, "engine", "first_token", 3),
           _r(51, "engine", "token", 3),
           _r(52, "engine", "done", 3)]
    assert not trace_check.check_records(mid)
    # but the same stream starting at seq 0 is a violation
    fresh = [dict(r, seq=r["seq"] - 50, ts=float(r["seq"] - 50))
             for r in mid]
    assert trace_check.check_records(fresh)


# --------------------------------------------------------- SLO controller


def _seeded_sink():
    """Synthetic stage history: retrieve 0.10s for 2 queries (0.05/q),
    prefill chunk 0.02s, decode step 0.01s."""
    clock = {"t": 0.0}
    sink = TraceSink(clock=lambda: clock["t"])

    def span(comp, name, dur, rid=-1, **attrs):
        sink.emit(comp, name, rid, ph="B", **attrs)
        clock["t"] += dur
        sink.emit(comp, name, rid, ph="E")

    span("session", "retrieve", 0.10, n=2)
    span("engine", "prefill_chunk", 0.02, rid=0)
    span("engine", "decode_step", 0.01)
    return sink


def test_slo_stage_costs_and_estimate():
    c = SLOController(_seeded_sink())
    costs = c.stage_costs()
    assert np.isclose(costs["retrieve_per_query_s"], 0.05)
    assert np.isclose(costs["prefill_chunk_s"], 0.02)
    assert np.isclose(costs["decode_step_s"], 0.01)
    # 0.05 + 2*0.02 + 10*0.01
    assert np.isclose(c.estimate(10), 0.19)


def test_slo_ladder_degrades_before_shedding():
    c = SLOController(_seeded_sink())
    # plenty of budget: admit untouched
    p = c.plan(1.0, 16, 4, 4)
    assert p.action == "admit" and p.max_new == 16 and p.n_probe == 4
    # tight budget: degrade — clamp max_new to fit, halve chunk + probes
    p = c.plan(0.15, 16, 4, 4)
    assert p.action == "degrade"
    assert p.max_new == 6                 # (0.15 - 0.09) / 0.01
    assert p.retrieve_chunk == 2 and p.n_probe == 2
    # budget below even the floor (1 token, 0.10s): shed
    p = c.plan(0.05, 16, 4, 4)
    assert p.action == "shed"
    # floors are respected on the way down
    p = c.plan(0.101, 16, 1, 1)
    assert p.action == "degrade"
    assert p.max_new == 1 and p.retrieve_chunk == 1 and p.n_probe == 1


def test_slo_never_sheds_blind():
    """No samples in the window (or no budget at all): always admit."""
    c = SLOController(TraceSink())
    assert c.plan(1e-9, 16, 4, 4).action == "admit"
    c2 = SLOController(_seeded_sink())
    assert c2.plan(None, 16, 4, 4).action == "admit"


# ------------------------------------------------------- real engine runs


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run_workload(cfg, params, lens, cancel_at, seed):
    """Ragged prompts through a traced engine, cancelling a subset
    mid-flight; returns (engine, sink, rids)."""
    sink = TraceSink()
    ce = ContinuousEngine(cfg, params, slots=2, max_len=96, trace=sink)
    rng = np.random.default_rng(seed)
    rids = [ce.submit(rng.integers(4, 500, n).astype(np.int32),
                      max_new=2 + i % 3, greedy=bool(i % 2), seed=seed)
            for i, n in enumerate(lens)]
    for i in cancel_at:
        ce.cancel(rids[i % len(rids)])
    steps = 0
    while ce.pending:
        ce.step()
        steps += 1
        assert steps < 10_000
    return ce, sink, rids


def _assert_trace_oracle(ce, sink, rids):
    viol = trace_check.check_records(sink.records())
    assert viol == [], viol
    recs = [r.to_dict() for r in sink.records()]
    queued = {r["rid"] for r in recs
              if r["comp"] == "engine" and r["name"] == "queued"}
    assert queued == set(rids)
    # exactly one terminal per rid, and page accounting reconciles with
    # the live engine
    terms = [r for r in recs if r["comp"] == "engine"
             and r["name"] in ("done", "shed", "cancelled")]
    assert sorted(t["rid"] for t in terms) == sorted(rids)
    st = ce.page_stats()
    last = trace_check.last_page_stats(recs)
    assert last["mapped_refs"] == st.mapped_refs
    assert last["retained"] == st.retained
    assert last["inflight"] == 0


def test_engine_trace_is_clean_and_reconciles(dense_setup):
    cfg, params = dense_setup
    ce, sink, rids = _run_workload(cfg, params,
                                   lens=(16, 40, 9, 33, 24),
                                   cancel_at=(1, 3), seed=0)
    _assert_trace_oracle(ce, sink, rids)
    recs = [r.to_dict() for r in sink.records()]
    # cancelled requests really terminate as cancelled, and emit nothing
    # afterwards (checked structurally by the oracle; spot-check kinds)
    kinds = {r["rid"]: r["name"] for r in recs if r["comp"] == "engine"
             and r["name"] in ("done", "cancelled")}
    assert kinds[rids[1]] == "cancelled" and kinds[rids[3]] == "cancelled"
    assert kinds[rids[0]] == "done"
    # prefill/decode spans all closed, with positive durations
    assert all(d > 0 for d in sink.durations("engine", "prefill_chunk"))
    assert all(d > 0 for d in sink.durations("engine", "decode_step"))


def test_oversize_and_prefix_hit_appear_in_trace(dense_setup):
    cfg, params = dense_setup
    sink = TraceSink()
    ce = ContinuousEngine(cfg, params, slots=2, max_len=96, trace=sink)
    rng = np.random.default_rng(3)
    p = rng.integers(4, 500, 50).astype(np.int32)
    big = rng.integers(4, 500, ce.table_width * ce.page_size) \
        .astype(np.int32)
    ce.submit(p, max_new=4)
    shed_rid = ce.submit(big, max_new=8)
    while ce.pending:
        ce.step()
    ce.submit(p, max_new=4)               # second pass: prefix hit
    while ce.pending:
        ce.step()
    viol = trace_check.check_records(sink.records())
    assert viol == [], viol
    recs = [r.to_dict() for r in sink.records()]
    sheds = [r for r in recs if r["name"] == "shed"]
    assert [s["rid"] for s in sheds] == [shed_rid]
    assert sheds[0]["attrs"]["reason"] == "oversize"
    hits = [r for r in recs if r["comp"] == "pager"
            and r["name"] == "prefix_hit"]
    assert hits and hits[0]["attrs"]["matched"] >= 32


# deterministic fallback workloads mirror the property test's domain
_WORKLOADS = [
    ((8, 21, 34, 47), (0,), 1),
    ((60, 5, 5, 60, 30), (2, 4), 2),
    ((12,), (), 3),
]


@pytest.mark.parametrize("lens, cancel_at, seed", _WORKLOADS)
def test_workload_trace_invariants_deterministic(dense_setup, lens,
                                                 cancel_at, seed):
    cfg, params = dense_setup
    ce, sink, rids = _run_workload(cfg, params, lens, cancel_at, seed)
    _assert_trace_oracle(ce, sink, rids)


@settings(max_examples=5, deadline=None)
@given(st.lists(st.integers(4, 70), min_size=1, max_size=6),
       st.lists(st.integers(0, 5), max_size=2),
       st.integers(0, 100))
def test_workload_trace_invariants_property(lens, cancel_at, seed):
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    ce, sink, rids = _run_workload(cfg, params, lens, cancel_at, seed)
    _assert_trace_oracle(ce, sink, rids)


# --------------------------------------------------------- session + SLO


@pytest.fixture(scope="module")
def corpus():
    from repro.data.synthetic import make_qa_corpus
    return make_qa_corpus("squad", n_docs=50, n_questions=16, seed=0)


def _mobile(corpus):
    from repro.serving.embedder import HashEmbedder
    from repro.serving.rag import MobileRAG
    return MobileRAG(corpus.docs, HashEmbedder(dim=96), top_k=3)


def test_session_trace_full_lifecycle(corpus, tmp_path):
    """A traced RagSession run is checker-clean end to end (session +
    engine + pager components share one sink), and the export survives
    the CLI checker."""
    pipe = _mobile(corpus)
    sink = TraceSink()
    sess = pipe.session(max_new=4, slots=2, retrieve_chunk=2, trace=sink)
    out = sess.run([e.question for e in corpus.examples[:4]])
    assert all(a is not None for a in out)
    viol = trace_check.check_records(sink.records())
    assert viol == [], viol
    recs = [r.to_dict() for r in sink.records()]
    by_name = {}
    for r in recs:
        if r["comp"] == "session" and r["ph"] != "E":
            by_name.setdefault(r["name"], []).append(r["rid"])
    assert sorted(by_name["queued"]) == [0, 1, 2, 3]
    assert sorted(by_name["done"]) == [0, 1, 2, 3]
    assert set(by_name["retrieved"]) == set(by_name["condensed"])
    # retrieve spans carry the fused chunk size for per-query costing
    bs = [r for r in recs if r["name"] == "retrieve" and r["ph"] == "B"]
    assert bs and all(1 <= b["attrs"]["n"] <= 2 for b in bs)
    path = tmp_path / "session.jsonl"
    sink.export_jsonl(path)
    assert trace_check.main([str(path)]) == 0


def test_session_slo_sheds_after_learning_costs(corpus):
    """SLO admission learns stage costs from the first (blindly
    admitted) chunk, then sheds requests whose budget can't even cover
    the floor configuration — and the shed chains stay checker-clean."""
    pipe = _mobile(corpus)
    sink = TraceSink()
    sess = pipe.session(max_new=4, slots=2, retrieve_chunk=2,
                        trace=sink, slo_s=1e-6)
    first = [sess.submit(e.question) for e in corpus.examples[:2]]
    while sess.pending:
        sess.step()
    # no samples yet when the first chunk was planned: admitted blind
    assert all(sess.requests[r].state == "done" for r in first)
    assert sess.counters.shed_slo == 0
    later = [sess.submit(e.question) for e in corpus.examples[2:4]]
    while sess.pending or sess._events_out:
        sess.step()
    assert all(sess.requests[r].state == "shed" for r in later)
    assert sess.counters.shed_slo == 2
    viol = trace_check.check_records(sink.records())
    assert viol == [], viol
    shed = [r for r in sink.records()
            if r.comp == "session" and r.name == "shed"]
    assert {r.attrs["reason"] for r in shed} == {"slo"}


def test_session_slo_degrade_reduces_n_probe(corpus, monkeypatch):
    """The degrade rung really lowers the pipeline's probe width for the
    planned chunk and restores it afterwards — through a wrapper chain,
    exercising the `.inner` walk."""
    from repro.serving.faults import ChaosPipeline, FaultPlan
    pipe = _mobile(corpus)
    wrapped = ChaosPipeline(pipe, FaultPlan(seed=0))   # no faults @ rate 0
    sink = TraceSink()
    sess = wrapped.session(max_new=4, slots=2, retrieve_chunk=2,
                           trace=sink, slo_s=30.0)
    seen = []
    orig = type(pipe)._retrieve_batch

    def spy(self, qvs, k):
        seen.append(self.n_probe)
        return orig(self, qvs, k)

    monkeypatch.setattr(type(pipe), "_retrieve_batch", spy)
    # prime the cost window
    sess.run([corpus.examples[0].question])
    assert seen == [4]
    # force the planner into the degrade rung for the next chunk
    monkeypatch.setattr(
        sess._slo, "plan",
        lambda budget, mx, ch, np_, **kw: __import__(
            "repro.serving.trace", fromlist=["SLOPlan"]).SLOPlan(
                "degrade", mx, ch, 2, 0.0))
    sess.run([corpus.examples[1].question])
    assert seen[-1] == 2                  # degraded probe width applied
    assert pipe.n_probe == 4              # and restored after the chunk
    assert sess.counters.degraded_slo == 1
