"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from hypothesis_compat import given, settings, st

from repro.core.analytical import (memory_bytes, n_search_ops,
                                   search_energy_mj, search_latency_ms)
from repro.core.kmeans import kmeans
from repro.core.pq import PQ
from repro.data.tokenizer import HashTokenizer
from repro.train.optimizer import dequantize_rows, quantize_rows


@settings(max_examples=20, deadline=None)
@given(st.integers(10, 200), st.integers(2, 8))
def test_kmeans_assign_is_argmin(n, k):
    rng = np.random.default_rng(n * k)
    x = rng.normal(size=(n, 8)).astype(np.float32)
    cent, assign = kmeans(x, k, iters=3, use_pallas=False)
    d = ((x[:, None, :] - cent[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(assign, d.argmin(1))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64))
def test_tokenizer_stable_and_in_range(seed):
    rng = np.random.default_rng(seed)
    words = ["w%d" % rng.integers(0, 1000) for _ in range(30)]
    text = " ".join(words)
    tok = HashTokenizer(5000)
    ids = tok.encode(text)
    assert ids == tok.encode(text)          # deterministic
    assert all(4 <= i < 5000 for i in ids)  # reserved ids never produced
    assert len(ids) == len(words)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 3, 4, 6]))
def test_pq_roundtrip_beats_random(m):
    rng = np.random.default_rng(m)
    x = rng.normal(size=(400, 24)).astype(np.float32)
    pq = PQ(24, m=m).train(x, iters=4)
    recon = pq.decode(pq.encode(x))
    err = np.mean((x - recon) ** 2)
    base = np.mean(x ** 2)
    assert err < base * 0.9


def test_pq_error_decreases_with_m():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 32)).astype(np.float32)
    errs = []
    for m in (2, 4, 8):
        pq = PQ(32, m=m).train(x, iters=4)
        errs.append(float(np.mean((x - pq.decode(pq.encode(x))) ** 2)))
    assert errs[0] > errs[1] > errs[2]


@settings(max_examples=30, deadline=None)
@given(st.integers(1000, 10_000_000), st.integers(16, 1024))
def test_analytical_memory_ordering(N, d):
    """Paper Fig. 6 ordering: disk-based variants use (much) less RAM than
    in-RAM variants; EcoVector is within ~2x of IVF-DISK."""
    kw = dict(N=N, d=d, Nc=max(16, N // 256))
    assert memory_bytes("IVF-DISK", **kw) < memory_bytes("IVF", **kw)
    assert memory_bytes("EcoVector", **kw) < memory_bytes("HNSW", **kw)
    assert memory_bytes("EcoVector", **kw) < 3 * memory_bytes("IVF-DISK",
                                                              **kw)


@settings(max_examples=30, deadline=None)
@given(st.integers(150_000, 5_000_000))
def test_analytical_ecovector_fewest_ops(N):
    """Table 2: EcoVector's distance-op count beats IVF variants at scale
    (the paper's regime; at tiny N exhaustive IVF probing is cheaper)."""
    kw = dict(N=N, Nc=max(64, N // 256), n_probe=8)
    assert n_search_ops("EcoVector", **kw) < n_search_ops("IVF", **kw)
    assert n_search_ops("EcoVector", **kw) < n_search_ops("IVF-DISK", **kw)


@settings(max_examples=20, deadline=None)
@given(st.integers(100_000, 2_000_000), st.integers(2, 16))
def test_analytical_energy_positive_and_monotone_in_probes(N, n_probe):
    kw = dict(N=N, d=128, Nc=1024)
    e1 = search_energy_mj("EcoVector", n_probe=n_probe, **kw)
    e2 = search_energy_mj("EcoVector", n_probe=n_probe + 1, **kw)
    assert 0 < e1 < e2


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 512))
def test_int8_moment_quantisation_bound(rows, cols):
    rng = np.random.default_rng(rows * 1000 + cols)
    x = rng.normal(size=(rows, cols)).astype(np.float32) * \
        rng.uniform(0.01, 10)
    qt = quantize_rows(jnp.asarray(x))
    x2 = np.asarray(dequantize_rows(qt))
    bound = np.abs(x).max(axis=1, keepdims=True) / 127 + 1e-7
    assert np.all(np.abs(x - x2) <= bound)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(8, 32))
def test_topk_merge_invariant_ecoscan(nprobe, cap):
    """ecoscan's running merge == global top-k over all probed clusters."""
    from repro.kernels import ref
    from repro.kernels.ecoscan import ecoscan
    rng = np.random.default_rng(nprobe * cap)
    NC, d, K = nprobe + 2, 16, 5
    q = jnp.asarray(rng.normal(size=(2, d)), jnp.float32)
    data = jnp.asarray(rng.normal(size=(NC, cap, d)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, cap + 1, NC), jnp.int32)
    probes = jnp.stack([jnp.asarray(rng.permutation(NC)[:nprobe])
                        for _ in range(2)]).astype(jnp.int32)
    dk, ik = ecoscan(q, data, lens, probes, k=K)
    dr, ir = ref.ecoscan(q, data, lens, probes, K)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=1e-4,
                               atol=1e-4)
