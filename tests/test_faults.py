"""Serving-under-fire tests: deadlines, backpressure, replica probation,
degradation ladders, and the deterministic fault-injection harness.

The chaos soak (`-k chaos`) is the acceptance gate: under a seeded
FaultPlan mixing replica crashes, slot stalls and slow steps over 32
requests on 3 real ContinuousEngine replicas, every request must end in
exactly one terminal state (Completion or Shed — nothing stuck, nothing
lost, nothing double-counted), and a drained replica must demonstrably
return to service through the probation canary path.
"""
import time
from collections import deque
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro.dist.fault import HealthConfig, HealthTracker
from repro.serving.faults import (ChaosEngine, ChaosPipeline, FaultPlan,
                                  InjectedFault, wrap_replicas)
from repro.serving.scheduler import Scheduler, SlotScheduler


# --------------------------------------------------------------- fakes

class FakeEngine:
    """Engine-like (submit/step/available_slots/cancel) with scripted
    behaviour: emits one token per request per step, `fail_steps` raise,
    `stalled` returns no events, `step_delay` slows real time down so
    wall-clock probation cooldowns can elapse mid-drain."""

    def __init__(self, slots=2, step_delay=0.0):
        self.slots_n = slots
        self.step_delay = step_delay
        self.fail_steps = set()
        self.stalled = False
        self.step_idx = 0
        self.queue = deque()            # (rid, max_new)
        self.running = {}               # rid -> [max_new, tokens]
        self._next = 0

    def submit(self, prompt, max_new=32, **kw):
        rid = self._next
        self._next += 1
        self.queue.append((rid, max_new))
        return rid

    def available_slots(self):
        return self.slots_n - len(self.running) - len(self.queue)

    def cancel(self, rid):
        if rid in self.running:
            del self.running[rid]
            return True
        n = len(self.queue)
        self.queue = deque(x for x in self.queue if x[0] != rid)
        return len(self.queue) != n

    def step(self):
        i = self.step_idx
        self.step_idx += 1
        if self.step_delay:
            time.sleep(self.step_delay)
        if i in self.fail_steps:
            raise RuntimeError(f"scripted step failure @ {i}")
        if self.stalled:
            return []
        events = []
        while self.queue and len(self.running) < self.slots_n:
            rid, mx = self.queue.popleft()
            self.running[rid] = [mx, []]
        for rid in list(self.running):
            mx, toks = self.running[rid]
            toks.append(7)
            if len(toks) >= mx:
                del self.running[rid]
                events.append(SimpleNamespace(
                    rid=rid, kind="done",
                    result=SimpleNamespace(tokens=list(toks))))
            else:
                events.append(SimpleNamespace(rid=rid, kind="token",
                                              token=7))
        return events


# -------------------------------------------------------- HealthTracker

def test_health_tracker_lifecycle():
    clock = {"t": 0.0}
    t = HealthTracker(HealthConfig(max_strikes=2, cooldown_s=1.0,
                                   cooldown_backoff=2.0, max_probes=2),
                      clock=lambda: clock["t"])
    assert t.healthy and t.state == HealthTracker.HEALTHY
    # strikes decay on success: a lone transient never drains
    assert t.record_failure() is False and t.strikes == 1
    assert t.record_success() is False and t.strikes == 0
    # two consecutive failures drain
    t.record_failure()
    assert t.record_failure() is True
    assert t.state == HealthTracker.DRAINED and t.drains == 1
    # cooldown gates the probe
    assert not t.probe_due()
    clock["t"] = 1.0
    assert t.probe_due()
    t.begin_probe()
    assert t.state == HealthTracker.PROBING and t.probes == 1
    # a failed probe re-drains with exponential backoff
    assert t.record_failure() is True
    assert t.state == HealthTracker.DRAINED
    clock["t"] = 2.9
    assert not t.probe_due()            # next probe at 1.0 + 2.0
    clock["t"] = 3.0
    assert t.probe_due()
    t.begin_probe()
    # a successful probe recovers and resets strikes + probe budget
    assert t.record_success() is True
    assert t.healthy and t.strikes == 0 and t.recoveries == 1
    assert t.probes == 0                # fresh budget after recovery


def test_health_tracker_probe_budget_exhausts():
    clock = {"t": 0.0}
    t = HealthTracker(HealthConfig(max_strikes=1, cooldown_s=0.1,
                                   max_probes=1),
                      clock=lambda: clock["t"])
    t.record_failure()
    clock["t"] = 1.0
    t.begin_probe()
    t.record_failure()
    assert t.exhausted and not t.probe_due()


# ------------------------------------------------------------ FaultPlan

def test_fault_plan_deterministic_and_independent():
    rates = {"replica_crash": 0.1, "slot_stall": 0.1, "slow_step": 0.1,
             "retrieval_error": 0.1}
    a = FaultPlan(seed=7, horizon=300, rates=rates)
    b = FaultPlan(seed=7, horizon=300, rates=rates)
    # same (seed, replica) -> identical schedule, replayable
    assert a.replica(2) == b.replica(2)
    assert a.retrieval_errors() == b.retrieval_errors()
    # replicas draw independent sub-schedules from the same seed
    assert a.replica(0) != a.replica(1)
    # a different seed reshuffles everything
    assert FaultPlan(seed=8, horizon=300, rates=rates).replica(0) \
        != a.replica(0)
    with pytest.raises(ValueError):
        FaultPlan(rates={"bogus_kind": 1.0})


def test_chaos_engine_injects_scheduled_faults():
    plan = FaultPlan(seed=1, horizon=60,
                     rates={"replica_crash": 0.15, "slot_stall": 0.1,
                            "slow_step": 0.1},
                     stall_steps=3, slow_s=0.0)
    faults = plan.replica(0)
    assert faults.crashes, "seed must schedule at least one crash"
    first_crash = min(faults.crashes)
    ce = ChaosEngine(FakeEngine(slots=2), plan, 0)
    ce.submit(np.arange(4), max_new=100)
    for _ in range(first_crash):
        ce.step()                        # stalls return [], slows sleep
    with pytest.raises(InjectedFault):
        ce.step()
    assert ce.injected["replica_crash"] == 1
    # stall windows really suppress events
    stall_start = min(faults.stalls - faults.crashes, default=None)
    if stall_start is not None and stall_start < first_crash:
        assert ce.injected["slot_stall"] >= 1


def test_chaos_pipeline_raises_by_call_index():
    plan = FaultPlan(seed=3, horizon=40, rates={"retrieval_error": 0.3})
    inner = SimpleNamespace(answer_batch=lambda qs, **kw: list(qs),
                            name="stub")
    cp = ChaosPipeline(inner, plan)
    errs = plan.retrieval_errors()
    assert errs, "seed must schedule at least one retrieval error"
    for i in range(40):
        if i in errs:
            with pytest.raises(InjectedFault):
                cp.answer_batch(["q"])
        else:
            assert cp.answer_batch(["q"]) == ["q"]
    assert cp.injected == len([e for e in errs if e < 40])
    assert cp.name == "stub"            # everything else delegates


# ------------------------------------------- SlotScheduler, fake engines

def test_queue_bound_degrades_then_sheds():
    s = SlotScheduler([FakeEngine(slots=1)], max_queue=2,
                      overflow="degrade")
    rids = [s.submit(np.arange(4), max_new=8) for _ in range(6)]
    # 2 admitted whole, 2 degraded (halved budget), 2 shed past 2x bound
    assert s.counters.degraded == 2 and s.counters.shed_queue == 2
    assert [sh.reason for sh in s.shed] == ["queue_full"] * 2
    done = s.run()
    toks = {c.rid: c.tokens for c in done}
    assert set(toks) == set(rids[:4])
    assert len(toks[rids[0]]) == 8 and len(toks[rids[2]]) == 4
    # terminal partition: completions + sheds cover every submitted rid
    assert {c.rid for c in done} | {sh.rid for sh in s.shed} == set(rids)

    r = SlotScheduler([FakeEngine(slots=1)], max_queue=1,
                      overflow="reject")
    for _ in range(3):
        r.submit(np.arange(4), max_new=2)
    assert r.counters.shed_queue == 2 and r.counters.degraded == 0


def test_rehedge_after_repeated_stall():
    """A request whose hedge target ALSO stalls hedges again: the stall
    budget re-arms after every hedge (the latched-flag fix), and the
    Completion still reports hedged=True."""
    stall0, stall1 = FakeEngine(slots=4), FakeEngine(slots=3)
    stall0.stalled = stall1.stalled = True
    good = FakeEngine(slots=2)
    s = SlotScheduler([stall0, stall1, good], stall_s=0.03, max_hedges=2,
                      max_strikes=5)
    rid = s.submit(np.arange(6), max_new=2)
    done = s.run()
    assert [c.rid for c in done] == [rid]
    assert done[0].hedged and done[0].replica == 2
    assert s.counters.hedges == 2       # stall0 -> stall1 -> good
    assert s.counters.strikes >= 2      # both stalled replicas struck


def test_drain_requeue_probation_recovery():
    """Satellite (c): a replica that raises twice drains with its
    in-flight work re-queued (and served elsewhere), then re-enters
    service by completing one canary after the cooldown."""
    flaky = FakeEngine(slots=2)
    flaky.fail_steps = {0, 1}
    good = FakeEngine(slots=1, step_delay=0.004)   # slow: backlog persists
    s = SlotScheduler([flaky, good], max_strikes=2,
                      probe_cooldown_s=0.03, stall_s=10.0)
    rids = [s.submit(np.arange(5), max_new=3) for _ in range(8)]
    done = s.run()
    assert {c.rid for c in done} == set(rids) and not s.shed
    h = s.state[0]
    assert h.tracker.drains == 1 and s.counters.drains == 1
    assert s.counters.probes >= 1
    assert h.tracker.recoveries == 1 and s.counters.recoveries == 1
    assert h.healthy                    # back in service
    assert h.served >= 1                # canary (at least) ran on it
    assert s.counters.strikes >= 2


# ------------------------------------------- real-engine fixtures/tests

@pytest.fixture(scope="module")
def base_engine():
    import jax
    from repro.configs import get_reduced
    from repro.models import model
    from repro.serving.engine import ContinuousEngine
    cfg = get_reduced("qwen25_0_5b")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    ce = ContinuousEngine(cfg, params, slots=2, max_len=128)
    ce.warmup()
    return ce


@pytest.fixture
def engine(base_engine):
    e = base_engine.clone()
    e.warmup()
    return e


def test_engine_cancel_frees_slot(engine):
    p = np.arange(4, 20, dtype=np.int32)
    r1 = engine.submit(p, max_new=50)
    r2 = engine.submit(p + 1, max_new=3)
    r3 = engine.submit(p + 2, max_new=3)          # over capacity: queued
    engine.step()                                  # admit r1, r2
    assert engine.free_slots() == 0
    assert engine.cancel(r1)
    assert engine.free_slots() == 1                # slot freed immediately
    assert not engine.cancel(r1)                   # already gone
    assert engine.cancel(r3)                       # queued cancel works too
    seen = set()
    for _ in range(200):
        if not engine.pending:
            break
        for ev in engine.step():
            seen.add(ev.rid)
            if ev.kind == "done":
                assert ev.rid == r2
    assert engine.pending == 0
    assert r1 not in seen and r3 not in seen       # no events after cancel
    assert engine.cancelled == 2


def test_deadline_expiry_sheds_and_frees_slot(engine):
    sched = SlotScheduler([engine])
    p = np.arange(4, 24, dtype=np.int32)
    r_dead = sched.submit(p, max_new=64, deadline_s=0.03)
    r_ok = sched.submit(p + 1, max_new=3)
    sched._admit()                       # both placed before expiry
    assert engine.pending == 2
    time.sleep(0.05)
    done = sched.run()
    assert [c.rid for c in done] == [r_ok]
    assert [(sh.rid, sh.reason) for sh in sched.shed] \
        == [(r_dead, "deadline")]
    assert sched.counters.shed_deadline == 1
    assert engine.pending == 0 and engine.free_slots() == engine.slots
    assert engine.cancelled >= 1


def test_chaos_soak_terminal_partition_and_recovery(base_engine, tmp_path):
    """THE acceptance soak: seeded chaos over 32 requests on 3 replicas.
    Every request ends in exactly one terminal state; drained replicas
    come back through probation once the plan's horizon passes. The
    whole run records into one shared TraceSink whose export must pass
    tools/trace_check.py — every injected fault surfaces as a
    well-formed cancelled/requeue/shed span chain, never a silent drop."""
    from repro.serving.trace import TraceSink
    sink = TraceSink()
    engines = [base_engine.clone() for _ in range(3)]
    for e in engines:
        e.warmup()
        e.trace = sink
    plan = FaultPlan(seed=0, horizon=80,
                     rates={"replica_crash": 0.06, "slot_stall": 0.03,
                            "slow_step": 0.05},
                     stall_steps=30, slow_s=0.002)
    wrapped = wrap_replicas(engines, plan)
    sched = SlotScheduler(wrapped, stall_s=0.5, probe_cooldown_s=0.05,
                          max_strikes=2, max_hedges=3, max_probes=None,
                          deadline_s=30.0, trace=sink)
    rng = np.random.default_rng(1)
    rids = []
    for i in range(32):
        prompt = rng.integers(4, 500,
                              size=int(rng.integers(8, 40))).astype(np.int32)
        tight = i % 8 == 7               # a few impossible deadlines
        rids.append(sched.submit(prompt, int(rng.integers(2, 6)),
                                 deadline_s=0.002 if tight else 30.0))
    done = sched.run()

    done_rids = [c.rid for c in done]
    shed_rids = [sh.rid for sh in sched.shed]
    # exactly one terminal state per request: no loss, no double-count
    assert len(set(done_rids)) == len(done_rids)
    assert len(set(shed_rids)) == len(shed_rids)
    assert set(done_rids).isdisjoint(shed_rids)
    assert set(done_rids) | set(shed_rids) == set(rids)
    c = sched.counters
    assert c.completed + c.shed_deadline + c.shed_queue == len(rids)
    # the chaos actually fired, and it drained at least one replica
    assert sum(w.injected["replica_crash"] for w in wrapped) >= 1
    assert c.drains >= 1
    # nothing stranded engine-side either — and the chaos-injected
    # cancels (crash requeues, hedges, deadline expiries) all walked the
    # pager's decref path: with every request terminal, no slot holds
    # page references, only prefix-cache retentions remain
    for w in wrapped:
        assert w.inner.pending == 0
        st = w.inner.page_stats()
        assert st.mapped_refs == st.retained, st

    # calm tail: drive small batches until a drained replica recovers
    # (past the horizon probes face no chaos, so this converges fast)
    extra = []
    for _ in range(20):
        if sched.counters.recoveries >= 1:
            break
        batch = [sched.submit(
            rng.integers(4, 500, size=12).astype(np.int32), 3)
            for _ in range(4)]
        extra.extend(batch)
        done2 = sched.run()
        assert {c2.rid for c2 in done2} == set(batch)
        time.sleep(0.05)                 # let probe cooldowns elapse
    assert sched.counters.recoveries >= 1
    assert sched.counters.probes >= 1
    # full-drain leak check: dropping the prefix cache returns every
    # page to the free list on every replica
    for w in wrapped:
        w.inner.drop_prefix_cache()
        st = w.inner.page_stats()
        assert st.free == st.total and st.mapped_refs == 0, st

    # ---- trace invariants over the whole soak: export the shared sink
    # and run the standalone checker exactly as the nightly CI does
    trace_check = _load_trace_check()
    path = tmp_path / "chaos_soak_trace.jsonl"
    n = sink.export_jsonl(path)
    assert n == len(sink)
    recs = trace_check.load_jsonl(path)
    violations = trace_check.check_records(recs, complete=True)
    assert violations == [], "\n".join(violations)
    # the injected faults are themselves in the trace...
    injected = [r for r in recs if r["comp"] == "chaos"]
    assert {r["attrs"]["kind"] for r in injected} >= {"replica_crash"}
    assert len(injected) == sum(sum(w.injected.values()) for w in wrapped)
    # ...and every crash with requests in flight produced cancelled
    # chains (the checker enforces this; spot-check one exists)
    crashes = [r for r in injected
               if r["attrs"]["kind"] == "replica_crash"
               and r["attrs"]["inflight"] > 0]
    if crashes:
        cancels = [r for r in recs if r["comp"] == "engine"
                   and r["name"] == "cancelled"]
        assert cancels
    # scheduler-side: every submitted rid reached a sched terminal
    sched_term = {r["rid"] for r in recs if r["comp"] == "sched"
                  and r["name"] in ("done", "shed") and r["rid"] >= 0}
    assert sched_term == set(rids) | set(extra)
    # replica lifecycle showed up: drain before the recover we waited on
    names = [r["name"] for r in recs if r["comp"] == "sched"
             and r["rid"] < 0]
    assert "drain" in names and "recover" in names


def _load_trace_check():
    """Import tools/trace_check.py (not a package) the way CI runs it."""
    import importlib.util
    p = Path(__file__).resolve().parent.parent / "tools" / "trace_check.py"
    spec = importlib.util.spec_from_file_location("trace_check", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------ RagSession fire

@pytest.fixture(scope="module")
def corpus():
    from repro.data.synthetic import make_qa_corpus
    return make_qa_corpus("squad", n_docs=60, n_questions=24, seed=0)


def _mobile(corpus, embed=None):
    from repro.serving.embedder import HashEmbedder
    from repro.serving.rag import MobileRAG
    return MobileRAG(corpus.docs, embed or HashEmbedder(dim=96), top_k=3)


class PoisonEmbedder:
    """Raises on any text containing the poison marker — a scripted
    embedder failure that hits exactly one query."""

    def __init__(self, inner):
        self.inner = inner

    def __call__(self, texts):
        if any("POISON" in t for t in texts):
            raise RuntimeError("embedder down for this query")
        return self.inner(texts)


def test_session_embedder_failure_isolated(corpus):
    """Satellite (f): one query's embedder failure emits a terminal
    "failed" event for THAT rid; the rest of the chunk completes."""
    from repro.serving.embedder import HashEmbedder
    pipe = _mobile(corpus, PoisonEmbedder(HashEmbedder(dim=96)))
    sess = pipe.session(max_new=4, slots=2, retrieve_chunk=4)
    queries = [e.question for e in corpus.examples[:3]] + ["POISON query?"]
    rids = [sess.submit(q) for q in queries]
    events = []
    while sess.pending or sess._events_out:
        events.extend(sess.step())
    failed = [ev for ev in events if ev.kind == "failed"]
    assert [ev.req_id for ev in failed] == [rids[3]]
    assert sess.requests[rids[3]].state == "failed"
    for r in rids[:3]:
        assert sess.requests[r].state == "done"
        assert sess.requests[r].answer.gen_tokens
    assert sess.counters.failed == 1 and sess.counters.completed == 3
    assert sess.counters.retrieval_retries >= 1   # isolated retry ran


def test_session_overload_degrades_then_sheds(corpus):
    pipe = _mobile(corpus)
    sess = pipe.session(max_new=4, slots=2, retrieve_chunk=2,
                        max_pending=4)
    queries = [e.question for e in corpus.examples[:6]]
    rids = [sess.submit(q) for q in queries]
    # 2 admitted whole, 2 degraded past half the bound, 2 shed at it
    assert sess.counters.degraded == 2
    assert sess.counters.shed_overload == 2
    assert sess.requests[rids[2]].max_new == 2    # halved budget
    events = []
    while sess.pending or sess._events_out:
        events.extend(sess.step())
    shed = [ev for ev in events if ev.kind == "shed"]
    assert {ev.req_id for ev in shed} == {rids[4], rids[5]}
    assert all(ev.payload == "overload" for ev in shed)
    for r in rids[:4]:
        assert sess.requests[r].state == "done"
    # terminal partition on the session too
    states = [sess.requests[r].state for r in rids]
    assert states.count("done") + states.count("shed") == 6


def test_session_deadline_cancels_decoding(corpus):
    pipe = _mobile(corpus)
    sess = pipe.session(max_new=48, slots=2)
    rid = sess.submit(corpus.examples[0].question, deadline_s=0.05)
    sess.step()                          # retrieval + first engine step
    assert sess.requests[rid].state == "decoding"
    time.sleep(0.06)
    events = sess.step()                 # expired mid-decode
    assert any(ev.kind == "shed" and ev.req_id == rid and
               ev.payload == "deadline" for ev in events)
    assert sess.counters.shed_deadline == 1
    assert sess.engine.pending == 0      # slot freed via cancel
    # the freed slot serves the next request normally
    out = sess.run([corpus.examples[1].question])
    assert out[0] is not None and out[0].gen_tokens


def test_chaos_pipeline_faults_surface_as_failed_span_chains(corpus,
                                                            tmp_path):
    """Injected retrieval errors must appear in the trace as chaos
    records AND terminate the hit rids with well-formed 'failed' chains
    that tools/trace_check.py accepts — never a stranded request.

    Seed 3 schedules errors at calls {0, 2, 5, 9, 10}: the fused batch
    (call 0) fails, per-query retries run as calls 1-4, and call 2
    (query index 1) fails again -> exactly one 'failed' rid."""
    from repro.serving.session import RagSession
    from repro.serving.trace import TraceSink
    sink = TraceSink()
    plan = FaultPlan(seed=3, horizon=12, rates={"retrieval_error": 0.5})
    cp = ChaosPipeline(_mobile(corpus), plan, trace=sink)
    sess = RagSession(cp, max_new=4, slots=2, retrieve_chunk=4,
                      trace=sink)
    queries = [e.question for e in corpus.examples[:4]]
    rids = [sess.submit(q) for q in queries]
    while sess.pending or sess._events_out:
        sess.step()
    assert cp.injected == 2
    assert sess.counters.failed == 1 and sess.counters.completed == 3
    assert sess.requests[rids[1]].state == "failed"

    path = tmp_path / "chaos_session_trace.jsonl"
    sink.export_jsonl(path)
    trace_check = _load_trace_check()
    recs = trace_check.load_jsonl(path)
    violations = trace_check.check_records(recs, complete=True)
    assert violations == [], "\n".join(violations)
    injected = [r for r in recs if r["comp"] == "chaos"]
    assert len(injected) == 2
    assert all(r["attrs"]["kind"] == "retrieval_error" for r in injected)
    # session-side terminal partition, straight from the trace
    terms = {r["rid"]: r["name"] for r in recs if r["comp"] == "session"
             and r["name"] in ("done", "failed", "shed")}
    assert terms == {rids[0]: "done", rids[1]: "failed",
                     rids[2]: "done", rids[3]: "done"}


# ----------------------------------------------- pipeline degradation

def test_mobilerag_scr_fallback(corpus, monkeypatch):
    pipe = _mobile(corpus)
    q = corpus.examples[0].question

    def boom(*a, **kw):
        raise RuntimeError("scr stage down")

    monkeypatch.setattr("repro.serving.rag.apply_scr_batch", boom)
    ans = pipe.answer(q)                 # single-query path
    assert pipe.scr_fallbacks == 1
    assert ans.scr is None and ans.prompt.startswith("Context:")
    assert len(ans.doc_ids) == 3
    outs = pipe.answer_batch([q, corpus.examples[1].question])
    assert pipe.scr_fallbacks == 2       # batch path counts once
    assert all(o.scr is None and o.prompt for o in outs)


def test_retrieval_fallback_reuses_last_good(corpus, monkeypatch):
    pipe = _mobile(corpus)
    q = corpus.examples[0].question
    good = pipe.answer(q)                # primes _last_good_ids
    monkeypatch.setattr(pipe.index, "search",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            RuntimeError("index down")))
    degraded = pipe.answer(q)
    assert pipe.retrieval_fallbacks == 1
    assert set(degraded.doc_ids) == set(good.doc_ids)

    cold = _mobile(corpus)               # no prior retrieval at all
    monkeypatch.setattr(cold.index, "search",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            RuntimeError("index down")))
    ans = cold.answer(q)
    assert cold.retrieval_fallbacks == 1
    assert set(ans.doc_ids) <= set(range(cold.top_k))  # corpus head


# -------------------------------------------------- legacy wave path

def test_legacy_scheduler_cold_start_exempt_from_deadline():
    """Satellite (b): a replica's FIRST successful dispatch pays jit
    compile time and must not be struck for overrunning the deadline —
    but a WARMED replica overrunning still is."""
    calls = []

    def cold_then_fast(prompts, max_new):
        calls.append(len(prompts))
        if len(calls) == 1:
            time.sleep(0.08)             # "jit compile" on first dispatch
        return [[1, 2] for _ in prompts]

    s = Scheduler([cold_then_fast], max_wave=4, deadline_s=0.02)
    for i in range(2):
        s.submit(np.arange(5))
    done = s.run()                       # one wave: slow but exempt
    assert len(done) == 2 and not any(c.hedged for c in done)
    assert s.state[0].strikes == 0 and s.state[0].healthy
    assert s.state[0].warmed

    def always_slow(prompts, max_new):
        time.sleep(0.05)
        return [[1] for _ in prompts]

    def fast(prompts, max_new):
        return [[1] for _ in prompts]

    s2 = Scheduler([always_slow, fast], max_wave=4, deadline_s=0.02,
                   max_strikes=1)
    for n in (5, 6, 7):                  # distinct lengths: three waves
        s2.submit(np.arange(n))
    done2 = s2.run()
    assert len(done2) == 3
    # wave 1 warmed replica 0 (exempt); wave 3 hits it warm -> strike,
    # drain at max_strikes=1, hedged re-dispatch to the fast replica
    assert not s2.state[0].healthy and s2.state[0].strikes == 1
    assert any(c.hedged and c.replica == 1 for c in done2)
