"""Corpus-resident SCR window index: edge cases, dirty-block repack
protocol, and parity between the batched device path and per-query
`apply_scr` / per-query `answer`."""
import numpy as np
import pytest

from repro.core.scr import (SCRConfig, apply_scr, apply_scr_batch,
                            segment_best_windows)
from repro.core.window_index import WindowIndex
from repro.serving.embedder import HashEmbedder

DOCS = [
    ("Volcanoes are studied by geologists. "
     "Their eruptions follow magma pressure. "
     "Monitoring stations track seismic activity. "
     "Lava flows reshape the landscape."),
    ("The Tiramisu dessert originated in Italy. "
     "An interesting historical note about Tiramisu follows. "
     "Recipe of the Tiramisu includes cheese and coffee. "
     "The price of a single slice of Tiramisu can vary. "
     "Many cafes now offer Tiramisu for pick-up."),
    "One single sentence about astronomy.",
    "",
    ("Quantum computers use qubits. "
     "Error correction is the central challenge."),
]


@pytest.fixture(scope="module")
def embed():
    return HashEmbedder(dim=64).fit([d for d in DOCS if d])


@pytest.fixture()
def widx(embed):
    return WindowIndex(embed, SCRConfig(3, 2, 1)).build(DOCS)


def test_build_precomputes_all_windows(widx):
    assert widx.stats.full_builds == 1
    assert widx.stats.embed_calls == 1
    data, lens = widx.pack()
    assert data.shape[0] == len(DOCS)
    assert lens[3] == 0                       # empty doc: no windows
    assert lens[2] == 1                       # single sentence: one window
    assert all(lens[i] == len(widx.spans[i]) for i in range(len(DOCS)))


@pytest.mark.parametrize("doc_ids", [
    [0, 1], [1, 0, 4], [2], [3], [3, 2], [0, 1, 2, 3, 4], [],
])
def test_batch_matches_per_query_apply_scr(embed, widx, doc_ids):
    """apply_scr_batch over the index == apply_scr re-embedding per query,
    including windowless and empty docs."""
    q = "Show me the dessert recipe from recent downloads."
    ref = apply_scr(q, [DOCS[i] for i in doc_ids], embed, widx.cfg)
    out = apply_scr_batch([q], [doc_ids], widx, embed)[0]
    assert out.order == ref.order
    assert out.spans == ref.spans
    assert out.texts == ref.texts
    assert out.tokens_before == ref.tokens_before
    assert out.tokens_after == ref.tokens_after
    np.testing.assert_allclose(out.scores, ref.scores, rtol=1e-5, atol=1e-5)


def test_batch_multiple_queries(embed, widx):
    qs = ["dessert recipe?", "volcano eruptions", "qubits"]
    ids = [[0, 1], [0, 1, 4], [4, 2]]
    outs = apply_scr_batch(qs, ids, widx, embed)
    for q, row, out in zip(qs, ids, outs):
        ref = apply_scr(q, [DOCS[i] for i in row], embed, widx.cfg)
        assert out.order == ref.order and out.spans == ref.spans


def test_all_windowless_corpus(embed):
    w = WindowIndex(embed, SCRConfig(3, 2, 1)).build(["", ""])
    out = apply_scr_batch(["anything"], [[0, 1]], w, embed)[0]
    assert out.texts == ["", ""]
    assert out.scores == [0.0, 0.0]
    assert out.tokens_before == 0 and out.tokens_after == 0


def test_update_marks_only_owning_block_dirty(embed, widx):
    repacks0 = widx.stats.block_repacks
    widx.update(0, "Completely new text about sailing. Boats need wind.")
    assert widx._dirty == {0}
    data, lens = widx.pack()
    assert widx.stats.block_repacks == repacks0 + 1
    assert widx.stats.full_builds == 1            # no rebuild
    assert lens[0] == len(widx.spans[0])
    # the refreshed block answers for the new content
    out = apply_scr_batch(["wind and boats sailing"], [[0, 1]], widx,
                          embed)[0]
    assert "sailing" in " ".join(out.texts) or "wind" in " ".join(out.texts)


def test_update_invalidates_stale_windows(embed, widx):
    """After an update, a query matching the OLD content must no longer
    select it (the dirty block was re-embedded, not served stale)."""
    q = "Show me the dessert recipe."
    before = apply_scr_batch([q], [[1, 0]], widx, embed)[0]
    assert any("Recipe of the Tiramisu" in t for t in before.texts)
    widx.update(1, "Weather patterns shift with ocean currents.")
    after = apply_scr_batch([q], [[1, 0]], widx, embed)[0]
    assert not any("Tiramisu" in t for t in after.texts)


def test_add_and_remove_docs(embed, widx):
    di = widx.add("Fresh document about gardening. Tomatoes need sun. "
                  "Water them daily.")
    assert di == len(DOCS)
    out = apply_scr_batch(["gardening tomatoes"], [[di]], widx, embed)[0]
    assert "Tomatoes" in " ".join(out.texts)
    widx.remove(di)
    _, lens = widx.pack()
    assert lens[di] == 0


def test_capw_grows_geometrically(embed):
    w = WindowIndex(embed, SCRConfig(1, 0, 0)).build(["Short. Doc."])
    capw0 = w.pack()[0].shape[1]
    long_doc = " ".join(f"Sentence number {i} talks about topic."
                        for i in range(capw0 * 3))
    w.update(0, long_doc)
    data, lens = w.pack()
    assert w.stats.grows >= 1
    assert data.shape[1] >= capw0 * 3
    assert lens[0] == capw0 * 3


def test_row_table_grows_on_add(embed):
    w = WindowIndex(embed, SCRConfig(3, 2, 1)).build(["One doc. Two "
                                                      "sentences."])
    nd0 = w.pack()[0].shape[0]
    for i in range(nd0 + 3):
        w.add(f"Additional doc {i}. It has sentences.")
    data, lens = w.pack()
    assert data.shape[0] >= nd0 + 4
    assert w.stats.full_builds == 1


def test_device_mirror_refreshes_dirty_blocks(embed, widx):
    d0, l0 = widx.device_arrays()
    widx.update(2, "Replacement text. With two sentences.")
    d1, l1 = widx.device_arrays()
    assert int(l1[2]) == len(widx.spans[2])
    assert not np.allclose(np.asarray(d0[2, 0]), np.asarray(d1[2, 0]))


def test_segment_best_windows_matches_scan():
    rng = np.random.default_rng(0)
    owners = np.sort(rng.integers(0, 7, 40))
    scores = rng.normal(size=40).astype(np.float32)
    scores[10] = scores[11] = scores.max() + 1.0   # tie inside one owner
    owners[10] = owners[11] = owners[10]
    best, counts = segment_best_windows(scores, owners, 9)
    for di in range(9):
        idx = [i for i, o in enumerate(owners) if o == di]
        assert counts[di] == len(idx)
        if idx:
            assert best[di] == max(idx, key=lambda i: scores[i])


def test_mobilerag_answer_batch_matches_answer():
    from repro.data.synthetic import make_qa_corpus
    from repro.serving.rag import MobileRAG
    corpus = make_qa_corpus("squad", n_docs=40, n_questions=6, seed=0)
    emb = HashEmbedder(dim=64).fit(corpus.docs)
    pipe = MobileRAG(corpus.docs, emb, top_k=3)
    qs = [e.question for e in corpus.examples[:6]]
    batch = pipe.answer_batch(qs)
    for q, b in zip(qs, batch):
        a = pipe.answer(q)
        assert a.prompt == b.prompt
        assert a.doc_ids == b.doc_ids
        assert a.scr.spans == b.scr.spans and a.scr.order == b.scr.order


def test_mobilerag_window_index_matches_legacy_path():
    from repro.data.synthetic import make_qa_corpus
    from repro.serving.rag import MobileRAG
    corpus = make_qa_corpus("hotpot", n_docs=40, n_questions=6, seed=1)
    emb = HashEmbedder(dim=64).fit(corpus.docs)
    new = MobileRAG(corpus.docs, emb, top_k=3)
    legacy = MobileRAG(corpus.docs, emb, top_k=3, use_window_index=False)
    for e in corpus.examples[:6]:
        a, b = new.answer(e.question), legacy.answer(e.question)
        assert a.prompt == b.prompt
        assert a.scr.spans == b.scr.spans and a.scr.order == b.scr.order
