"""Durable store unit tests: segment framing, tamper detection, WAL
replay semantics, generation journal atomicity, and crash/corruption
sweeps driven by the deterministic fs-op fault hooks."""
import json
import os
import pickle
import struct
import zlib

import numpy as np
import pytest

from repro.core import store, store_faults
from repro.core.store import (CorruptSegmentError, Journal, StoreError,
                              WriteAheadLog)


@pytest.fixture(autouse=True)
def _clean_hooks():
    """Every test starts and ends with no crash hook armed."""
    store.set_crash_hook(None)
    store.reset_fs_ops()
    yield
    store.set_crash_hook(None)


# ------------------------------------------------------------- segments

def test_segment_roundtrip(tmp_path):
    p = str(tmp_path / "a.seg")
    recs = [b"hello", b"", b"\x00" * 1024]
    store.write_segment(p, recs, {"x": 1}, kind="t")
    meta, out = store.read_segment(p, kind="t")
    assert out == recs
    assert meta["x"] == 1 and meta["kind"] == "t"
    assert not os.path.exists(p + ".tmp")


def test_segment_kind_mismatch(tmp_path):
    p = str(tmp_path / "a.seg")
    store.write_segment(p, [b"x"], kind="cluster")
    with pytest.raises(CorruptSegmentError, match="kind"):
        store.read_segment(p, kind="manifest")


def test_obj_roundtrip(tmp_path):
    p = str(tmp_path / "o.bin")
    obj = {"a": np.arange(3).tolist(), "b": "text"}
    store.dump_obj(p, obj, kind="k")
    assert store.load_obj(p, kind="k") == obj


def test_foreign_file_rejected(tmp_path):
    """A raw pickle (the pre-durability format) is refused, not fed to
    pickle.loads."""
    p = str(tmp_path / "legacy.bin")
    with open(p, "wb") as f:
        pickle.dump({"oops": 1}, f)
    with pytest.raises(CorruptSegmentError, match="magic"):
        store.load_obj(p)


def test_every_byte_flip_detected(tmp_path):
    """Bit-rot anywhere in the file — header, meta, record framing or
    payload — fails validation."""
    p = str(tmp_path / "a.seg")
    store.write_segment(p, [b"payload-one", b"payload-two"], {"m": 2})
    size = os.path.getsize(p)
    with open(p, "rb") as f:
        good = f.read()
    step = max(1, size // 64)
    for off in range(0, size, step):
        with open(p, "wb") as f:
            f.write(good)
        store_faults.flip_byte(p, off)
        with pytest.raises(CorruptSegmentError):
            store.read_segment(p)


def test_every_truncation_detected(tmp_path):
    p = str(tmp_path / "a.seg")
    store.write_segment(p, [b"some-payload" * 8], {"m": 1})
    with open(p, "rb") as f:
        good = f.read()
    for keep in range(0, len(good), max(1, len(good) // 32)):
        with open(p, "wb") as f:
            f.write(good[:keep])
        with pytest.raises(CorruptSegmentError):
            store.read_segment(p)


def test_trailing_garbage_detected(tmp_path):
    p = str(tmp_path / "a.seg")
    store.write_segment(p, [b"x"], {})
    with open(p, "ab") as f:
        f.write(b"\x00" * 7)
    with pytest.raises(CorruptSegmentError, match="trailing"):
        store.read_segment(p)


def test_array_record_roundtrip():
    a = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    payload, spec = store.array_record(a)
    b = store.record_array(payload, spec)
    assert b.dtype == a.dtype and b.shape == a.shape
    np.testing.assert_array_equal(a, b)
    with pytest.raises(CorruptSegmentError):
        store.record_array(payload[:-4], spec)


def test_atomic_write_crash_leaves_old_or_nothing(tmp_path):
    """CrashPlan swept over every fs op of a segment overwrite: the file
    on disk is always either the old version or the new one, intact."""
    p = str(tmp_path / "a.seg")
    store.write_segment(p, [b"old"], kind="t")
    total = store_faults.count_fs_ops(
        lambda: store.write_segment(p, [b"new"], kind="t"))
    assert total >= 3
    for at in range(1, total + 1):
        store.write_segment(p, [b"old"], kind="t")
        with store_faults.CrashPlan(at) as plan:
            try:
                store.write_segment(p, [b"new"], kind="t")
            except store_faults.InjectedCrash:
                pass
        assert plan.fired
        _, recs = store.read_segment(p, kind="t")
        assert recs in ([b"old"], [b"new"])


# ------------------------------------------------------------------ WAL

def test_wal_append_replay(tmp_path):
    p = str(tmp_path / "w.log")
    w = WriteAheadLog(p, generation=3)
    frames = [b"one", b"", b"three" * 100]
    for fr in frames:
        w.append(fr)
    w.close()
    ops, torn = WriteAheadLog.replay(p)
    assert ops == frames and not torn


def test_wal_missing_and_empty(tmp_path):
    assert WriteAheadLog.replay(str(tmp_path / "nope.log")) == ([], False)
    p = str(tmp_path / "empty.log")
    open(p, "wb").close()
    assert WriteAheadLog.replay(p) == ([], False)


def test_wal_torn_tail_discarded(tmp_path):
    """Truncating anywhere keeps a prefix of intact frames and flags the
    tail; no partial frame is ever replayed."""
    p = str(tmp_path / "w.log")
    w = WriteAheadLog(p)
    frames = [f"op-{i}".encode() * (i + 1) for i in range(6)]
    for fr in frames:
        w.append(fr)
    w.close()
    size = os.path.getsize(p)
    with open(p, "rb") as f:
        good = f.read()
    for keep in range(size - 1, 0, -max(1, size // 40)):
        with open(p, "wb") as f:
            f.write(good[:keep])
        ops, torn = WriteAheadLog.replay(p)
        assert ops == frames[:len(ops)]       # strict prefix, in order
        if len(ops) < len(frames):
            assert torn


def test_wal_corrupt_frame_stops_replay(tmp_path):
    p = str(tmp_path / "w.log")
    w = WriteAheadLog(p)
    for i in range(4):
        w.append(f"frame-{i}".encode())
    w.close()
    # flip a byte inside frame 2's payload: frames 0-1 survive, 2+ drop
    hdr = struct.calcsize("<4sHHQ")
    frame = struct.calcsize("<II") + len(b"frame-0")
    store_faults.flip_byte(p, hdr + 2 * frame + struct.calcsize("<II") + 3)
    ops, torn = WriteAheadLog.replay(p)
    assert ops == [b"frame-0", b"frame-1"] and torn


# -------------------------------------------------------------- journal

def _commit_gen(j: Journal, payload: bytes) -> int:
    tmp = j.begin()
    store.write_segment(os.path.join(tmp, "state.seg"), [payload], kind="t")
    return j.commit()


def test_journal_generations_and_read(tmp_path):
    j = Journal(str(tmp_path))
    assert j.latest() is None and j.replay() == ([], False)
    g0 = _commit_gen(j, b"gen-zero")
    g1 = _commit_gen(j, b"gen-one")
    assert (g0, g1) == (0, 1)
    assert j.generations() == [0, 1]
    blob = j.read_file(1, "state.seg")
    _, recs = store.decode_segment(blob)
    assert recs == [b"gen-one"]


def test_journal_wal_rotation(tmp_path):
    j = Journal(str(tmp_path))
    with pytest.raises(StoreError):
        j.append(b"no base generation yet")
    _commit_gen(j, b"base")
    j.append(b"m1")
    j.append(b"m2")
    assert j.wal_records() == 2
    _commit_gen(j, b"compacted")
    assert j.wal_records() == 0               # rotated away
    assert [n for n in os.listdir(tmp_path) if n.startswith("wal_")] == []


def test_journal_manifest_detects_bitrot(tmp_path):
    j = Journal(str(tmp_path))
    _commit_gen(j, b"data" * 100)
    store_faults.flip_byte(os.path.join(j.gen_dir(0), "state.seg"), 50)
    with pytest.raises(CorruptSegmentError, match="manifest"):
        j.read_file(0, "state.seg")


def test_journal_crash_sweep_never_half_commits(tmp_path):
    """Crash at every fs op during a second commit: the journal's latest
    generation is always fully readable (either gen 0 or gen 1), and WAL
    ops are only dropped once the commit that folds them is visible."""
    probe = Journal(str(tmp_path / "probe"))
    _commit_gen(probe, b"a")
    probe.append(b"op")
    total = store_faults.count_fs_ops(lambda: _commit_gen(probe, b"b"))
    for at in range(1, total + 1):
        root = str(tmp_path / f"r{at}")
        j = Journal(root)
        _commit_gen(j, b"a")
        j.append(b"op")
        with store_faults.CrashPlan(at):
            try:
                _commit_gen(j, b"b")
            except store_faults.InjectedCrash:
                pass
        j.close()
        j2 = Journal(root)                    # recovery: fresh reader
        g = j2.latest()
        assert g in (0, 1)
        _, recs = store.decode_segment(j2.read_file(g, "state.seg"))
        assert recs == [b"a" if g == 0 else b"b"]
        if g == 0:                            # not folded yet -> WAL kept
            assert j2.replay() == ([b"op"], False)


def test_stale_tmp_and_gateless_dirs_ignored(tmp_path):
    j = Journal(str(tmp_path))
    _commit_gen(j, b"real")
    os.makedirs(tmp_path / "gen_00000005.tmp")
    os.makedirs(tmp_path / "gen_00000007")    # no MANIFEST.json
    assert Journal(str(tmp_path)).latest() == 0


# ---------------------------------------------------------------- scrub

def test_scrub_clean_and_corrupt(tmp_path):
    j = Journal(str(tmp_path))
    tmp = j.begin()
    store.write_segment(os.path.join(tmp, "state.seg"), [b"x" * 500],
                        kind="t")
    j.commit()
    j.append(b"mutation")
    j.close()
    reps = store.scrub_path(str(tmp_path))
    assert reps and all(r["ok"] for r in reps)
    store_faults.flip_byte(os.path.join(j.gen_dir(0), "state.seg"), 200)
    reps = store.scrub_path(str(tmp_path))
    assert any(not r["ok"] for r in reps)


def test_scrub_plain_spill_dir(tmp_path):
    d = tmp_path / "spill"
    d.mkdir()
    store.write_segment(str(d / "c0.bin"), [b"ok"], kind="c")
    store.write_segment(str(d / "c1.bin"), [b"ok"], kind="c")
    store_faults.truncate_file(str(d / "c1.bin"), 10)
    reps = {os.path.basename(r["item"]): r["ok"]
            for r in store.scrub_path(str(d))}
    assert reps == {"c0.bin": True, "c1.bin": False}


def test_quarantine_file(tmp_path):
    p = str(tmp_path / "bad.bin")
    with open(p, "wb") as f:
        f.write(b"junk")
    dst = store.quarantine_file(p)
    assert dst == p + ".quarantined"
    assert not os.path.exists(p) and os.path.exists(dst)
    assert store.quarantine_file(str(tmp_path / "gone.bin")) is None
