"""HNSW external-id -> dense-internal-slot remapping: arbitrary 64-bit
ids must not balloon the vector array / pickles, and freed slots are
recycled. (Kept hypothesis-free so it collects everywhere; structural
property tests live in test_hnsw.py.)"""
import pickle

import numpy as np

from repro.core.hnsw import HNSW


def build(n=60, d=12, seed=0, ids=None):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    g = HNSW(d, M=8, ef_construction=32, seed=seed, max_elements=8)
    ids = range(n) if ids is None else ids
    for i, vid in enumerate(ids):
        g.insert(int(vid), X[i])
    return g, X


def test_huge_ids_stay_dense():
    n = 60
    base = 10**15
    g, X = build(n, ids=[base + 7 * i for i in range(n)])
    # the vectors array scales with the node count, not the id magnitude
    assert g.vectors.shape[0] <= 4 * n
    ids, _ = g.search(X[3], k=1, ef_search=64)
    assert int(ids[0]) == base + 21


def test_pickle_size_independent_of_id_magnitude():
    g_small, _ = build(40, ids=range(40))
    g_huge, _ = build(40, ids=[10**12 + i for i in range(40)])
    s, h = len(pickle.dumps(g_small)), len(pickle.dumps(g_huge))
    assert h < 2 * s


def test_graph_arrays_returns_external_ids():
    base = 5_000_000
    g, X = build(20, ids=[base + i for i in range(20)])
    ids, vecs = g.graph_arrays()
    assert set(map(int, ids)) == {base + i for i in range(20)}
    assert vecs.shape == (20, 12)
    # exported vectors line up with their external ids
    for vid, v in zip(ids, vecs):
        np.testing.assert_array_equal(v, X[int(vid) - base])


def test_delete_recycles_slots():
    g, X = build(30)
    cap0 = g.vectors.shape[0]
    for round_ in range(5):
        vid = 10**9 + round_
        g.insert(vid, X[0] + 0.01 * round_)
        g.delete(vid)
    assert g.vectors.shape[0] == cap0       # churn reused freed slots
    ids, _ = g.search(X[1], k=1, ef_search=64)
    assert int(ids[0]) == 1


def test_reinsert_same_external_id():
    g, X = build(20)
    g.delete(5)
    g.insert(5, X[5])
    ids, _ = g.search(X[5], k=1, ef_search=64)
    assert int(ids[0]) == 5


def test_reconstruct_by_external_id():
    base = 77_000_000
    g, X = build(10, ids=[base + i for i in range(10)])
    np.testing.assert_array_equal(g.reconstruct(base + 4), X[4])
